"""Design-space exploration walkthrough (paper §III.B / Fig. 3 + Fig. 5).

Runs the engine-in-the-loop DSE for a chosen digit width: the
whole-multiplier search proposes candidate cell assignments per border,
each is materialized into a real schedule and Monte-Carlo-measured through
ONE fused engine dispatch, costed by the energy model's structural proxy,
and the measured (|MRED|, energy) Pareto frontier is flagged — i.e. the
paper's Tables I/II + Fig. 5 exploration for any configuration you like,
scored by measurement instead of the analytic mean alone.

  PYTHONPATH=src python examples/dse_explore.py --digits 4 --borders 12 18 24
  PYTHONPATH=src python examples/dse_explore.py --digits 2 --candidates 3
"""
import argparse

from repro.core import AMRMultiplier
from repro.core.dse import pareto_sweep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--digits", type=int, default=2)
    ap.add_argument("--borders", type=int, nargs="+", default=[6, 7, 8, 9, 10])
    ap.add_argument("--samples", type=int, default=50000)
    ap.add_argument("--candidates", type=int, default=2,
                    help="assignments explored per border (k-best)")
    args = ap.parse_args()

    exact = AMRMultiplier(args.digits, border=None)
    print(f"exact {args.digits}-digit MRSD multiplier: "
          f"{sum(exact.cell_counts.values())} cells, {exact.n_stages} PPR stages")

    points = pareto_sweep(
        args.digits, args.borders, k=args.candidates,
        n_samples=args.samples, seed=0)

    print(f"{'border':>7} {'cand':>4} {'MRED':>11} {'MARED':>10} {'NMED':>11} "
          f"{'analytic':>11} {'energy':>8} {'nodes':>9} {'front':>5}")
    for pt in points:
        m = pt.measured
        a = pt.assignment
        print(f"{pt.border:7d} {pt.candidate:4d} {m['mred']:+.3e} "
              f"{m['mared']:.3e} {m['nmed']:+.3e} "
              f"{float(a.expected_error):+.4e} {pt.energy:8.0f} "
              f"{a.nodes:9d} {'  *' if pt.frontier else '':>5}")

    front = [pt for pt in points if pt.frontier]
    print(f"\nmeasured (|MRED|, energy) frontier: {len(front)} of "
          f"{len(points)} candidates (*)")
    best = min(front, key=lambda pt: abs(pt.measured["mred"]))
    counts = best.schedule.cell_counts
    fa = {k: v for k, v in counts.items() if k != "HA"}
    total = sum(fa.values())
    usage = "  ".join(f"{k}:{100.0 * v / total:.0f}%" for k, v in sorted(fa.items()))
    print(f"lowest-error frontier design (border {best.border}): cells {usage}")


if __name__ == "__main__":
    main()

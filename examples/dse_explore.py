"""Design-space exploration walkthrough (paper §III.B / Fig. 3 + Fig. 5).

Sweeps border columns for a chosen digit count, printing accuracy metrics,
cell-usage breakdown, and the calibrated cost model's energy estimates —
i.e. the paper's Tables I/II + Fig. 5 for any configuration you like.

  PYTHONPATH=src python examples/dse_explore.py --digits 4 --borders 12 18 24
"""
import argparse

from repro.core import AMRMultiplier
from repro.core.energy import DesignFeatures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--digits", type=int, default=2)
    ap.add_argument("--borders", type=int, nargs="+", default=[6, 7, 8, 9, 10])
    ap.add_argument("--samples", type=int, default=50000)
    args = ap.parse_args()

    exact = AMRMultiplier(args.digits, border=None)
    fe = DesignFeatures.from_multiplier(exact)
    print(f"exact {args.digits}-digit MRSD multiplier: "
          f"{sum(exact.cell_counts.values())} cells, {exact.n_stages} PPR stages")

    print(f"{'border':>7} {'MRED':>11} {'MARED':>10} {'NMED':>11} "
          f"{'approx-lit':>10} {'DSE nodes':>9}")
    for b in args.borders:
        m = AMRMultiplier(args.digits, border=b)
        r = m.monte_carlo(args.samples, seed=0)
        f = DesignFeatures.from_multiplier(m)
        print(f"{b:7d} {r['mred']:+.3e} {r['mared']:.3e} {r['nmed']:+.3e} "
              f"{f.approx_cell_literals:10d} {m.schedule.dse_nodes:9d}")
        usage = m.cell_usage_percent()
        line = "  ".join(f"{k}:{v:.0f}%" for k, v in usage.items())
        print(f"        cells: {line}")


if __name__ == "__main__":
    main()

"""Batched serving example: prefill + greedy decode on any assigned arch.

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b
  PYTHONPATH=src python examples/serve_decode.py --arch whisper-small
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()

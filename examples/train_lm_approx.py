"""End-to-end driver: train an LM under AMR-MUL numerics vs exact numerics.

Runs the full production path (data pipeline -> sharded state -> jitted
train step -> fault-tolerant loop -> checkpoints) twice on a small LM and
compares loss curves: the paper's claim is that its near-zero-mean,
Gaussian multiplier error is benign for error-resilient workloads — here,
LM training still converges under approximate matmuls.

  PYTHONPATH=src python examples/train_lm_approx.py --steps 60
  PYTHONPATH=src python examples/train_lm_approx.py --steps 300 --preset 100m

``--modes`` picks the numerics arms; ``amr_inject`` trains under the EXACT
per-product error of the design (on-device replay, docs/numerics.md), and
``--dse-candidate`` additionally trains a whole-multiplier-search candidate
schedule through the same injection path (no LUT export needed):

  PYTHONPATH=src python examples/train_lm_approx.py --steps 20 --preset tiny \
      --modes exact,amr_inject --dse-candidate

(the injected replay is exact-but-heavy on CPU — use ``--preset tiny`` for
interactive amr_inject runs; benchmarks/train_numerics_bench.py is the
CI-sized version of this comparison).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import SyntheticLM
from repro.numerics import AMRNumerics
from repro.train.steps import make_train_state, make_train_step

PRESETS = {
    # amr_inject-friendly CPU demo: the on-device replay pays ~hundreds of
    # bitwise ops per product, so keep M*K*N small for interactive runs
    "tiny": dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                 d_ff=64, vocab=64, batch=4, seq=16),
    # CPU-friendly smoke (runs in minutes)
    "small": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                  d_ff=512, vocab=512, batch=8, seq=128),
    # the deliverable-scale run (~100M params; use on real accelerators)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                 d_ff=3072, vocab=32000, batch=32, seq=512),
}


def make_cfg(p: dict, numerics: AMRNumerics) -> ModelConfig:
    return ModelConfig(
        name="amr-train", family="dense", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab=p["vocab"],
        mlp_act="swiglu", tie_embeddings=True, numerics=numerics, remat="none")


def run(cfg: ModelConfig, steps: int, batch: int, seq: int, seed: int = 0):
    data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch, seed=seed)
    state = make_train_state(cfg, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=10, total_steps=steps),
                   donate_argnums=(0,))
    losses = []
    t0 = time.time()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, b)
        losses.append(float(metrics["loss"]))
        if (i + 1) % 10 == 0:
            print(f"  step {i+1:4d} loss {losses[-1]:.4f}")
    dt = time.time() - t0
    return losses, dt


def main() -> None:
    from repro.launch.cli import (add_numerics_args, apply_pallas_interpret,
                                  numerics_from_args, parse_modes, policy_label)

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    add_numerics_args(ap, multi=True, default="exact,amr_lowrank",
                      rank_default=16)
    ap.add_argument("--dse-candidate", action="store_true",
                    help="also train a DSE-searched candidate schedule via amr_inject")
    ap.add_argument("--out", default="experiments/train_approx.json")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    apply_pallas_interpret(args, tag="example")

    # every arm is built the same way — the registry validates the mode name
    # and its parameters; there is no per-mode construction logic here
    arms: list[tuple[str, AMRNumerics]] = []
    for mode in parse_modes(args):
        try:
            nm = numerics_from_args(args, mode=mode)
        except ValueError as e:
            ap.error(str(e))
        arms.append((policy_label(nm), nm))
    if getattr(args, "policy_file", None):
        # a saved (possibly per-layer) policy artifact trains as one more arm
        pol = numerics_from_args(args)
        arms.append((policy_label(pol), pol))
    if args.dse_candidate:
        # a raw searched assignment, trained with NO materialized LUT
        from repro.core.dse import materialize, search_assignments
        from repro.numerics import injection

        cand = search_assignments(2, args.border, k=1, beam_width=16,
                                  branch_cap=4, max_nodes=4000)[0]
        ref = injection.register_schedule(materialize(cand))
        arms.append((f"amr_inject(dse,b={args.border})",
                     AMRNumerics("amr_inject", border=args.border, schedule_ref=ref)))

    results = {}
    for label, numerics in arms:
        print(f"== training with {label} numerics ==")
        losses, dt = run(make_cfg(p, numerics), args.steps, p["batch"], p["seq"])
        results[label] = {"losses": losses, "seconds": dt}
        print(f"   first->last loss: {losses[0]:.3f} -> {losses[-1]:.3f} ({dt:.0f}s)")

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(results, indent=1))
    exact = results.get("exact")
    for label, r in results.items():
        drop = r["losses"][0] - r["losses"][-1]
        gap = (f"; gap to exact {r['losses'][-1] - exact['losses'][-1]:+.3f}"
               if exact else "")
        print(f"{label}: final {r['losses'][-1]:.3f} (drop {drop:.3f}{gap})")


if __name__ == "__main__":
    main()

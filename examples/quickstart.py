"""Quickstart: the paper's multiplier end-to-end in five minutes.

1. Build the bit-accurate radix-16 AMR-MUL and reproduce a Table-I-style
   accuracy row.
2. Show the branch-and-bound DSE compensating a column's running error.
3. Use AMR-MUL numerics inside a real matmul (LUT, low-rank MXU form, and
   the Pallas kernel) and compare errors.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AMRMultiplier, assign_column, exact_multiplier
from repro.core.lut import lowrank_factor
from repro.kernels.amr_matmul.ops import amr_matmul
from repro.numerics import AMRNumerics, approx_matmul


def main() -> None:
    print("=== 1. bit-accurate AMR-MUL (paper §III) ===")
    exact = exact_multiplier(2)
    x, y = np.array([137]), np.array([-55])
    print(f"exact 2-digit MRSD: {x[0]} * {y[0]} = {exact.multiply_values(x, y)[0]:.0f}")
    for border in (6, 8, 10):
        m = AMRMultiplier(2, border=border)
        r = m.monte_carlo(20000, seed=0)
        print(f"border {border:2d}: MRED {r['mred']:+.2e}  MARED {r['mared']:.2e} "
              f" NMED {r['nmed']:+.2e}  (Table I trend)")

    print("\n=== 2. DSE cell assignment (paper Fig. 3) ===")
    res = assign_column(pos_cnt=7, neg_cnt=2, err_in=0.5)
    print(f"column with 7 posibits + 2 negabits, incoming err +0.50:")
    print(f"  cells: {[c[0] for c in res.cells]}  -> residual err {float(res.err):+.2f}")

    print("\n=== 3. AMR-MUL as NN matmul numerics ===")
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (128, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    exact_mm = a @ b
    for mode, kwargs in [("amr_lut", {}), ("amr_lowrank", {"rank": 8}),
                         ("amr_lowrank", {"rank": 64})]:
        out = approx_matmul(a, b, AMRNumerics(mode, border=8, **kwargs))
        rel = jnp.median(jnp.abs(out - exact_mm) / (jnp.abs(exact_mm) + 1e-3))
        print(f"  {mode}{kwargs or ''}: median relative deviation {float(rel):.3f}")

    print("\n=== 4. Pallas kernel (interpret mode) ===")
    out_k = amr_matmul(a[:, :128], b[:128, :], border=8, rank=8, interpret=True)
    ref = approx_matmul(a[:, :128], b[:128, :], AMRNumerics("amr_lowrank", border=8, rank=8))
    print(f"  kernel vs jnp ref max |diff|: "
          f"{float(jnp.abs(out_k - ref).max()):.2e}")
    f = lowrank_factor(8, 64)
    print(f"  rank-64 error-table residual: {f.residual_fro:.3f} "
          f"(rank-256 is bit-exact)")


if __name__ == "__main__":
    main()

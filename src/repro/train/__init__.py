"""Training/serving step functions (jit/pjit targets)."""
from .steps import TrainState, loss_fn, make_serve_step, make_train_step, make_prefill_step

__all__ = ["TrainState", "loss_fn", "make_train_step", "make_serve_step",
           "make_prefill_step"]

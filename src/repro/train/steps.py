"""train_step / prefill_step / serve_step — the functions the launcher jits.

These are the exact computations the dry-run lowers for every
(arch x shape x mesh) cell:
  * train_*   — loss + grad + AdamW update (optionally with microbatch
                gradient accumulation), donated state.
  * prefill_* — full-sequence forward returning logits (batch inference).
  * serve_*   — one-token decode against a KV/SSM cache, donated cache.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward
from repro.numerics import numerics_scope
from repro.optim import adamw_init, adamw_update, cosine_warmup


@partial(jax.tree_util.register_dataclass,
         data_fields=["params", "opt", "step"], meta_fields=[])
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray


def make_train_state(cfg: ModelConfig, key) -> TrainState:
    from repro.models import init_params
    params = init_params(cfg, key)
    return TrainState(params, adamw_init(params), jnp.zeros((), jnp.int32))


def loss_fn(cfg: ModelConfig, params, tokens, targets, extra=None,
            aux_weight: float = 0.01, step=None, *, with_logits: bool = False):
    """``step`` (traced int scalar) feeds the numerics PRNG scope so
    amr_noise draws decorrelate across training steps (repro.numerics.context).

    ``with_logits=True`` returns ``(loss, (aux, logits))`` — lets a single
    differentiated call serve both the gradient and a logits inspection
    (the conformance probes) without a second forward compile."""
    with numerics_scope(step=step):
        logits, aux = forward(cfg, params, tokens, extra)
    ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(ll, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean() + aux_weight * aux
    return (loss, (aux, logits)) if with_logits else (loss, aux)


def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, microbatch: int | None = None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch``: split the global batch into that many sequential
    micro-steps with gradient accumulation (activation memory / pipeline
    trade-off — a §Perf lever).
    """

    def grads_of(params, tokens, targets, extra, step):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, targets, extra, step=step),
            has_aux=True)(params)
        return loss, aux, grads

    def train_step(state: TrainState, batch: dict):
        tokens = batch["tokens"]
        targets = batch["targets"]
        extra = batch.get("extra")
        if microbatch and microbatch > 1:
            def mb(carry, xs):
                loss_a, aux_a, acc = carry
                t, y = xs[0], xs[1]
                e = xs[2] if len(xs) > 2 else None
                loss, aux, g = grads_of(state.params, t, y, e, state.step)
                acc = jax.tree.map(jnp.add, acc, g)
                return (loss_a + loss, aux_a + aux, acc), None

            B = tokens.shape[0]
            if B % microbatch:
                raise ValueError(
                    f"global batch size {B} is not divisible by "
                    f"microbatch={microbatch}; pick a microbatch count that "
                    f"divides the batch (e.g. {B} % {microbatch} == 0)")
            mbs = B // microbatch
            resh = lambda x: x.reshape(microbatch, mbs, *x.shape[1:])
            xs = (resh(tokens), resh(targets)) + ((resh(extra),) if extra is not None else ())
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, aux, grads), _ = jax.lax.scan(mb, (0.0, 0.0, zero), xs)
            loss, aux = loss / microbatch, aux / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, aux, grads = grads_of(state.params, tokens, targets, extra,
                                        state.step)

        lr = cosine_warmup(state.step, peak_lr=peak_lr, warmup=warmup, total=total_steps)
        params, opt = adamw_update(grads, state.opt, state.params, lr)
        metrics = {"loss": loss, "aux": aux, "lr": lr}
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def make_grads_step(cfg: ModelConfig):
    """Forward+backward only (one microbatch worth) — the dry-run's unit of
    cost extraction: per-step cost = microbatches x this + optimizer terms
    (launch/roofline.py)."""

    def grads_step(params, batch):
        (_, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch["tokens"], batch["targets"],
                              batch.get("extra")), has_aux=True)(params)
        return grads

    return grads_step


def make_prefill_step(cfg: ModelConfig):
    """Prefill returns ONLY the last position's logits (the decode seed).

    Materialising (B, S, vocab) logits for a 32k prefill is ~tens of GB per
    device of pure waste — no serving system does it (measured: gemma3-1b
    prefill peak 100 GB/device before this, <16 GB after)."""

    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch["tokens"], batch.get("extra"),
                            last_only=True)
        return logits[:, 0, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, with_logits: bool = False):
    """One greedy decode step over a (possibly slot-batched) cache.

    ``batch`` may carry ``active`` — a (B,) bool continuous-batching slot
    mask threaded through to ``decode_step`` (inactive slots' cache state is
    held bit-for-bit; their outputs are garbage the caller masks off). One
    trace serves every admit/evict pattern: the mask is a traced operand,
    so slots finishing or joining never recompiles.

    ``with_logits=True`` additionally returns the final-position logits
    (float32) — serve_bench uses the raw logit stream for the
    batched-vs-solo bit-exactness gate, which is a strictly stronger check
    than argmax-token equality.
    """

    def serve_step(params, cache, batch):
        logits, cache = decode_step(cfg, params, batch["token"], cache,
                                    batch.get("enc_out"), batch.get("active"))
        last = logits[:, -1]
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        if with_logits:
            return next_tok, last.astype(jnp.float32), cache
        return next_tok, cache

    return serve_step

"""Static analysis for the repo's numerics invariants.

Two engines (docs/analysis.md):

  * ``lint`` — AST-level rules with stable IDs (RPL001..RPL006) enforcing
    the invariants that previously lived only as runtime guards or reviewer
    lore: no mode-name string matching outside ``numerics/``, no raw
    ``jax.random.PRNGKey`` outside ``numerics/context.py``, no unlabeled
    dense/approx-matmul call sites, no array constants captured in Pallas
    kernel bodies, no ``lru_cache`` over array-taking functions, no
    non-atomic persistent writes bypassing the ``.tmp``+rename protocol.
    Deliberate exceptions go in the committed ``.analysis-allowlist``.
  * ``trace_contract`` — traces the REAL jitted train / prefill / serve
    decode steps per config family x numerics mode and statically checks
    the closed jaxprs: retrace stability (the ``_cache_size() == 1``
    serving property, proven structurally), PRNG provenance (every random
    primitive derives from a ``numerics_scope``-folded key), decode-cache
    donation actually aliased in the lowering, and the int32-saturation
    proof over every registered schedule.

Run ``python -m repro.analysis`` (lint) / ``python -m repro.analysis trace``
— both are wired into the CI ``analysis`` job and exit non-zero on any
finding.
"""
from .lint import Finding, Rule, load_allowlist, run_lint
from .trace_contract import (ContractFinding, run_trace_contracts,
                             saturation_report)

__all__ = ["Finding", "Rule", "run_lint", "load_allowlist",
           "ContractFinding", "run_trace_contracts", "saturation_report"]

"""Numerics-invariant lint pass: AST rules with stable IDs (RPL001..).

Each rule encodes one invariant the repo used to enforce only at runtime
or by review (docs/analysis.md has the full catalog with rationale):

  RPL001  mode-name string matching outside ``numerics/`` — dispatch and
          sweep construction must go through the mode registry
          (``mode_names`` / ``is_exact_mode`` / ``default_policy``).
  RPL002  raw ``jax.random.PRNGKey`` outside ``numerics/context.py`` — the
          PR 4 PRNG-reuse bug class; keys derive from ``root_key`` /
          ``noise_key`` so step/layer/site folding can't be bypassed.
  RPL003  ``dense``/``approx_matmul`` call sites without a ``site=`` label —
          unlabeled sites are invisible to audit traces, per-site policy
          resolution and the PRNG decorrelation fold.  Under
          ``src/repro/models/`` the rule also flags RAW matmuls
          (``jnp.einsum``/``matmul``/``dot``/``tensordot``/
          ``lax.dot_general``): model-layer contractions bypass the seam
          entirely unless they go through ``dense``/``approx_matmul``, so
          every deliberate-exact einsum (router logits, intra-chunk SSD
          quadratic form, exact-mode branches) carries an allowlist entry
          naming it as reviewed.
  RPL004  array constants captured by a Pallas kernel body's closure —
          Pallas lowers captured arrays as baked constants; they must
          arrive as refs (whole-block inputs) instead.
  RPL005  ``functools.lru_cache`` on a function taking array arguments —
          the PR 2 tracer-caching bug class (tracers hash by object
          identity; caching them leaks traces across jaxpr scopes).
  RPL006  persistent writes bypassing the ``.tmp``+rename protocol — a
          crash mid-write must never leave a torn artifact at the real
          path (``ckpt/checkpoint.py`` is the reference implementation).

Pure stdlib (no jax import): the pass parses, never executes.  Deliberate
exceptions live in the committed ``.analysis-allowlist``, keyed on
``(rule, path, enclosing qualname)`` — line-number free so entries survive
unrelated churn.  Run as ``python -m repro.analysis`` /
``scripts/lint_repro.py`` / the ``repro-lint`` console script.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import sys
from pathlib import Path
from typing import Iterable, Iterator

__all__ = ["Finding", "Rule", "RULES", "run_lint", "load_allowlist", "main"]

# Directories scanned relative to the repo root (tests/ is excluded: rule
# fixtures and runtime-guard pokes live there on purpose).
SCAN_DIRS = ("src", "benchmarks", "scripts", "examples")

# Names whose presence as an lru_cache'd parameter marks the function as
# array-taking (exact match, conventional jax/numpy operand names).
_ARRAYISH_PARAMS = frozenset({
    "a", "b", "x", "y", "xs", "ys", "arr", "array", "ia", "ib", "qa", "qb",
    "tokens", "batch", "params", "weights", "operands", "grads",
})
_ARRAYISH_ANNOTATIONS = ("ndarray", "jax.Array", "jnp.", "ArrayLike",
                         "DeviceArray")

# Array-constructor attributes on numpy/jax.numpy roots (RPL004).
_ARRAY_CTORS = frozenset({
    "array", "asarray", "zeros", "ones", "full", "arange", "linspace", "eye",
    "empty", "zeros_like", "ones_like", "full_like",
})
_ARRAY_ROOTS = frozenset({"np", "numpy", "jnp"})


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str       # stable rule ID, e.g. "RPL002"
    path: str       # repo-relative posix path
    line: int
    col: int
    qualname: str   # enclosing def/class qualname, or "<module>"
    message: str

    def key(self) -> tuple[str, str, str]:
        """The allowlist key: line-number free so entries survive edits
        elsewhere in the file."""
        return (self.rule, self.path, self.qualname)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.qualname}] {self.message}")


class _FileContext:
    """Parsed file + parent links and qualname resolution for rule checks."""

    def __init__(self, rel: str, source: str, tree: ast.Module):
        self.rel = rel
        self.source = source
        self.tree = tree
        self.parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

    def qualname(self, node: ast.AST) -> str:
        parts: list[str] = []
        cur: ast.AST | None = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = self.parents.get(cur)
        return ".".join(reversed(parts)) or "<module>"

    def enclosing_scopes(self, node: ast.AST) -> list[ast.AST]:
        """Function scopes enclosing ``node`` (innermost first), then the
        module — the chain a closure resolves free names against."""
        scopes: list[ast.AST] = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Module)):
                scopes.append(cur)
            cur = self.parents.get(cur)
        return scopes

    def finding(self, rule: "Rule", node: ast.AST, message: str) -> Finding:
        return Finding(rule.id, self.rel, getattr(node, "lineno", 0),
                       getattr(node, "col_offset", 0), self.qualname(node),
                       message)


def _dotted(node: ast.AST) -> str | None:
    """``jax.random.PRNGKey``-style dotted name of a Name/Attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Rule:
    """One lint rule: stable ID, path scope, and a per-file check."""

    id: str = "RPL000"
    title: str = ""
    include: tuple[str, ...] = ("src/",)
    exclude: tuple[str, ...] = ()

    def applies_to(self, rel: str) -> bool:
        return (any(rel.startswith(p) for p in self.include)
                and not any(rel.startswith(p) for p in self.exclude))

    def check(self, ctx: _FileContext) -> Iterator[Finding]:
        raise NotImplementedError


class ModeStringRule(Rule):
    """RPL001: mode-name string literals in comparisons outside numerics/."""

    id = "RPL001"
    title = "mode-name string matching outside numerics/"
    include = ("src/", "benchmarks/", "scripts/", "examples/")
    exclude = ("src/repro/numerics/",)

    @staticmethod
    def _mode_ident(node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            low = node.id.lower()
            return low in ("m", "modes") or "mode" in low
        if isinstance(node, ast.Attribute):
            return "mode" in node.attr.lower()
        return False

    @classmethod
    def _literals(cls, node: ast.AST) -> Iterator[str]:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            yield node.value
        elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            for elt in node.elts:
                yield from cls._literals(elt)

    def check(self, ctx: _FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            if not any(isinstance(op, (ast.Eq, ast.NotEq, ast.In, ast.NotIn))
                       for op in node.ops):
                continue
            operands = [node.left, *node.comparators]
            lits = [s for op in operands for s in self._literals(op)]
            amr = [s for s in lits if s.startswith("amr_")]
            exact = "exact" in lits and any(self._mode_ident(op)
                                            for op in operands)
            if amr or exact:
                what = amr[0] if amr else "exact"
                yield ctx.finding(
                    self, node,
                    f"comparison against mode name {what!r}: dispatch on the "
                    f"registry instead (mode_names / is_exact_mode / "
                    f"default_policy)")


class RawPrngRule(Rule):
    """RPL002: raw jax.random.PRNGKey outside numerics/context.py."""

    id = "RPL002"
    title = "raw jax.random.PRNGKey outside numerics/context.py"
    include = ("src/",)
    exclude = ("src/repro/numerics/context.py",)

    def check(self, ctx: _FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted and (dotted == "PRNGKey"
                           or dotted.endswith("random.PRNGKey")):
                yield ctx.finding(
                    self, node,
                    "raw PRNGKey creation: derive keys from "
                    "numerics.context.root_key (or noise_key) so step/layer/"
                    "site folding cannot be bypassed")


class UnlabeledSiteRule(Rule):
    """RPL003: seam calls without a site label; raw matmuls in models/.

    Two findings share the ID (both are "this contraction is invisible to
    the numerics policy machinery"):

    * a ``dense``/``approx_matmul`` call without ``site=`` — on the seam
      but unaddressable by audits, per-site policies and the PRNG fold;
    * a raw ``jnp.einsum``/``matmul``/``dot``/``tensordot``/
      ``lax.dot_general`` under ``src/repro/models/`` — bypasses the seam
      entirely.  Deliberate-exact contractions (router logits, the
      intra-chunk SSD quadratic form whose masked-decay weighting has no
      plain matmul form, exact-mode fallback branches) are reviewed
      exceptions carried in ``.analysis-allowlist``.
    """

    id = "RPL003"
    title = "dense/approx_matmul call without site= label"
    include = ("src/",)
    exclude = ("src/repro/numerics/",)

    _RAW_MATMULS = ("einsum", "matmul", "dot", "dot_general", "tensordot")
    _MODELS_PREFIX = "src/repro/models/"

    def check(self, ctx: _FileContext) -> Iterator[Finding]:
        in_models = ctx.rel.startswith(self._MODELS_PREFIX)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            name = dotted.rsplit(".", 1)[-1] if dotted else None
            if name in ("dense", "approx_matmul"):
                if any(kw.arg == "site" for kw in node.keywords):
                    continue
                if name == "dense" and len(node.args) >= 4:  # positional site
                    continue
                yield ctx.finding(
                    self, node,
                    f"{name} call without site=: unlabeled sites are "
                    f"invisible to audit traces, per-site policies and the "
                    f"PRNG decorrelation fold")
            elif in_models and name in self._RAW_MATMULS and dotted and (
                    "." in dotted):
                yield ctx.finding(
                    self, node,
                    f"raw {dotted} in models/: the contraction bypasses the "
                    f"numerics seam — route it through dense/approx_matmul "
                    f"with a site label, or allowlist it as a reviewed "
                    f"deliberate-exact site")


class PallasCapturedConstRule(Rule):
    """RPL004: array constants captured by a Pallas kernel body closure."""

    id = "RPL004"
    title = "array constant captured in a Pallas kernel body"
    include = ("src/",)

    @staticmethod
    def _is_kernel_def(node: ast.AST) -> bool:
        if not isinstance(node, ast.FunctionDef):
            return False
        refs = [a for a in node.args.args if a.arg.endswith("_ref")]
        return len(refs) >= 2

    @staticmethod
    def _local_names(fn: ast.FunctionDef) -> set[str]:
        names = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                 + fn.args.kwonlyargs)}
        for extra in (fn.args.vararg, fn.args.kwarg):
            if extra is not None:
                names.add(extra.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                names.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)) and node is not fn:
                names.add(node.name)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for alias in node.names:
                    names.add((alias.asname or alias.name).split(".")[0])
        return names

    @staticmethod
    def _array_ctor_call(node: ast.AST) -> bool:
        if not isinstance(node, ast.Call):
            return False
        dotted = _dotted(node.func)
        if not dotted or "." not in dotted:
            return False
        root, attr = dotted.split(".", 1)
        return root in _ARRAY_ROOTS and attr.rsplit(".", 1)[-1] in _ARRAY_CTORS

    def check(self, ctx: _FileContext) -> Iterator[Finding]:
        if "pallas" not in ctx.source:
            return
        for node in ast.walk(ctx.tree):
            if not self._is_kernel_def(node):
                continue
            local = self._local_names(node)
            free = {n.id for n in ast.walk(node)
                    if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                    and n.id not in local}
            for scope in ctx.enclosing_scopes(node):
                for stmt in ast.walk(scope):
                    if not isinstance(stmt, ast.Assign):
                        continue
                    targets = [t.id for t in stmt.targets
                               if isinstance(t, ast.Name)]
                    hits = [t for t in targets if t in free]
                    if hits and self._array_ctor_call(stmt.value):
                        yield ctx.finding(
                            self, node,
                            f"kernel body {node.name!r} closes over array "
                            f"constant {hits[0]!r} (bound at line "
                            f"{stmt.lineno}): Pallas bakes captured arrays "
                            f"into the lowering — pass it as a whole-block "
                            f"ref input instead")


class LruCacheArrayRule(Rule):
    """RPL005: functools.lru_cache on functions taking array arguments."""

    id = "RPL005"
    title = "lru_cache on an array-taking function"
    include = ("src/",)

    @staticmethod
    def _is_cache_decorator(dec: ast.AST) -> bool:
        target = dec.func if isinstance(dec, ast.Call) else dec
        dotted = _dotted(target) or ""
        return dotted.rsplit(".", 1)[-1] in ("lru_cache", "cache")

    def check(self, ctx: _FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(self._is_cache_decorator(d) for d in node.decorator_list):
                continue
            for arg in (node.args.posonlyargs + node.args.args
                        + node.args.kwonlyargs):
                ann = ast.unparse(arg.annotation) if arg.annotation else ""
                if (arg.arg in _ARRAYISH_PARAMS
                        or any(m in ann for m in _ARRAYISH_ANNOTATIONS)):
                    yield ctx.finding(
                        self, node,
                        f"lru_cache on {node.name!r} whose parameter "
                        f"{arg.arg!r} looks array-valued: tracers hash by "
                        f"identity and caching them leaks traces across "
                        f"jaxpr scopes (the PR 2 bug class); key on static "
                        f"metadata instead")
                    break


class NonAtomicWriteRule(Rule):
    """RPL006: persistent writes bypassing the .tmp+rename protocol."""

    id = "RPL006"
    title = "non-atomic persistent write"
    include = ("src/",)
    exclude = ("src/repro/ckpt/checkpoint.py",)  # the protocol itself

    _WRITE_ATTRS = ("write_text", "write_bytes")
    _SAVE_FNS = ("save", "savez", "savez_compressed")

    def _path_expr(self, node: ast.Call) -> ast.AST | None:
        dotted = _dotted(node.func)
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in self._WRITE_ATTRS:
                return node.func.value
            if (dotted and dotted.split(".", 1)[0] in ("np", "numpy")
                    and node.func.attr in self._SAVE_FNS and node.args):
                return node.args[0]
        if dotted == "open" and node.args:
            mode = None
            if len(node.args) >= 2:
                mode = node.args[1]
            for kw in node.keywords:
                if kw.arg == "mode":
                    mode = kw.value
            if (isinstance(mode, ast.Constant) and isinstance(mode.value, str)
                    and mode.value[:1] in ("w", "a", "x")):
                return node.args[0]
        return None

    def check(self, ctx: _FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            path_expr = self._path_expr(node)
            if path_expr is None:
                continue
            if "tmp" in ast.unparse(path_expr).lower():
                continue  # the .tmp half of a tmp+rename pair
            yield ctx.finding(
                self, node,
                "persistent write without the .tmp+rename protocol: a crash "
                "mid-write leaves a torn artifact at the real path — write "
                "to '<path>.tmp' then os.replace (see ckpt/checkpoint.py)")


RULES: tuple[Rule, ...] = (
    ModeStringRule(), RawPrngRule(), UnlabeledSiteRule(),
    PallasCapturedConstRule(), LruCacheArrayRule(), NonAtomicWriteRule(),
)


def _iter_files(root: Path, paths: Iterable[str] | None) -> Iterator[Path]:
    if paths:
        for p in paths:
            p = Path(p)
            if p.is_dir():
                yield from sorted(p.rglob("*.py"))
            else:
                yield p
        return
    for d in SCAN_DIRS:
        base = root / d
        if base.is_dir():
            yield from sorted(base.rglob("*.py"))


def load_allowlist(path: Path) -> dict[tuple[str, str, str], str]:
    """Parse the allowlist: ``RULE path qualname`` per line, ``#`` comments.

    Returns entry -> its source line (for stale-entry reporting)."""
    entries: dict[tuple[str, str, str], str] = {}
    if not path.is_file():
        return entries
    for raw in path.read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split()
        if len(parts) != 3:
            raise ValueError(
                f"{path}: malformed allowlist line {raw!r} — expected "
                f"'RULE_ID path qualname'")
        entries[(parts[0], parts[1], parts[2])] = line
    return entries


def run_lint(root: Path, paths: Iterable[str] | None = None,
             allowlist: dict | None = None,
             rules: Iterable[str] | None = None,
             ) -> tuple[list[Finding], list[Finding], list[str]]:
    """Run the pass. Returns (findings, suppressed, stale_allowlist_lines)."""
    root = Path(root)
    allowlist = allowlist or {}
    wanted = set(rules) if rules else None
    active = [r for r in RULES if wanted is None or r.id in wanted]
    findings: list[Finding] = []
    suppressed: list[Finding] = []
    used: set[tuple[str, str, str]] = set()
    for file in _iter_files(root, paths):
        try:
            rel = file.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = file.as_posix()
        if "__pycache__" in rel:
            continue
        source = file.read_text()
        try:
            tree = ast.parse(source, filename=str(file))
        except SyntaxError as e:
            findings.append(Finding("RPL000", rel, e.lineno or 0, 0,
                                    "<module>", f"syntax error: {e.msg}"))
            continue
        ctx = _FileContext(rel, source, tree)
        for rule in active:
            if not rule.applies_to(rel):
                continue
            for f in rule.check(ctx):
                if f.key() in allowlist:
                    used.add(f.key())
                    suppressed.append(f)
                else:
                    findings.append(f)
    stale = [line for key, line in allowlist.items() if key not in used]
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, suppressed, stale


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint", description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to scan (default: src benchmarks "
                         "scripts examples under --root)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: nearest parent of cwd with a "
                         "pyproject.toml)")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist file (default: <root>/.analysis-allowlist)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule IDs to run (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULES:
            print(f"{r.id}  {r.title}")
        return 0

    root = Path(args.root) if args.root else _find_root()
    allow_path = (Path(args.allowlist) if args.allowlist
                  else root / ".analysis-allowlist")
    allowlist = load_allowlist(allow_path)
    rules = args.rules.split(",") if args.rules else None
    findings, suppressed, stale = run_lint(root, args.paths or None,
                                           allowlist, rules)
    for f in findings:
        print(f.render())
    for line in stale:
        print(f"{allow_path}: stale allowlist entry (matches nothing): {line}")
    n_files = "scanned"
    print(f"repro-lint: {len(findings)} finding(s), "
          f"{len(suppressed)} allowlisted, {len(stale)} stale "
          f"allowlist entr(y/ies) [{n_files}: "
          f"{', '.join(args.paths) if args.paths else ', '.join(SCAN_DIRS)}]")
    return 1 if (findings or stale) else 0


def _find_root() -> Path:
    cur = Path.cwd()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return cur


if __name__ == "__main__":
    sys.exit(main())

"""``python -m repro.analysis`` — lint by default, ``trace`` subcommand.

  python -m repro.analysis                # lint pass (RPL001..), stdlib-only
  python -m repro.analysis lint [...]     # same, explicit
  python -m repro.analysis trace [...]    # jaxpr trace contracts (imports jax)

Arguments after the subcommand go to that engine's own argparse
(``--allowlist``/``--rules`` for lint, ``--full``/``--out`` for trace).
"""
from __future__ import annotations

import sys


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "trace":
        from .trace_contract import main as trace_main
        return trace_main(argv[1:])
    if argv and argv[0] == "lint":
        argv = argv[1:]
    from .lint import main as lint_main
    return lint_main(argv)


if __name__ == "__main__":
    sys.exit(main())

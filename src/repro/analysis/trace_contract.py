"""jaxpr trace-contract analyzer: structural proofs over the real steps.

Traces the ACTUAL jitted computations — ``make_train_step``,
``make_prefill_step``, ``make_serve_step`` from ``train.steps`` on the
conformance representatives (``conformance.matrix``) — and checks the
closed jaxprs statically, no compile or execution:

  * retrace stability — tracing the step twice with DIFFERENT operand
    values (params/state/cache abstract via ``jax.eval_shape``, inputs
    concrete) must yield byte-identical jaxprs with value-identical
    consts.  Baked operand data shows up as a differing const; a captured
    Python scalar shows up as differing jaxpr text.  This is the
    structural form of the serving engine's ``_cache_size() == 1``
    property: if the jaxpr is invariant to operand VALUES, no
    admit/evict/token pattern can force a retrace.
  * PRNG provenance — every random primitive in the jaxpr must carry a
    traceback frame through ``numerics/context.py`` (``root_key`` /
    ``noise_key`` / the scope fold) — i.e. no key material enters a step
    except through the blessed derivation chain (lint RPL002's dynamic
    dual).
  * donation — the serve decode step lowered with ``donate_argnums=(1,)``
    must actually alias the cache buffers (``tf.aliasing_output`` in the
    StableHLO), not silently drop the donation.
  * int32-saturation proof — for every registered injection schedule
    (default borders + every ``register_schedule`` handle), bound
    ``max|product|`` symbolically from the lowered replay's bit weights,
    cross-check against the exact ``max_abs_product``, and verify every
    dense call site's contraction length K (collected trace-time via the
    ``NumericsScope.shape_probe`` channel, under ``jax.eval_shape``)
    against the ``check_accumulation_bound`` guard.  docs/analysis.md
    derives the math.

Run ``python -m repro.analysis trace [--full] [--out report.json]``.
Everything heavier than stdlib imports lazily so ``python -m
repro.analysis`` (the lint half) stays jax-free.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, Iterator

__all__ = ["ContractFinding", "iter_eqns", "check_retrace_stability",
           "check_prng_provenance", "check_donation",
           "run_trace_contracts", "saturation_report", "main"]

# Files a random primitive's traceback must pass through: the root/noise key
# derivation (context.py) or the in-scope fold at the matmul site.
BLESSED_PRNG_FILES = ("repro/numerics/context.py",
                      "repro/numerics/approx_matmul.py")

# Default-schedule borders the saturation proof covers.
QUICK_BORDERS = (8,)                      # the conformance BORDER
FULL_BORDERS = (4, 5, 6, 7, 8, 9, 10)     # the DSE sweep range

INT32_LIMIT = 2**31


@dataclasses.dataclass(frozen=True)
class ContractFinding:
    contract: str   # "retrace" | "prng" | "donation" | "saturation"
    where: str      # e.g. "gemma3-1b/amr_noise/train"
    message: str

    def render(self) -> str:
        return f"{self.where}: [{self.contract}] {self.message}"


# --------------------------------------------------------------------------
# jaxpr plumbing
# --------------------------------------------------------------------------

def _sub_jaxprs(eqn) -> Iterator[Any]:
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            inner = getattr(v, "jaxpr", None)  # ClosedJaxpr
            if inner is not None and hasattr(inner, "eqns"):
                yield inner
            elif hasattr(v, "eqns"):           # bare Jaxpr
                yield v


def iter_eqns(jaxpr) -> Iterator[Any]:
    """All equations of a (Closed)Jaxpr, recursing into scan/cond/pjit/
    while bodies via params."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _is_random_prim(eqn) -> bool:
    name = eqn.primitive.name
    return name.startswith("random_") or name.startswith("threefry")


def _frame_files(eqn) -> list[str]:
    tb = getattr(eqn.source_info, "traceback", None)
    if tb is None:
        return []
    return [f.file_name.replace("\\", "/") for f in tb.frames]


# --------------------------------------------------------------------------
# contracts
# --------------------------------------------------------------------------

def _normalized(jaxpr) -> str:
    """jaxpr text with object addresses scrubbed.

    ``custom_vjp_call_jaxpr`` params repr their bwd thunks as
    ``<function ... at 0x...>`` — fresh objects per trace, so raw text
    comparison would flag every custom-vjp mode as unstable.  Addresses
    never encode operand values; scrubbing them cannot mask a real leak.
    """
    import re

    return re.sub(r"0x[0-9a-fA-F]+", "0x", str(jaxpr))


def check_retrace_stability(fn, args_a, args_b, where: str,
                            ) -> list[ContractFinding]:
    """Trace ``fn`` under two operand bindings; the jaxprs must be
    structurally identical AND their consts value-identical.

    ``args_a``/``args_b`` share every shape/dtype and differ only in
    VALUES (abstract leaves may be ``jax.ShapeDtypeStruct``).  A text diff
    means a Python scalar / control-flow decision leaked into the trace; a
    const diff means operand DATA was baked in (the classic
    ``np.asarray(python_list)`` closure) — either one forces a recompile
    per distinct value at runtime.
    """
    import jax
    import numpy as np

    # A fresh wrapper per trace: jax caches traces on (callable, avals) and
    # the two bindings share avals by construction, so tracing ``fn``
    # directly would return the FIRST jaxpr twice and prove nothing.
    ja = jax.make_jaxpr(lambda *a: fn(*a))(*args_a)
    jb = jax.make_jaxpr(lambda *a: fn(*a))(*args_b)
    findings: list[ContractFinding] = []
    if _normalized(ja) != _normalized(jb):
        findings.append(ContractFinding(
            "retrace", where,
            "jaxpr structure differs across operand bindings — a Python "
            "value (scalar, shape, branch) from the operands is baked into "
            "the trace; every distinct value will recompile"))
        return findings  # const lists are not comparable across structures
    ca, cb = ja.consts, jb.consts
    if len(ca) != len(cb):
        findings.append(ContractFinding(
            "retrace", where,
            f"const count differs across bindings ({len(ca)} vs {len(cb)})"))
        return findings
    for i, (a, b) in enumerate(zip(ca, cb)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype != b.dtype or a.shape != b.shape or not np.array_equal(a, b):
            findings.append(ContractFinding(
                "retrace", where,
                f"baked operand data: const #{i} (shape {a.shape}, "
                f"{a.dtype}) differs across operand bindings — an input "
                f"value was captured as a trace constant instead of being "
                f"passed as an argument"))
    return findings


def check_prng_provenance(jaxpr, where: str, *, require_random: bool = False,
                          ) -> list[ContractFinding]:
    """Every random primitive must trace back through the blessed key
    derivation (``numerics/context.py`` / the scope fold in
    ``approx_matmul``); with ``require_random`` the jaxpr must contain at
    least one (a noise arm that traced no PRNG is silently exact)."""
    findings: list[ContractFinding] = []
    n_random = 0
    for eqn in iter_eqns(jaxpr):
        if not _is_random_prim(eqn):
            continue
        n_random += 1
        files = _frame_files(eqn)
        if not files:
            findings.append(ContractFinding(
                "prng", where,
                f"random primitive {eqn.primitive.name!r} carries no "
                f"traceback — provenance unverifiable"))
        elif not any(f.endswith(BLESSED_PRNG_FILES) for f in files):
            origin = next((f for f in files if "/repro/" in f), files[-1])
            findings.append(ContractFinding(
                "prng", where,
                f"random primitive {eqn.primitive.name!r} does not derive "
                f"from the numerics key chain (deepest repro frame: "
                f"{origin}) — keys must come from root_key/noise_key so "
                f"step/layer/site folding applies"))
    if require_random and n_random == 0:
        findings.append(ContractFinding(
            "prng", where,
            "expected PRNG primitives in this arm but the jaxpr has none — "
            "the noise path traced as exact"))
    return findings


def count_random_prims(jaxpr) -> int:
    return sum(1 for e in iter_eqns(jaxpr) if _is_random_prim(e))


def check_donation(fn, donate_argnums, args, where: str,
                   ) -> list[ContractFinding]:
    """Lower ``fn`` with the given donation and verify the StableHLO
    actually aliases at least one input buffer to an output
    (``tf.aliasing_output``) — jit silently drops undonatable args."""
    import jax

    text = jax.jit(fn, donate_argnums=donate_argnums).lower(*args).as_text()
    if "tf.aliasing_output" not in text:
        return [ContractFinding(
            "donation", where,
            f"donate_argnums={donate_argnums} produced no aliased output "
            f"buffer in the lowering — the donation is being dropped and "
            f"the decode cache is double-buffered")]
    return []


# --------------------------------------------------------------------------
# the arm driver: real steps on the conformance representatives
# --------------------------------------------------------------------------

def _trace_arms(quick: bool) -> list[tuple[str, str]]:
    """(arch, mode) grid: quick = every mode on the dense representative +
    the load-bearing approximate mode on every other representative; full =
    the whole representative x mode grid (nightly)."""
    from repro.conformance.matrix import REPRESENTATIVE
    from repro.numerics import mode_names

    reps = list(REPRESENTATIVE.values())
    dense = REPRESENTATIVE["dense"]
    if quick:
        arms = [(dense, m) for m in mode_names()]
        arms += [(a, "amr_inject") for a in reps if a != dense]
        return arms
    return [(a, m) for a in reps for m in mode_names()]


def _serve_binding(cfg, batch_size: int, capacity: int, seed: int):
    """(cache_sds, batch) for one decode step, mirroring ServeEngine:
    per-slot cache, token + active-mask operands (concrete, seed-varied)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import init_cache

    cache = jax.eval_shape(
        lambda: init_cache(cfg, batch_size, capacity, per_slot=True))
    rng = np.random.default_rng(seed)
    batch: dict[str, Any] = {
        "token": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch_size, 1)), jnp.int32),
        "active": jnp.asarray(rng.integers(0, 2, (batch_size,)) > 0),
    }
    if cfg.encoder_layers:
        batch["enc_out"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.encoder_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return cache, batch


def _strip_targets(batch: dict) -> dict:
    return {k: v for k, v in batch.items() if k != "targets"}


def run_arm(arch: str, mode: str, *, batch: int = 2, seq: int = 8,
            capacity: int = 16) -> tuple[list[ContractFinding], dict]:
    """All trace contracts for one (arch, mode) arm. Returns
    (findings, record) — the record goes into the JSON report."""
    import jax

    from repro.conformance.matrix import make_inputs, tiny_config
    from repro.launch.specs import abstract_params, abstract_train_state
    from repro.train.steps import (make_prefill_step, make_serve_step,
                                   make_train_step)

    cfg = tiny_config(arch, mode)
    findings: list[ContractFinding] = []
    where = f"{arch}/{mode}"

    # --- train step: abstract state, concrete batches from two seeds
    state = abstract_train_state(cfg)
    train_step = make_train_step(cfg, total_steps=4)
    b0, b1 = make_inputs(cfg, batch, seq, 0), make_inputs(cfg, batch, seq, 1)
    findings += check_retrace_stability(
        train_step, (state, b0), (state, b1), f"{where}/train")
    train_jaxpr = jax.make_jaxpr(train_step)(state, b0)
    findings += check_prng_provenance(train_jaxpr, f"{where}/train")

    # --- prefill step
    params = abstract_params(cfg)
    prefill_step = make_prefill_step(cfg)
    findings += check_retrace_stability(
        prefill_step, (params, _strip_targets(b0)),
        (params, _strip_targets(b1)), f"{where}/prefill")

    # --- serve decode step: stability + provenance + donation
    serve_step = make_serve_step(cfg)
    cache, sb0 = _serve_binding(cfg, batch, capacity, 0)
    _, sb1 = _serve_binding(cfg, batch, capacity, 1)
    findings += check_retrace_stability(
        serve_step, (params, cache, sb0), (params, cache, sb1),
        f"{where}/serve")
    serve_jaxpr = jax.make_jaxpr(serve_step)(params, cache, sb0)
    findings += check_prng_provenance(serve_jaxpr, f"{where}/serve")
    findings += check_donation(serve_step, (1,), (params, cache, sb0),
                               f"{where}/serve")

    record = {
        "arch": arch, "mode": mode,
        "train_eqns": sum(1 for _ in iter_eqns(train_jaxpr)),
        "serve_eqns": sum(1 for _ in iter_eqns(serve_jaxpr)),
        "train_random_prims": count_random_prims(train_jaxpr),
        "serve_random_prims": count_random_prims(serve_jaxpr),
        "findings": [f.render() for f in findings],
    }
    return findings, record


# --------------------------------------------------------------------------
# int32-saturation proof
# --------------------------------------------------------------------------

def _symbolic_bound(inj) -> int:
    """Bound max|product| from the lowered replay's bit weights alone.

    A replayed value is ``sum(bits * bit_weights) - offset_total`` with
    ``bits in {0, 1}``, so it lies in ``[-offset_total,
    sum(bit_weights) - offset_total]`` and ``max|value| <=
    max(|offset_total|, |sum(bit_weights) - offset_total|)`` — no product
    enumeration needed.  Conservative (docs/analysis.md quantifies the
    slack vs the exact ``max_abs_product``); soundness (symbolic >= exact)
    is itself checked per schedule.
    """
    bw_sum = int(inj.lowered.bit_weights.sum())
    ot = int(inj.lowered.offset_total)
    return max(abs(ot), abs(bw_sum - ot))


def collect_site_ks(archs, *, batch: int = 2, seq: int = 8,
                    capacity: int = 16) -> dict[str, int]:
    """Max contraction length K per dense call site across the given
    archs' train/prefill/serve computations — collected trace-time via the
    ``NumericsScope.shape_probe`` channel under ``jax.eval_shape`` (no
    compile, no execution)."""
    import jax

    from repro.conformance.matrix import make_inputs, tiny_config
    from repro.launch.specs import abstract_params, abstract_train_state
    from repro.numerics import numerics_scope
    from repro.train.steps import (make_prefill_step, make_serve_step,
                                   make_train_step)

    probe: list[dict] = []
    for arch in archs:
        cfg = tiny_config(arch, "amr_inject")
        b0 = make_inputs(cfg, batch, seq, 0)
        cache, sb0 = _serve_binding(cfg, batch, capacity, 0)
        with numerics_scope(shape_probe=probe):
            jax.eval_shape(make_train_step(cfg, total_steps=4),
                           abstract_train_state(cfg), b0)
            params = abstract_params(cfg)
            jax.eval_shape(make_prefill_step(cfg), params, _strip_targets(b0))
            jax.eval_shape(make_serve_step(cfg), params, cache, sb0)
    ks: dict[str, int] = {}
    for rec in probe:
        ks[rec["site"]] = max(ks.get(rec["site"], 0), rec["k"])
    return ks


def saturation_report(archs, *, borders=QUICK_BORDERS,
                      ) -> tuple[list[ContractFinding], dict]:
    """Per-schedule int32-saturation proof over every default-border design
    point in ``borders`` AND every ``register_schedule`` handle live in
    this process (100% registry coverage by construction)."""
    from repro.conformance.matrix import ACTIVATION_SITES
    from repro.core import engine
    from repro.numerics import injection

    site_ks = collect_site_ks(archs)
    max_site_k = max(site_ks.values(), default=0)
    # Activation×activation sites get their own breakout: their K is a
    # RUNTIME quantity (attn.pv / ssm.scan contract over the attended
    # length, moe.expert.* over the expert token bucket), so unlike the
    # weight sites — whose K is fixed by the config — the probed value
    # only witnesses the traced shapes.  ``max_safe_k_exact`` on each
    # schedule row is therefore also the serve-time CONTEXT bound the
    # deployment must respect for these sites.
    act_union = set().union(*ACTIVATION_SITES.values())
    activation_ks = {s: k for s, k in site_ks.items() if s in act_union}
    entries: list[tuple[str, Any]] = []
    for b in borders:
        inj = engine.get_injector(2, b)
        entries.append((injection.schedule_label(inj), inj))
    registered = sorted(injection._SCHEDULES)
    for handle in registered:
        shim = type("_Ref", (), {"schedule_ref": handle, "border": None})()
        entries.append((handle, injection.get_injector(shim)))

    findings: list[ContractFinding] = []
    rows = []
    for handle, inj in entries:
        sym = _symbolic_bound(inj)
        exact = int(inj.max_abs_product)
        max_safe_k = (INT32_LIMIT - 1) // exact
        proved = max_site_k * exact < INT32_LIMIT
        rows.append({
            "schedule": handle,
            "symbolic_bound": sym,
            "exact_bound": exact,
            "symbolic_slack": round(sym / exact, 2) if exact else None,
            "max_safe_k_exact": max_safe_k,
            "max_safe_k_symbolic": (INT32_LIMIT - 1) // sym if sym else None,
            "max_site_k": max_site_k,
            "proved": proved,
        })
        if sym < exact:
            findings.append(ContractFinding(
                "saturation", handle,
                f"symbolic bound {sym} < exact max|product| {exact} — the "
                f"bit-weight bound is unsound for this schedule"))
        if not proved:
            findings.append(ContractFinding(
                "saturation", handle,
                f"max site K={max_site_k} x max|product|={exact} = "
                f"{max_site_k * exact} >= 2**31: the runtime guard "
                f"(check_accumulation_bound) WILL reject this schedule at "
                f"K={max_site_k}; keep K <= {max_safe_k}"))
    report = {
        "sites": dict(sorted(site_ks.items())),
        "activation_sites": dict(sorted(activation_ks.items())),
        "max_activation_k": max(activation_ks.values(), default=0),
        "max_site_k": max_site_k,
        "schedules": rows,
        "registered_handles": registered,
        "default_borders": list(borders),
        "all_proved": all(r["proved"] for r in rows),
    }
    return findings, report


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def run_trace_contracts(*, quick: bool = True,
                        ) -> tuple[list[ContractFinding], dict]:
    """The full analyzer: all arms + the saturation proof. Returns
    (findings, report)."""
    from repro.conformance.matrix import REPRESENTATIVE

    findings: list[ContractFinding] = []
    records = []
    for arch, mode in _trace_arms(quick):
        f, rec = run_arm(arch, mode)
        findings += f
        records.append(rec)

    dense = REPRESENTATIVE["dense"]
    archs = [dense] if quick else list(REPRESENTATIVE.values())
    sat_findings, sat = saturation_report(
        archs, borders=QUICK_BORDERS if quick else FULL_BORDERS)
    findings += sat_findings

    report = {
        "schema": "analysis_trace/v1",
        "quick": quick,
        "arms": records,
        "saturation": sat,
        "n_findings": len(findings),
        "findings": [f.render() for f in findings],
    }
    return findings, report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis trace",
        description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="full representative x mode grid + the DSE border "
                         "sweep (nightly); default is the quick CI arm set")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here (artifact-friendly)")
    args = ap.parse_args(argv)

    findings, report = run_trace_contracts(quick=not args.full)
    for f in findings:
        print(f.render())
    if args.out:
        with open(args.out + ".tmp", "w") as fh:
            json.dump(report, fh, indent=1)
        import os
        os.replace(args.out + ".tmp", args.out)
        print(f"report: {args.out}")
    n_arms = len(report["arms"])
    print(f"trace-contract: {n_arms} arm(s), "
          f"{len(report['saturation']['schedules'])} schedule(s) in the "
          f"saturation proof, {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""Public AMR-matmul op: float matmul under AMR-MUL numerics via Pallas.

Dispatches between the two kernel variants (kernel.py):

  * ``method="lowrank"`` — rank-r SVD factors of the error table, single
    augmented MXU dot per block; per-product error <= sigma_{r+1} of the
    error table's spectrum (core/lut.py documents the bound);
  * ``method="lut"``     — full 256x256 int32 table gather, bit-exact AMR
    products with int32 accumulation.

Both source their constants from ``core/lut.py``'s cached accessors — the
factors/table for a ``(border, rank, engine)`` point are built once per
process by the fused multi-border engine and converted to jnp once
(``lut.factor_arrays`` / ``lut.table_array``); no call site rebuilds them.

Tiling (``bm/bn/bk=None``) and execution mode (``interpret=None``) resolve
in THIS non-jitted wrapper — tiles from the shared backend-keyed autotune
table clamped to shape divisors, interpret from the backend autodetect
with the ``REPRO_PALLAS_INTERPRET`` env override — then the jitted inner
function is keyed on the concrete values.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.kernels.pallas_config import resolve_interpret
from repro.numerics.quant import quantize_int8

from .kernel import (_amr_matmul_int8_jit, _amr_matmul_int8_lut_grouped_jit,
                     _amr_matmul_int8_lut_jit)
from .tiling import pick_tiles


def lut_factors(
    border: int | None, rank: int, engine: str = "jax"
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Cached low-rank error factors for the kernel (u, v) as jnp arrays.

    Thin alias for ``core.lut.factor_arrays`` — the single process-level
    cache behind every kernel/numerics call site (the source 256x256 table
    comes from the fused multi-border engine build, provenance recorded on
    the underlying LowRankFactors)."""
    return lut_lib.factor_arrays(border, rank, engine)


@partial(jax.jit, static_argnames=("border", "rank", "method", "bm", "bn", "bk",
                                   "interpret"))
def _amr_matmul_jit(a, b, *, border, rank, method, bm, bn, bk, interpret):
    qa, sa = quantize_int8(a, axis=-1)
    qb, sb = quantize_int8(b, axis=0)
    if method == "lut":
        table = lut_lib.table_array(border)
        out = _amr_matmul_int8_lut_jit(qa, qb, table, bm=bm, bn=bn, bk=bk,
                                       interpret=interpret).astype(jnp.float32)
    elif method == "lowrank":
        u, v = lut_factors(border, rank)
        out = _amr_matmul_int8_jit(qa, qb, u, v, bm=bm, bn=bn, bk=bk,
                                   interpret=interpret)
    else:
        raise ValueError(f"method must be 'lowrank' or 'lut', got {method!r}")
    return out * sa * sb


def amr_matmul(a: jnp.ndarray, b: jnp.ndarray, *, border: int | None = 8,
               rank: int = 8, method: str = "lowrank",
               bm: int | None = None, bn: int | None = None, bk: int | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Float (M,K) @ (K,N) with AMR-MUL product semantics
    (quantize -> kernel variant -> rescale)."""
    if method not in ("lowrank", "lut"):
        raise ValueError(f"method must be 'lowrank' or 'lut', got {method!r}")
    tiles = pick_tiles(a.shape[0], b.shape[1], a.shape[1],
                       variant=method, bm=bm, bn=bn, bk=bk)
    return _amr_matmul_jit(a, b, border=border, rank=rank, method=method,
                           bm=tiles.bm, bn=tiles.bn, bk=tiles.bk,
                           interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("border", "bm", "bn", "bk", "interpret"))
def _amr_matmul_grouped_jit(a, b, *, border, bm, bn, bk, interpret):
    qa, sa = quantize_int8(a, axis=-1)               # per-row scale (G, M, 1)
    qb, sb = quantize_int8(b, axis=-2)               # per-col scale (G, 1, N)
    table = lut_lib.table_array(border)
    out = _amr_matmul_int8_lut_grouped_jit(qa, qb, table, bm=bm, bn=bn, bk=bk,
                                           interpret=interpret)
    return out.astype(jnp.float32) * sa * sb


def amr_matmul_grouped(a: jnp.ndarray, b: jnp.ndarray, *,
                       border: int | None = 8,
                       bm: int | None = None, bn: int | None = None,
                       bk: int | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Grouped float (G, M, K) @ (G, K, N) under bit-exact full-LUT AMR
    numerics — the activation×activation kernel form (MoE expert capacity
    buffers, attention score/value contractions after the batch·head
    leading dims are flattened to one group axis).

    Quantization follows the seam convention (per-row of A, per-column of
    B), so the output is bit-identical to stacking per-group
    ``amr_matmul(..., method="lut")`` calls.  Tiles come from the shared
    autotune table (variant ``lut_grouped``) clamped to shape divisors.
    """
    if a.ndim != 3 or b.ndim != 3 or a.shape[0] != b.shape[0]:
        raise ValueError(
            f"amr_matmul_grouped takes (G, M, K) @ (G, K, N) with matching "
            f"group counts, got {a.shape} @ {b.shape}")
    tiles = pick_tiles(a.shape[1], b.shape[2], a.shape[2],
                       variant="lut_grouped", bm=bm, bn=bn, bk=bk)
    return _amr_matmul_grouped_jit(a, b, border=border, bm=tiles.bm,
                                   bn=tiles.bn, bk=tiles.bk,
                                   interpret=resolve_interpret(interpret))

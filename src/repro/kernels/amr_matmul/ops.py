"""jit'd public wrapper: float matmul under AMR-MUL numerics via the kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.numerics.quant import quantize_int8

from .kernel import amr_matmul_int8


def lut_factors(
    border: int, rank: int, engine: str = "jax"
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Low-rank error factors for the kernel; the source 256x256 table is
    built by the compiled schedule engine (``engine="jax"``, bit-exact vs the
    numpy host replay — provenance recorded on the LowRankFactors)."""
    f = lut_lib.lowrank_factor(border, rank, engine=engine)
    return jnp.asarray(f.u), jnp.asarray(f.v)


@partial(jax.jit, static_argnames=("border", "rank", "bm", "bn", "bk", "interpret"))
def amr_matmul(a: jnp.ndarray, b: jnp.ndarray, *, border: int = 8, rank: int = 8,
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = True) -> jnp.ndarray:
    """Float (M,K) @ (K,N) with AMR-MUL product semantics (quantize->kernel->rescale)."""
    u, v = lut_factors(border, rank)
    qa, sa = quantize_int8(a, axis=-1)
    qb, sb = quantize_int8(b, axis=0)
    out = amr_matmul_int8(qa, qb, u, v, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out * sa * sb

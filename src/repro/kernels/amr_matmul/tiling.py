"""Shared tiling policy for the amr_matmul kernel variants.

One autotune table keyed on ``(backend, variant)`` serves both the
low-rank MXU kernel and the full-table LUT-gather kernel; callers pass
``bm/bn/bk=None`` to take the table entry, clamped down to divisors of the
actual problem shape so ``pallas_call`` grids always tile exactly.

Entries encode where each variant is bound:

  * ``lowrank`` is MXU-bound — big square 128-multiple tiles keep the
    (bm, bk*(1+r)) x (bk*(1+r), bn) dot on the systolic array;
  * ``lut`` is VPU/gather-bound and walks K sequentially inside the block,
    so K tiles shrink on real accelerators to bound the per-step gather
    footprint while M/N stay MXU-tile aligned for the output block.
"""
from __future__ import annotations

import dataclasses

from repro.kernels.pallas_config import backend_kind


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bk: int


# (backend, variant) -> preferred tiles; clamped to shape divisors at pick
# time. The gpu rows size VMEM-equivalent footprints for a future Triton
# variant — today GPU runs the interpreter (pallas_config) so they only
# shape the grid.
AUTOTUNE: dict[tuple[str, str], TileConfig] = {
    ("tpu", "lowrank"): TileConfig(128, 128, 128),
    ("tpu", "lut"): TileConfig(128, 128, 32),
    ("gpu", "lowrank"): TileConfig(64, 128, 64),
    ("gpu", "lut"): TileConfig(64, 128, 32),
    ("cpu", "lowrank"): TileConfig(128, 128, 128),
    ("cpu", "lut"): TileConfig(128, 128, 128),
}

VARIANTS = ("lowrank", "lut")


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def pick_tiles(
    m: int, n: int, k: int, *, variant: str = "lowrank", backend: str | None = None,
    bm: int | None = None, bn: int | None = None, bk: int | None = None,
) -> TileConfig:
    """Resolve block sizes: explicit overrides win, else the autotune entry
    for the (detected) backend, each clamped to the largest divisor of its
    dimension so the grid covers the problem exactly."""
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}, got {variant!r}")
    pref = AUTOTUNE[(backend or backend_kind(), variant)]
    return TileConfig(
        bm=bm if bm is not None else _largest_divisor_leq(m, pref.bm),
        bn=bn if bn is not None else _largest_divisor_leq(n, pref.bn),
        bk=bk if bk is not None else _largest_divisor_leq(k, pref.bk),
    )

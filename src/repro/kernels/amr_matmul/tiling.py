"""Shared tiling policy for the amr_matmul kernel variants.

One autotune table keyed on ``(backend, variant)`` serves both the
low-rank MXU kernel and the full-table LUT-gather kernel; callers pass
``bm/bn/bk=None`` to take the table entry, clamped down to divisors of the
actual problem shape so ``pallas_call`` grids always tile exactly.

Entries encode where each variant is bound:

  * ``lowrank`` is MXU-bound — big square 128-multiple tiles keep the
    (bm, bk*(1+r)) x (bk*(1+r), bn) dot on the systolic array;
  * ``lut`` is VPU/gather-bound and walks K sequentially inside the block,
    so K tiles shrink on real accelerators to bound the per-step gather
    footprint while M/N stay MXU-tile aligned for the output block;
  * ``inject_replay`` (kernels/inject_replay) holds the whole bit-sliced
    wire state of a block in VMEM — ~n_wires uint32 words per (m, k) pair
    per 32 output columns — so its M/K tiles are much smaller than the
    LUT variants'; its n dimension is blocked in 32-column lane words, so
    preferred ``bn`` entries are multiples of 32 (the op wrapper clamps
    autotuned tiles to word-aligned divisors).

Explicit ``bm/bn/bk`` overrides win over the table but must divide the
problem shape exactly — a non-divisor would leave a partial tile the
grids of these kernels never visit, so ``pick_tiles`` rejects it.
"""
from __future__ import annotations

import dataclasses

from repro.kernels.pallas_config import backend_kind


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bk: int


# (backend, variant) -> preferred tiles; clamped to shape divisors at pick
# time. The gpu rows size VMEM-equivalent footprints for a future Triton
# variant — today GPU runs the interpreter (pallas_config) so they only
# shape the grid.
AUTOTUNE: dict[tuple[str, str], TileConfig] = {
    ("tpu", "lowrank"): TileConfig(128, 128, 128),
    ("tpu", "lut"): TileConfig(128, 128, 32),
    ("tpu", "lut_grouped"): TileConfig(128, 128, 32),
    ("tpu", "inject_replay"): TileConfig(32, 128, 8),
    ("gpu", "lowrank"): TileConfig(64, 128, 64),
    ("gpu", "lut"): TileConfig(64, 128, 32),
    ("gpu", "lut_grouped"): TileConfig(64, 128, 32),
    ("gpu", "inject_replay"): TileConfig(32, 128, 8),
    ("cpu", "lowrank"): TileConfig(128, 128, 128),
    ("cpu", "lut"): TileConfig(128, 128, 128),
    ("cpu", "lut_grouped"): TileConfig(128, 128, 128),
    ("cpu", "inject_replay"): TileConfig(64, 256, 16),
}

VARIANTS = ("lowrank", "lut", "lut_grouped", "inject_replay")

# Fused-attention query-row tiles (kernels/attn_fused), keyed on the
# backend and a HEAD-DIM BUCKET: the kernel holds a whole (bm, T) score
# block plus the (T, D)/(T, P) operand panels in VMEM — larger head dims
# mean proportionally larger panels, so the preferred query tile shrinks
# as head_dim grows.  T/D/P are never tiled (full-T masked softmax).
ATTN_AUTOTUNE: dict[tuple[str, int], int] = {
    ("tpu", 64): 256, ("tpu", 128): 128, ("tpu", 256): 64,
    ("gpu", 64): 128, ("gpu", 128): 64, ("gpu", 256): 32,
    ("cpu", 64): 128, ("cpu", 128): 128, ("cpu", 256): 64,
}


def head_dim_bucket(head_dim: int) -> int:
    """Bucket a head dim to the next power of two in [64, 256] — the key
    granularity of ``ATTN_AUTOTUNE`` (sub-64 head dims share the 64 row)."""
    return min(max(64, 1 << max(head_dim - 1, 1).bit_length()), 256)


def pick_attn_tile(m: int, head_dim: int, *, backend: str | None = None,
                   bm: int | None = None) -> int:
    """Query-row tile for the fused-attention kernel: explicit ``bm`` wins
    (validated as a divisor of the row count), else the head-dim-bucketed
    autotune preference clamped to the largest divisor of ``m``."""
    pref = ATTN_AUTOTUNE[(backend or backend_kind(), head_dim_bucket(head_dim))]
    return _resolve_dim("bm", "m", m, bm, pref)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _resolve_dim(name: str, dim_name: str, size: int, override: int | None,
                 pref: int) -> int:
    if override is None:
        return _largest_divisor_leq(size, pref)
    if override < 1 or size % override:
        raise ValueError(
            f"{name}={override} does not tile the problem: {dim_name}={size} "
            f"is not a multiple (the grid would miss a partial tile); pass "
            f"None to take the autotune entry clamped to a divisor")
    return override


def pick_tiles(
    m: int, n: int, k: int, *, variant: str = "lowrank", backend: str | None = None,
    bm: int | None = None, bn: int | None = None, bk: int | None = None,
) -> TileConfig:
    """Resolve block sizes: explicit overrides win (validated to divide the
    problem shape exactly), else the autotune entry for the (detected)
    backend, clamped to the largest divisor of its dimension so the grid
    covers the problem exactly."""
    if variant not in VARIANTS:
        raise ValueError(f"variant must be one of {VARIANTS}, got {variant!r}")
    pref = AUTOTUNE[(backend or backend_kind(), variant)]
    return TileConfig(
        bm=_resolve_dim("bm", "m", m, bm, pref.bm),
        bn=_resolve_dim("bn", "n", n, bn, pref.bn),
        bk=_resolve_dim("bk", "k", k, bk, pref.bk),
    )

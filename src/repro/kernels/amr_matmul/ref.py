"""Pure-jnp/numpy oracles for the AMR matmul kernel variants.

``ref_lowrank_int8`` mirrors the low-rank kernel's math densely
(A@B + U[A]@V[B] einsum contraction) — agreement with the kernel is to
f32 accumulation order.  ``ref_bitexact_int8`` is the ground truth for
BOTH the full-LUT kernel (which must match it bit-for-bit, int64 exact)
and the rank-256 low-rank kernel (which matches to fp32 rounding): it
accumulates per-element products straight from the engine-built 256x256
table, i.e. it *is* the schedule engine's exact replay lifted to a matmul.
The gap between a rank-r kernel and this oracle is bounded by
K * sigma_{r+1} per element (core/lut.py)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib


def ref_lowrank_int8(a: jnp.ndarray, b: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray):
    """Same math as the kernel, dense jnp: A@B + U[A]@V[B] contraction."""
    fa = a.astype(jnp.float32)
    fb = b.astype(jnp.float32)
    ua = u[a.astype(jnp.int32) + 128]          # (M, K, r)
    vb = v[b.astype(jnp.int32) + 128]          # (K, N, r)
    return fa @ fb + jnp.einsum("mkr,knr->mn", ua, vb)


def ref_bitexact_int8(a: np.ndarray, b: np.ndarray, border: int) -> np.ndarray:
    """Ground truth: per-element products from the bit-accurate LUT."""
    table = lut_lib.build_int8_lut(border).astype(np.int64)
    M, K = a.shape
    N = b.shape[1]
    out = np.zeros((M, N), np.int64)
    for k in range(K):
        out += table[np.asarray(a[:, k], np.int64) + 128][:, np.asarray(b[k], np.int64) + 128]
    return out

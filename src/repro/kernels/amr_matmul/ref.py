"""Pure-jnp oracles for the AMR matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib


def ref_lowrank_int8(a: jnp.ndarray, b: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray):
    """Same math as the kernel, dense jnp: A@B + U[A]@V[B] contraction."""
    fa = a.astype(jnp.float32)
    fb = b.astype(jnp.float32)
    ua = u[a.astype(jnp.int32) + 128]          # (M, K, r)
    vb = v[b.astype(jnp.int32) + 128]          # (K, N, r)
    return fa @ fb + jnp.einsum("mkr,knr->mn", ua, vb)


def ref_bitexact_int8(a: np.ndarray, b: np.ndarray, border: int) -> np.ndarray:
    """Ground truth: per-element products from the bit-accurate LUT."""
    table = lut_lib.build_int8_lut(border).astype(np.int64)
    M, K = a.shape
    N = b.shape[1]
    out = np.zeros((M, N), np.int64)
    for k in range(K):
        out += table[np.asarray(a[:, k], np.int64) + 128][:, np.asarray(b[k], np.int64) + 128]
    return out

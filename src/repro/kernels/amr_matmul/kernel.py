"""Pallas TPU kernel: AMR-MUL approximate matmul in low-rank MXU form.

The paper's multiplier, as deployed on TPU (DESIGN.md §2 L2): for int8
operands the approximate product is exactly ``a*b + E(a,b)`` with E the
256x256 error table of the bit-accurate 2-digit AMR-MUL. E factors as
``E ~= U V^T`` (SVD, rank r), so a block matmul becomes

    acc += concat([A_f32, U[A+128]]) @ concat([B_f32, V[B+128]])

— ONE (bm, bk*(1+r)) x (bk*(1+r), bn) MXU dot per block instead of per-
element gather emulation on the VPU. U/V live whole in VMEM (256*r*4B).

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulator
scratch carries across the K sweep; block dims multiples of the MXU tile
(128) on M/N and of the int8 lane pack on K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _amr_matmul_kernel(a_ref, b_ref, u_ref, v_ref, out_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output block; K swept by the innermost grid dim."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                                  # (bm, bk) int8
    b = b_ref[...]                                  # (bk, bn) int8
    u = u_ref[...]                                  # (256, r) f32
    v = v_ref[...]                                  # (256, r) f32
    bm, bk = a.shape
    bn = b.shape[1]
    r = u.shape[1]

    ia = (a.astype(jnp.int32) + 128)
    ib = (b.astype(jnp.int32) + 128)
    ua = jnp.take(u, ia.reshape(-1), axis=0).reshape(bm, bk, r)
    vb = jnp.take(v, ib.reshape(-1), axis=0).reshape(bk, bn, r)

    # augmented operands: exact lane + r error lanes -> single MXU dot.
    # lane order along the contraction axis is (k, [exact, err_1..err_r])
    # on BOTH sides: A flattens (bm, bk, 1+r) -> (bm, bk*(1+r)); B must put
    # the lane axis BEFORE bn: (bk, 1+r, bn) -> (bk*(1+r), bn).
    a_aug = jnp.concatenate(
        [a.astype(jnp.float32)[:, :, None], ua], axis=2).reshape(bm, bk * (1 + r))
    b_aug = jnp.concatenate(
        [b.astype(jnp.float32)[:, None, :], vb.transpose(0, 2, 1)],
        axis=1).reshape(bk * (1 + r), bn)
    acc_ref[...] += jnp.dot(a_aug, b_aug, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def amr_matmul_int8(a: jnp.ndarray, b: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray,
                    *, bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """a (M,K) int8, b (K,N) int8, u/v (256,r) f32 -> (M,N) f32 approx products."""
    M, K = a.shape
    N = b.shape[1]
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_amr_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(u.shape, lambda i, j, k: (0, 0)),  # whole LUT in VMEM
            pl.BlockSpec(v.shape, lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, u, v)

"""Pallas TPU kernels: AMR-MUL approximate matmul, low-rank and full-LUT forms.

The paper's multiplier, as deployed on TPU (DESIGN.md §2 L2): for int8
operands the approximate product is exactly ``a*b + E(a,b)`` with E the
256x256 error table of the bit-accurate 2-digit AMR-MUL.  Two kernel
variants trade fidelity against the unit they load:

**Low-rank (MXU)** — E factors as ``E ~= U V^T`` (SVD, rank r), so a block
matmul becomes

    acc += concat([A_f32, U[A+128]]) @ concat([B_f32, V[B+128]])

— ONE (bm, bk*(1+r)) x (bk*(1+r), bn) MXU dot per block instead of per-
element gather emulation on the VPU. U/V live whole in VMEM (256*r*4B).
Per-product error vs the full table is bounded by the first dropped
singular value ``sigma_{r+1}`` (see core/lut.py), i.e. <= K*sigma_{r+1}
per output element.

**Full-LUT (gather)** — the whole 256x256 int32 product table lives in
VMEM (256KB) and each K step gathers the (bm, bn) outer-product block
``LUT[a_k + 128, b_k + 128]`` from the flattened table, accumulating in
int32.  Bit-exact by construction (zero error vs the schedule engine's
replay — asserted in tests/test_kernels.py), VPU/gather-bound, so the
shared tiling table (tiling.py) gives it narrower K tiles on accelerators.

Tiling (both variants): grid (M/bm, N/bn, K/bk), K innermost so the
accumulator scratch carries across the K sweep; block dims come from the
shared ``tiling.AUTOTUNE`` table keyed on backend, clamped to divisors.

``interpret=None`` (default) autodetects per backend — compiled Mosaic on
real TPU, interpreter mode on CPU and GPU (the kernels use pltpu memory
spaces the Triton lowering lacks) — overridable via the
``REPRO_PALLAS_INTERPRET`` env var (see kernels/pallas_config.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_config import resolve_interpret


def _amr_matmul_kernel(a_ref, b_ref, u_ref, v_ref, out_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output block; K swept by the innermost grid dim."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                                  # (bm, bk) int8
    b = b_ref[...]                                  # (bk, bn) int8
    u = u_ref[...]                                  # (256, r) f32
    v = v_ref[...]                                  # (256, r) f32
    bm, bk = a.shape
    bn = b.shape[1]
    r = u.shape[1]

    ia = (a.astype(jnp.int32) + 128)
    ib = (b.astype(jnp.int32) + 128)
    ua = jnp.take(u, ia.reshape(-1), axis=0).reshape(bm, bk, r)
    vb = jnp.take(v, ib.reshape(-1), axis=0).reshape(bk, bn, r)

    # augmented operands: exact lane + r error lanes -> single MXU dot.
    # lane order along the contraction axis is (k, [exact, err_1..err_r])
    # on BOTH sides: A flattens (bm, bk, 1+r) -> (bm, bk*(1+r)); B must put
    # the lane axis BEFORE bn: (bk, 1+r, bn) -> (bk*(1+r), bn).
    a_aug = jnp.concatenate(
        [a.astype(jnp.float32)[:, :, None], ua], axis=2).reshape(bm, bk * (1 + r))
    b_aug = jnp.concatenate(
        [b.astype(jnp.float32)[:, None, :], vb.transpose(0, 2, 1)],
        axis=1).reshape(bk * (1 + r), bn)
    acc_ref[...] += jnp.dot(a_aug, b_aug, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _amr_matmul_int8_jit(a, b, u, v, *, bm, bn, bk, interpret):
    M, K = a.shape
    N = b.shape[1]
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_amr_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(u.shape, lambda i, j, k: (0, 0)),  # whole factors in VMEM
            pl.BlockSpec(v.shape, lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, u, v)


def amr_matmul_int8(a: jnp.ndarray, b: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray,
                    *, bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """a (M,K) int8, b (K,N) int8, u/v (256,r) f32 -> (M,N) f32 approx products.

    ``interpret=None`` resolves via pallas_config (env override / backend
    autodetect) BEFORE the jitted inner function, so the jit cache is always
    keyed on a concrete bool."""
    return _amr_matmul_int8_jit(a, b, u, v, bm=bm, bn=bn, bk=bk,
                                interpret=resolve_interpret(interpret))


def _lut_gather_accum(a, b, flat, acc):
    """acc + sum_k LUT[a_k, b_k] outer products — the shared gather sweep
    of the full-table variants (flat, grouped, and fused-attention)."""
    bm, bk = a.shape
    bn = b.shape[1]
    ia = a.astype(jnp.int32) + 128
    ib = b.astype(jnp.int32) + 128

    def body(k, acc):
        # flat index LUT[a_k, b_k] = flat[a_k * 256 + b_k], outer-product shaped
        iak = jax.lax.dynamic_index_in_dim(ia, k, axis=1, keepdims=True)   # (bm, 1)
        ibk = jax.lax.dynamic_index_in_dim(ib, k, axis=0, keepdims=True)   # (1, bn)
        idx = iak * 256 + ibk                                              # (bm, bn)
        return acc + jnp.take(flat, idx.reshape(-1), axis=0).reshape(bm, bn)

    return jax.lax.fori_loop(0, bk, body, acc)


def _amr_matmul_lut_kernel(a_ref, b_ref, lut_ref, out_ref, acc_ref, *, n_k: int):
    """Full-table variant: per-K-step (bm, bn) gather from the flat LUT."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    flat = lut_ref[...].reshape(-1)                 # (65536,) int32
    acc_ref[...] = _lut_gather_accum(a_ref[...], b_ref[...], flat, acc_ref[...])

    @pl.when(k_idx == n_k - 1)
    def _store():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _amr_matmul_int8_lut_jit(a, b, table, *, bm, bn, bk, interpret):
    M, K = a.shape
    N = b.shape[1]
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_amr_matmul_lut_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(table.shape, lambda i, j, k: (0, 0)),  # whole LUT: 256KB VMEM
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, b, table)


def _amr_matmul_lut_grouped_kernel(a_ref, b_ref, lut_ref, out_ref, acc_ref,
                                   *, n_k: int):
    """Grouped full-LUT variant: independent (M, K) @ (K, N) per group.

    Grid ``(G, M/bm, N/bn, K/bk)`` — one leading grid axis per group (the
    MoE expert buffers / flattened attention batch·head groups), K still
    innermost so the int32 accumulator scratch carries across the K sweep.
    """
    k_idx = pl.program_id(3)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    flat = lut_ref[...].reshape(-1)                 # (65536,) int32
    acc_ref[...] = _lut_gather_accum(a_ref[0], b_ref[0], flat, acc_ref[...])

    @pl.when(k_idx == n_k - 1)
    def _store():
        out_ref[0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _amr_matmul_int8_lut_grouped_jit(a, b, table, *, bm, bn, bk, interpret):
    G, M, K = a.shape
    N = b.shape[2]
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (G, M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (G, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_amr_matmul_lut_grouped_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
            pl.BlockSpec(table.shape, lambda g, i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, b, table)


def amr_matmul_int8_lut(a: jnp.ndarray, b: jnp.ndarray, table: jnp.ndarray,
                        *, bm: int = 128, bn: int = 128, bk: int = 128,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Bit-exact variant: a (M,K) int8, b (K,N) int8, table (256,256) int32
    -> (M,N) int32 — int32 accumulation of true AMR products (exact for
    K * 2^16 < 2^31, i.e. any realistic K)."""
    return _amr_matmul_int8_lut_jit(a, b, table, bm=bm, bn=bn, bk=bk,
                                    interpret=resolve_interpret(interpret))

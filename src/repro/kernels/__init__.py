"""Pallas TPU kernels for the perf-critical compute layers.

  amr_matmul — the paper's approximate multiplier as an MXU matmul kernel
               (low-rank error-LUT factorization; DESIGN.md §2 L2).
  ssd_scan   — Mamba2 SSD chunked scan (intra-chunk dual form + carried
               state), the hot loop of the ssm/hybrid architectures.

Each kernel ships ops.py (jit wrapper) and ref.py (pure-jnp oracle);
tests sweep shapes/dtypes and assert allclose under interpret=True.
"""

"""Pallas kernels for the perf-critical compute layers.

  amr_matmul — the paper's approximate multiplier as a matmul kernel, in
               two variants: low-rank error-LUT factorization on the MXU
               (DESIGN.md §2 L2) and a bit-exact full-table LUT-gather
               form; shared backend-keyed tiling table (amr_matmul/tiling).
  ssd_scan   — Mamba2 SSD chunked scan (intra-chunk dual form + carried
               state), the hot loop of the ssm/hybrid architectures.

Execution mode is backend-autodetected (``interpret=None`` -> compiled
Mosaic on real TPU, interpreter mode on CPU/GPU) with a global
``REPRO_PALLAS_INTERPRET`` env override — see pallas_config.py and
docs/kernels.md.  Each kernel ships ops.py (jit wrapper) and ref.py
(pure-jnp oracle); tests sweep shapes/dtypes vs the oracles on CPU and
assert the full-LUT variant bit-exact vs the schedule engine's replay.
"""

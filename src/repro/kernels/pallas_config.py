"""Pallas execution-mode policy: backend autodetection + env override.

Kernels default to ``interpret=None`` and resolve it here at trace time:

  * ``REPRO_PALLAS_INTERPRET`` set to ``1/true/yes/on`` forces interpreter
    mode everywhere (debugging on real hardware), ``0/false/no/off`` forces
    compiled Mosaic/Triton lowering (e.g. to verify a CPU CI job fails fast
    rather than silently interpreting), ``auto``/unset defers to detection;
  * detection: compiled kernels on real TPU backends only. CPU has no
    compiled Pallas lowering, and the amr_matmul kernels use TPU memory
    spaces (``pltpu.VMEM`` scratch) that the Triton/GPU lowering does not
    support — so both fall back to interpreter mode until a Triton variant
    of the kernels lands.

``resolve_interpret`` is called by the NON-jitted public wrappers (see
kernels/amr_matmul/ops.py) so the env var is re-read on every call and a
changed override never collides with a stale jit cache entry keyed on
``interpret=None``.

The ``amr_inject`` numerics mode carries its own variant policy on top:
``AMRNumerics.inject_impl=None`` autodetects between the XLA outer-product
replay (``numerics/injection.py``) and the Pallas injection-replay kernel
(``kernels/inject_replay``) — Pallas only where it compiles (real TPU;
everywhere else the interpreter would be strictly slower than XLA), with
the ``REPRO_INJECT_IMPL`` env var (``xla``/``pallas``/``auto``) overriding
detection.  ``resolve_inject_impl`` runs at trace time (the inject matmul
only exists inside jitted steps), so a changed env var takes effect on the
next trace, not mid-executable.
"""
from __future__ import annotations

import os

ENV_VAR = "REPRO_PALLAS_INTERPRET"
INJECT_IMPL_ENV = "REPRO_INJECT_IMPL"
INJECT_IMPLS = ("xla", "pallas")
_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def backend_kind() -> str:
    """Coarse platform for the tiling/interpret tables: 'tpu'|'gpu'|'cpu'."""
    import jax

    plat = jax.default_backend()
    if plat in ("gpu", "cuda", "rocm"):
        return "gpu"
    return plat if plat == "tpu" else "cpu"


def default_interpret() -> bool:
    """Env override if set, else compiled only where the kernels can lower
    (TPU); CPU and GPU run the interpreter (see module docstring)."""
    raw = os.environ.get(ENV_VAR, "").strip().lower()
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    if raw and raw != "auto":
        raise ValueError(
            f"{ENV_VAR}={raw!r}: expected one of {_TRUE + _FALSE} or 'auto'")
    return backend_kind() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """None -> autodetected/env-overridden mode; explicit bool wins."""
    return default_interpret() if interpret is None else interpret


def default_inject_impl() -> str:
    """Env override if set, else the Pallas replay kernel only where it
    compiles (TPU); XLA elsewhere — interpreter-mode Pallas would be
    strictly slower than the XLA outer-product replay it mirrors.

    The TPU default rides on the same caveat as the other kernel variants
    (ROADMAP: compiled lowerings still need a real-TPU validation run);
    ``REPRO_INJECT_IMPL=xla`` pins the known-good XLA replay meanwhile —
    both implementations are bit-identical wherever they run."""
    raw = os.environ.get(INJECT_IMPL_ENV, "").strip().lower()
    if raw in INJECT_IMPLS:
        return raw
    if raw and raw != "auto":
        raise ValueError(
            f"{INJECT_IMPL_ENV}={raw!r}: expected one of {INJECT_IMPLS} or 'auto'")
    return "pallas" if backend_kind() == "tpu" else "xla"


def resolve_inject_impl(impl: str | None) -> str:
    """None -> autodetected/env-overridden impl; an explicit impl wins."""
    if impl is None:
        return default_inject_impl()
    if impl not in INJECT_IMPLS:
        raise ValueError(
            f"inject_impl must be one of {INJECT_IMPLS} (or None = auto), "
            f"got {impl!r}")
    return impl

"""Pallas injection-replay kernel: the bit-sliced AMR replay as a matmul.

Third ``amr_matmul`` kernel variant (beside ``lowrank``/``lut``): instead
of gathering pre-built LUT entries, each grid block replays the reduction
circuit itself on lane-packed operand words held in VMEM, with the
schedule's per-stage minterm masks and wire routing baked into the kernel
as constants.  Bit-identical to the ``amr_lut`` oracle and to the XLA
injection path (tests/test_inject_replay.py); selected per numerics policy
via ``AMRNumerics(inject_impl="pallas")`` — see docs/kernels.md.
"""
from .ops import inject_replay_matmul

__all__ = ["inject_replay_matmul"]

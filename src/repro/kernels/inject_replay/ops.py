"""Public injection-replay op: exact AMR integer matmul via the Pallas kernel.

``inject_replay_matmul`` mirrors ``numerics.injection.injected_matmul_int``
(the XLA form of the same outer-product replay) — identical contract,
bit-identical int32 output — but runs the stage loop inside a Pallas
kernel whose tiles come from the shared autotune table
(``amr_matmul/tiling.py``, variant ``inject_replay``).  Dispatch between
the two lives in ``numerics.approx_matmul.matmul_amr_inject`` via the
``AMRNumerics.inject_impl`` policy field, resolved by
``kernels/pallas_config.resolve_inject_impl`` (compiled Pallas on real
TPU, XLA elsewhere, ``REPRO_INJECT_IMPL`` overrides).

The n dimension is blocked in WORD units: 32 output columns share one
uint32 lane word, so an explicit ``bn`` override must be a multiple of 32
(as well as dividing the padded column count) — the autotune path clamps
to word-aligned divisors automatically.
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import _LANE_BITS, CompiledInjector
from repro.kernels.amr_matmul.tiling import _largest_divisor_leq, pick_tiles
from repro.kernels.pallas_config import resolve_interpret

from .kernel import _inject_replay_jit


def inject_replay_matmul(inj: CompiledInjector, ia, ib, *,
                         bm: int | None = None, bn: int | None = None,
                         bk: int | None = None,
                         interpret: bool | None = None,
                         packed_ib=None, schedule: str | None = None):
    """Exact integer AMR matmul on the Pallas replay kernel.

    ``ia``: (..., M, K) and ``ib``: (K, N) int32 operand indices
    (value + 128) -> (..., M, N) int32, bit-identical to
    ``injection.injected_matmul_int`` and the ``amr_lut`` gather oracle.
    Weight packing goes through the shared ``packed_weights`` cache (packed
    once per matmul in-trace; cached across calls for concrete weights) —
    or is bypassed entirely by a precomputed ``packed_ib``.  Raises at
    trace time when K could saturate the int32 accumulator.
    """
    from repro.numerics.injection import (check_accumulation_bound,
                                          packed_weights)

    *lead, m, k = ia.shape
    n = ib.shape[-1]
    check_accumulation_bound(inj, k, schedule=schedule)
    if bn is not None and bn % _LANE_BITS:
        # word-alignment first: clearer than pick_tiles' divisor error
        # against the padded width for a bn that divides the user's N
        raise ValueError(
            f"inject_replay blocks n in 32-column lane words: bn={bn} must "
            f"be a multiple of {_LANE_BITS} (and divide N={n} padded up to "
            f"whole words)")
    rows = int(np.prod(lead, dtype=np.int64)) * m if lead else m
    yw = packed_ib if packed_ib is not None else packed_weights(inj, ib)
    n_words = yw.shape[-1]
    npad = n_words * _LANE_BITS
    # note: bm tiles the FLATTENED row count (lead batch dims * M), bn the
    # padded column count — pick_tiles errors report those quantities
    tiles = pick_tiles(rows, npad, k, variant="inject_replay",
                       bm=bm, bn=bn, bk=bk)
    if bn is not None:
        bnw = bn // _LANE_BITS
    else:  # word-align the autotuned tile: largest word-count divisor
        bnw = _largest_divisor_leq(n_words, max(1, tiles.bn // _LANE_BITS))
    out = _inject_replay_jit(ia.reshape(rows, k), yw, inj._value_masks,
                             lowered=inj.lowered, bm=tiles.bm, bnw=bnw,
                             bk=tiles.bk, interpret=resolve_interpret(interpret))
    return out[:, :n].reshape(*lead, m, n)

"""Pallas TPU kernel: bit-sliced AMR injection replay, matmul-shaped.

The engine's on-device injection path (``engine.CompiledInjector``) proves
that ANY ``reduction.Schedule`` — including raw DSE candidates with no
materialized 256x256 LUT — can run inside a jitted training step.  This
kernel is its production form: one grid block evaluates the exact AMR
products of a ``(bm, bn)`` output tile by replaying the reduction circuit
directly on lane-packed operand words in VMEM.

Data layout (the outer-product form of the bit-sliced replay):

  * the **weight** side arrives pre-packed (``CompiledInjector.
    pack_weights``): 32 output columns per uint32 word, one word row per
    stored operand bit — ``(bk, n_opbits, bnw)`` words per block live in
    VMEM and are re-used by every activation row of the tile;
  * the **activation** side is gathered per block from a 256-entry
    value->mask table (stored bit -> 0 or 0xFFFFFFFF): a full-word mask
    broadcasts one activation operand against all 32 columns of a word, so
    no per-pair lane packing ever happens;
  * the schedule's lowering (``engine.LoweredReplay``) — PP gate minterm
    masks, per-stage cell truth-table masks, wire routing, final-bit
    weights — rides along as whole-block VMEM constant inputs (a few KB;
    Pallas does not allow captured array constants), sliced per stage at
    static offsets baked into the kernel closure.  A kernel is therefore
    specialized to one schedule, exactly like the LUT kernel is
    specialized to one table;
  * per-pair products combine 16-bit limbs in int32 and accumulate across
    the K grid sweep in an int32 VMEM scratch — bit-identical to the
    ``amr_lut`` gather oracle (zero error, asserted in
    tests/test_inject_replay.py).

Grid: ``(M/bm, n_words/bnw, K/bk)`` with K innermost so the accumulator
scratch carries across the K sweep; the n dimension is blocked in WORD
units (32 columns).  Tiles come from the shared autotune table
(``amr_matmul/tiling.py``, variant ``inject_replay``); ``interpret=None``
resolves per backend exactly like the other variants (compiled Mosaic on
real TPU, interpreter on CPU/GPU — ``kernels/pallas_config.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.engine import _LANE_BITS, LoweredReplay


@functools.lru_cache(maxsize=32)  # keyed on lowering identity (see engine)
def _replay_inputs(lowered: LoweredReplay):
    """The lowering as flat const arrays (Pallas inputs) + static metadata.

    Returns ``(consts, stage_bounds)``: per-stage cell tensors concatenate
    along the cell axis and are sliced back at the static ``stage_bounds``
    offsets inside the kernel; wire ids in ``in3`` are global (allocation
    order), so they index the growing ``vals`` array unchanged.
    """
    bounds = []
    c0 = 0
    for st in lowered.stages:
        bounds.append((c0, c0 + st.in3.shape[0]))
        c0 = bounds[-1][1]
    with jax.ensure_compile_time_eval():  # concrete under ambient traces
        consts = (
            jnp.asarray(lowered.gate_masks),                        # (n_pp, 4)
            jnp.asarray(lowered.x_idx),                             # (n_pp,)
            jnp.asarray(lowered.y_idx),                             # (n_pp,)
            jnp.asarray(np.concatenate([st.in3 for st in lowered.stages])),
            jnp.asarray(np.concatenate([st.sum_masks for st in lowered.stages])),
            jnp.asarray(np.concatenate([st.carry_masks for st in lowered.stages])),
            jnp.asarray(np.concatenate([st.perm for st in lowered.stages])),
            jnp.asarray(lowered.final_ids),                         # (n_final,)
            jnp.asarray(lowered.bit_weights.astype(np.int32)),      # (n_final,)
        )
    return consts, tuple(bounds)


def _replay_block(ia, yw, masks, gm, xi, yi, in3_all, sm_all, cm_all,
                  perm_all, fin, bw, *, stage_bounds, n_final: int,
                  offset: int):
    """Exact AMR products of one replay block, summed over its K axis.

    ``ia``: (bm, bk) int32 operand indices, ``yw``: (bk, n_opbits, bnw)
    lane-packed weight words, ``masks``: the (256, n_opbits) value->mask
    table; the remaining arrays are the ``_replay_inputs`` lowering consts.
    Returns (bm, bnw * 32) int32 = sum_k of the per-pair products.  Shared
    by the matmul-shaped replay kernel below (one call per K grid step)
    and the fused-attention kernel (``kernels/attn_fused``), which replays
    the QK^T and PV contractions back to back inside one grid block.
    """
    bm, bk = ia.shape
    bnw = yw.shape[-1]
    nb = masks.shape[-1]
    xm = jnp.take(masks, ia.reshape(-1), axis=0).reshape(bm, bk, nb)
    xw = xm.transpose(2, 0, 1)[:, :, :, None]   # (n_opbits, bm, bk, 1)
    ywt = yw.transpose(1, 0, 2)[:, None, :, :]  # (n_opbits, 1, bk, bnw)

    def bc(m):  # (rows,) -> (rows, 1, 1, 1): lift over the batch dims
        return m.reshape(m.shape[0], 1, 1, 1)

    # PP gates: x masks broadcast against packed y words
    x = jnp.take(xw, xi, axis=0)
    y = jnp.take(ywt, yi, axis=0)
    nx, ny = ~x, ~y
    vals = ((bc(gm[:, 0]) & (nx & ny)) | (bc(gm[:, 1]) & (nx & y))
            | (bc(gm[:, 2]) & (x & ny)) | (bc(gm[:, 3]) & (x & y)))
    # stage loop: cell tensors sliced at static per-stage offsets
    for c0, c1 in stage_bounds:
        ins = jnp.take(vals, in3_all[c0:c1].reshape(-1), axis=0)
        ins = ins.reshape(c1 - c0, 3, *vals.shape[1:])
        a, b, c = ins[:, 0], ins[:, 1], ins[:, 2]
        na, nb_, nc = ~a, ~b, ~c
        minterms = (na & nb_ & nc, na & nb_ & c, na & b & nc, na & b & c,
                    a & nb_ & nc, a & nb_ & c, a & b & nc, a & b & c)
        sm, cm = sm_all[c0:c1], cm_all[c0:c1]
        s_out = bc(sm[:, 0]) & minterms[0]
        c_out = bc(cm[:, 0]) & minterms[0]
        for t in range(1, 8):
            s_out |= bc(sm[:, t]) & minterms[t]
            c_out |= bc(cm[:, t]) & minterms[t]
        new = jnp.concatenate([s_out, c_out], 0)
        vals = jnp.concatenate(
            [vals, jnp.take(new, perm_all[2 * c0:2 * c1], axis=0)], 0)
    stored = jnp.take(vals, fin, axis=0)       # (n_final, bm, bk, bnw)
    # limb-combined products: sum_f 2**pos_f * bit_f - offset, in int32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, _LANE_BITS), 1)
    prods = jnp.zeros((bm, bk, bnw, _LANE_BITS), jnp.int32)
    for f in range(n_final):  # per-final-bit accumulation keeps the
        # unpacked (bm, bk, bnw, 32) intermediates at 2 live tensors
        bits = ((stored[f][..., None] >> shifts) & 1).astype(jnp.int32)
        prods = prods + bw[f] * bits
    prods = prods - offset                     # exact per-pair products
    return prods.sum(axis=1).reshape(bm, bnw * _LANE_BITS)


def _make_replay_kernel(stage_bounds, *, n_final: int, offset: int, n_k: int):
    """Kernel body; every array constant arrives as a ref, only Python
    scalars (stage offsets, the polarity offset, grid depth) are baked."""

    def kernel(ia_ref, yw_ref, masks_ref, gate_ref, xi_ref, yi_ref, in3_ref,
               sm_ref, cm_ref, perm_ref, fin_ref, bw_ref, out_ref, acc_ref):
        k_idx = pl.program_id(2)

        @pl.when(k_idx == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += _replay_block(
            ia_ref[...], yw_ref[...], masks_ref[...], gate_ref[...],
            xi_ref[...], yi_ref[...], in3_ref[...], sm_ref[...], cm_ref[...],
            perm_ref[...], fin_ref[...], bw_ref[...],
            stage_bounds=stage_bounds, n_final=n_final, offset=offset)

        @pl.when(k_idx == n_k - 1)
        def _store():
            out_ref[...] = acc_ref[...]

    return kernel


@functools.partial(jax.jit, static_argnames=("lowered", "bm", "bnw", "bk",
                                             "interpret"))
def _inject_replay_jit(ia, yw, masks, *, lowered, bm, bnw, bk, interpret):
    """ia (rows, K) int32, yw (K, n_opbits, n_words) uint32 packed weights,
    masks (256, n_opbits) uint32 -> (rows, n_words*32) int32 products sum."""
    rows, k = ia.shape
    n_words = yw.shape[-1]
    nb = yw.shape[1]
    assert rows % bm == 0 and n_words % bnw == 0 and k % bk == 0, \
        (rows, n_words, k, bm, bnw, bk)
    n_k = k // bk
    grid = (rows // bm, n_words // bnw, n_k)
    bn = bnw * _LANE_BITS
    consts, stage_bounds = _replay_inputs(lowered)
    whole = [pl.BlockSpec(c.shape, lambda i, j, k, nd=c.ndim: (0,) * nd)
             for c in (masks, *consts)]
    return pl.pallas_call(
        _make_replay_kernel(stage_bounds, n_final=len(lowered.final_ids),
                            offset=int(lowered.offset_total), n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, nb, bnw), lambda i, j, k: (k, 0, j)),
            *whole,  # value->mask table + lowering consts, whole in VMEM
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, n_words * _LANE_BITS), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(ia, yw, masks, *consts)

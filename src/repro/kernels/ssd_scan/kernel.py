"""Pallas TPU kernel: Mamba2 SSD chunked scan.

One grid cell = one (batch, head, chunk) tile. The chunk axis is the
innermost (sequential) grid dimension, so the carried SSM state lives in a
VMEM scratch that persists across grid steps — the standard Pallas pattern
for scans. Per tile:

  intra:  y  = tril(exp(cum_t - cum_s)) * (C B^T) @ (x*dt)   (MXU dots)
  inter:  y += exp(cum) * (C @ h_prev)
  carry:  h  = exp(cum_Q) * h_prev + (exp(cum_Q - cum) B dt)^T @ x

Tile sizes: Q (chunk) x P (head_dim) x N (d_state) — e.g. 256x64x128 ->
well under VMEM; dims padded to lane multiples by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)                # () log A for this head
    b = b_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)

    la = (-jnp.exp(a)) * dt                         # (Q,) negative log decay
    cum = jnp.cumsum(la)                            # (Q,)
    xdt = x * dt[:, None]                           # (Q, P)

    seg = cum[:, None] - cum[None, :]               # (Q, Q) t - s
    q_len = x.shape[0]
    tri = jnp.tril(jnp.ones((q_len, q_len), jnp.bool_))
    decay = jnp.exp(jnp.where(tri, seg, -1e30))
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)      # (Q, Q)
    y = jnp.dot(cb * decay, xdt, preferred_element_type=jnp.float32)

    h_prev = h_ref[...]                             # (N, P)
    y += jnp.exp(cum)[:, None] * jnp.dot(c, h_prev, preferred_element_type=jnp.float32)

    tail = jnp.exp(cum[-1] - cum)                   # (Q,)
    h_new = jnp.exp(cum[-1]) * h_prev + jnp.dot(
        (tail[:, None] * b).T, xdt, preferred_element_type=jnp.float32)
    h_ref[...] = h_new
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, chunk: int = 256,
             *, interpret: bool = True) -> jnp.ndarray:
    """x (B,S,H,P), dt (B,S,H), a_log (H,), b/c (B,S,H,N) -> y (B,S,H,P).

    b/c must already be head-expanded (ops.py repeats groups).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    grid = (B, H, nc)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, N), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda bi, hi, ci: (bi, ci, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c)

"""Pure-jnp oracle for the SSD kernel: the model's own chunked implementation."""
from __future__ import annotations

from repro.models.ssm import ssd_chunked


def ref_ssd(x, dt, a_log, b, c, chunk: int = 256):
    """Same contract as kernel.ssd_scan but with grouped (G,N) b/c expansion
    already applied by the caller: here b/c are (B,S,H,N), so pass G=H."""
    return ssd_chunked(x, dt, a_log, b, c, chunk)

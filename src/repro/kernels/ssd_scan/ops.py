"""jit'd wrapper: group expansion + dtype handling around the SSD kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_mixer(x, dt, a_log, b_grouped, c_grouped, *, chunk: int = 256,
              interpret: bool = True):
    """b/c arrive grouped (B,S,G,N); expand to heads then run the kernel."""
    H = x.shape[2]
    G = b_grouped.shape[2]
    rep = H // G
    b = jnp.repeat(b_grouped, rep, axis=2)
    c = jnp.repeat(c_grouped, rep, axis=2)
    return ssd_scan(x, dt, a_log, b, c, chunk, interpret=interpret)

"""Pallas kernel: fused AMR attention — QK^T, masked softmax, PV, one pass.

The activation×activation seam (numerics/approx_matmul.py) computes a
decode/prefill attention step as two separate grouped matmuls with an XLA
softmax between them: quantize Q/K, LUT-gather or circuit-replay the score
products, rescale, mask, softmax, re-quantize the probabilities, and
contract against V.  This kernel runs that whole chain inside ONE grid
block per (group, query-row tile), so the (bm, T) score block never
round-trips to HBM between QK^T and PV.

Two methods, mirroring the seam's integer paths:

  * ``lut``    — both contractions gather from the full 256x256 product
    table (``amr_matmul._lut_gather_accum``, the same sweep the flat and
    grouped LUT kernels use); bit-identical to the ``amr_lut`` seam
    composition by construction.
  * ``inject`` — both contractions replay the reduction circuit on
    lane-packed operand words (``inject_replay._replay_block`` — the exact
    kernel body of the matmul-shaped replay, called twice back to back),
    so ANY registered ``reduction.Schedule`` runs fused, LUT-free.  K and
    V are lane-packed outside the kernel (in-trace, per group — traced
    activations never touch the identity-keyed WEIGHT_PACKS cache).

Bitwise contract (asserted in tests/test_attn_fused.py and gated by the
attention benchmark): the output equals the UNFUSED seam composition —
``approx_matmul(q, kT) / scale`` -> mask -> softmax -> re-quantize ->
``approx_matmul(p, v)`` — bit for bit.  Everything the kernel fuses is
either integer math (gather/replay products, int32 accumulation: exactly
associative) or the identical sequence of f32 elementwise ops and row
reductions the seam's XLA program runs, in the same order.  The softmax is
NOT the online/streaming form — a flash-style rescaling accumulator would
change f32 summation order and break the bit-identity bar — so T, D and P
live whole in VMEM and only the query-row dim is tiled
(``tiling.ATTN_AUTOTUNE``, head-dim-bucketed: bigger head dims shrink the
row tile).  That sizes the kernel for decode/short-prefill shapes, the
serving hot path the paper's Table 2 energy claim turns on.

Masking: the caller passes an explicit per-row validity mask (int32 0/1,
(G, M, T)) — causal, sliding-window and ragged decode masks all reduce to
it.  Invalid columns take ``NEG_INF`` (the same fill models/attention.py
uses) BEFORE the softmax, exactly like the unfused path.  For the inject
method the replayed score block is word-padded (32 columns per lane word);
the pad is sliced off (statically) before the softmax, and the padded PV
columns are sliced off by the op wrapper after the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.engine import _LANE_BITS
from repro.kernels.amr_matmul.kernel import _lut_gather_accum
from repro.kernels.inject_replay.kernel import _replay_block, _replay_inputs

NEG_INF = -2.0e38  # the models/attention.py mask fill, bit for bit


def _quantize_probs(probs):
    """In-kernel int8 quantization of the softmax rows.

    Bitwise the ``quantize_int8`` / ``quantize_int8_ste`` index computation
    (numerics/quant.py): the two share ``_absmax_scale`` (absmax over the
    row, eps=1e-8, /127) and the round/clip, differing only in the returned
    dtype/gradient — neither of which reaches the integer contraction.
    Returns (q on the int8 grid as f32, per-row scale (bm, 1) f32).
    """
    amax = jnp.max(jnp.abs(probs), axis=-1, keepdims=True)
    ps = jnp.maximum(amax, 1e-8) / 127.0
    qp = jnp.clip(jnp.round(probs / ps), -128.0, 127.0)
    return qp, ps


def _attn_fused_lut_kernel(q_ref, k_ref, v_ref, sq_ref, sk_ref, sv_ref,
                           mask_ref, lut_ref, out_ref, *, scale: float):
    """One (bm, P) output block: full-LUT QK^T -> masked softmax -> PV."""
    flat = lut_ref[...].reshape(-1)                # (65536,) int32
    q = q_ref[0]                                   # (bm, D) int8
    kt = k_ref[0]                                  # (D, T) int8
    v = v_ref[0]                                   # (T, P) int8
    bm = q.shape[0]
    t_len = kt.shape[1]
    p_len = v.shape[1]
    acc = _lut_gather_accum(q, kt, flat, jnp.zeros((bm, t_len), jnp.int32))
    scores = acc.astype(jnp.float32) * sq_ref[0] * sk_ref[0] / scale
    scores = jnp.where(mask_ref[0] != 0, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    qp, ps = _quantize_probs(probs)
    acc = _lut_gather_accum(qp, v, flat, jnp.zeros((bm, p_len), jnp.int32))
    out_ref[0] = acc.astype(jnp.float32) * ps * sv_ref[0]


@functools.partial(jax.jit, static_argnames=("bm", "scale", "interpret"))
def _attn_fused_lut_jit(q, kt, v, sq, sk, sv, mask, table, *, bm, scale,
                        interpret):
    """q (G,M,D) / kt (G,D,T) / v (G,T,P) int8, per-seam scales, mask
    (G,M,T) int32, table (256,256) int32 -> (G, M, P) f32."""
    G, M, D = q.shape
    T = kt.shape[-1]
    P = v.shape[-1]
    assert M % bm == 0, (M, bm)
    return pl.pallas_call(
        functools.partial(_attn_fused_lut_kernel, scale=scale),
        grid=(G, M // bm),
        in_specs=[
            pl.BlockSpec((1, bm, D), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, D, T), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, T, P), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, bm, 1), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, 1, T), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, 1, P), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, bm, T), lambda g, i: (g, i, 0)),
            pl.BlockSpec(table.shape, lambda g, i: (0, 0)),  # whole LUT
        ],
        out_specs=pl.BlockSpec((1, bm, P), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((G, M, P), jnp.float32),
        interpret=interpret,
    )(q, kt, v, sq, sk, sv, mask, table)


def _make_attn_fused_inject_kernel(stage_bounds, *, n_final: int, offset: int,
                                   t_len: int, scale: float):
    """Inject-method body: two back-to-back ``_replay_block`` calls."""

    def kernel(iq_ref, kw_ref, vw_ref, masks_ref, sq_ref, sk_ref, sv_ref,
               mask_ref, gate_ref, xi_ref, yi_ref, in3_ref, sm_ref, cm_ref,
               perm_ref, fin_ref, bw_ref, out_ref):
        masks = masks_ref[...]
        consts = (gate_ref[...], xi_ref[...], yi_ref[...], in3_ref[...],
                  sm_ref[...], cm_ref[...], perm_ref[...], fin_ref[...],
                  bw_ref[...])
        qk = _replay_block(iq_ref[0], kw_ref[0], masks, *consts,
                           stage_bounds=stage_bounds, n_final=n_final,
                           offset=offset)          # (bm, Tw*32), word-padded
        scores = (qk[:, :t_len].astype(jnp.float32)
                  * sq_ref[0] * sk_ref[0] / scale)
        scores = jnp.where(mask_ref[0] != 0, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        qp, ps = _quantize_probs(probs)
        ip = qp.astype(jnp.int32) + 128            # replay operand indices
        pv = _replay_block(ip, vw_ref[0], masks, *consts,
                           stage_bounds=stage_bounds, n_final=n_final,
                           offset=offset)          # (bm, Pw*32), word-padded
        out_ref[0] = pv.astype(jnp.float32) * ps * sv_ref[0]

    return kernel


@functools.partial(jax.jit, static_argnames=("lowered", "bm", "scale",
                                             "interpret"))
def _attn_fused_inject_jit(iq, kw, vw, masks, sq, sk, sv, mask, *, lowered,
                           bm, scale, interpret):
    """iq (G,M,D) int32 indices, kw (G,D,nb,Tw) / vw (G,T,nb,Pw) lane-packed
    words, masks (256,nb), sv padded to whole words -> (G, M, Pw*32) f32
    (pad columns carry garbage; the op wrapper slices [:, :, :P])."""
    G, M, D = iq.shape
    nb, tw = kw.shape[2], kw.shape[3]
    t_len = vw.shape[1]
    pw = vw.shape[-1]
    npad = pw * _LANE_BITS
    assert M % bm == 0, (M, bm)
    consts, stage_bounds = _replay_inputs(lowered)
    whole = [pl.BlockSpec(c.shape, lambda g, i, nd=c.ndim: (0,) * nd)
             for c in (masks, *consts)]
    return pl.pallas_call(
        _make_attn_fused_inject_kernel(
            stage_bounds, n_final=len(lowered.final_ids),
            offset=int(lowered.offset_total), t_len=t_len, scale=scale),
        grid=(G, M // bm),
        in_specs=[
            pl.BlockSpec((1, bm, D), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, D, nb, tw), lambda g, i: (g, 0, 0, 0)),
            pl.BlockSpec((1, t_len, nb, pw), lambda g, i: (g, 0, 0, 0)),
            whole[0],                                   # value->mask table
            pl.BlockSpec((1, bm, 1), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, 1, t_len), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, 1, npad), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, bm, t_len), lambda g, i: (g, i, 0)),
            *whole[1:],                                 # lowering consts
        ],
        out_specs=pl.BlockSpec((1, bm, npad), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((G, M, npad), jnp.float32),
        interpret=interpret,
    )(iq, kw, vw, masks, sq, sk, sv, mask, *consts)

from .ops import METHODS, fused_attention, fused_attention_reference

__all__ = ["METHODS", "fused_attention", "fused_attention_reference"]

"""Public fused-attention op: the AMR attention step as one Pallas call.

``fused_attention`` consumes the seam's pre-folded operand layout — the
(G, M, D) query rows, (G, D, T) transposed keys and (G, T, P) values that
``models/attention._seam_scores`` / ``_seam_combine`` build by folding the
GQA group into the row dim and flattening (batch, kv head) to one group
axis — plus an explicit (G, M, T) validity mask.  It returns bit for bit
what the unfused seam composition returns (``fused_attention_reference``,
the assertion target of tests/test_attn_fused.py and the ``bit_exact``
gate of benchmarks/attn_bench.py).

Quantization happens HERE, outside the kernel, with the exact seam front
ends (``quantize_int8`` for the lut method, ``quantize_int8_ste`` for
inject — identical scales and integer indices), so the kernel only ever
sees integer operands and f32 scales; the in-kernel softmax-probability
re-quantization replicates the same functions (kernel._quantize_probs).

Tiling: only the query-row dim tiles (``tiling.pick_attn_tile``, head-dim
bucketed); T/D/P stay whole per block — full-T masked softmax, no online
rescaling (see kernel.py for why that is load-bearing for bit-identity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.core.engine import _LANE_BITS
from repro.kernels.amr_matmul.tiling import pick_attn_tile
from repro.kernels.pallas_config import resolve_interpret
from repro.numerics.quant import quantize_int8, quantize_int8_ste

from .kernel import NEG_INF, _attn_fused_inject_jit, _attn_fused_lut_jit

METHODS = ("lut", "inject")


def _check_shapes(q, kt, v, mask):
    if q.ndim != 3 or kt.ndim != 3 or v.ndim != 3 or mask.ndim != 3:
        raise ValueError(
            f"fused_attention wants q (G,M,D), kt (G,D,T), v (G,T,P), mask "
            f"(G,M,T); got {q.shape} / {kt.shape} / {v.shape} / {mask.shape}")
    G, M, D = q.shape
    T = kt.shape[-1]
    P = v.shape[-1]
    if kt.shape[:2] != (G, D) or v.shape[:2] != (G, T) \
            or mask.shape != (G, M, T):
        raise ValueError(
            f"fused_attention operand shapes disagree: q {q.shape}, "
            f"kt {kt.shape}, v {v.shape}, mask {mask.shape} (want matching "
            f"G and D/T/P contractions)")
    return G, M, D, T, P


def fused_attention(q, kt, v, mask, *, border: int = 8, method: str = "lut",
                    schedule_ref: str | None = None,
                    scale: float | None = None, bm: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Fused QK^T -> masked softmax -> PV under AMR product semantics.

    ``q``: (G, M, D) f32 query rows, ``kt``: (G, D, T) transposed keys,
    ``v``: (G, T, P) values, ``mask``: (G, M, T) bool/int validity (invalid
    columns take NEG_INF before the softmax).  ``scale`` divides the scores
    (default sqrt(D), the seam's convention).  ``method="lut"`` gathers the
    default design point's product table; ``method="inject"`` replays the
    reduction circuit — any registered schedule via ``schedule_ref``
    (None = the paper's default for ``border``).  Returns (G, M, P) f32,
    bit-identical to ``fused_attention_reference`` with the same arguments.
    """
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    G, M, D, T, P = _check_shapes(q, kt, v, mask)
    scale = float(D) ** 0.5 if scale is None else float(scale)
    bm = pick_attn_tile(M, D, bm=bm)
    interpret = resolve_interpret(interpret)
    mask = mask.astype(jnp.int32)

    if method == "lut":
        if schedule_ref is not None:
            raise ValueError(
                "schedule_ref is an inject-method knob (the lut method "
                "tabulates the default design point for `border`); use "
                "method='inject' to run a registered schedule")
        max_abs = lut_lib.table_max_abs(border)
        for k_len, what in ((D, "QK^T"), (T, "PV")):
            if k_len * max_abs >= 2**31:
                raise ValueError(
                    f"fused_attention {what} int32 accumulator can saturate: "
                    f"K={k_len} with max|product|={max_abs} gives "
                    f"{k_len * max_abs} >= 2**31; keep K <= "
                    f"{(2**31 - 1) // max_abs}")
        qq, sq = quantize_int8(q, axis=-1)
        qk, sk = quantize_int8(kt, axis=-2)
        qv, sv = quantize_int8(v, axis=-2)
        return _attn_fused_lut_jit(qq, qk, qv, sq, sk, sv, mask,
                                   lut_lib.table_array(border), bm=bm,
                                   scale=scale, interpret=interpret)

    # inject: lane-pack K and V per group, in-trace (traced activations —
    # the WEIGHT_PACKS identity cache is structurally invalid here)
    from repro.numerics import injection  # lazy: kernels <-> numerics cycle
    from repro.numerics.approx_matmul import AMRNumerics

    nm = AMRNumerics(mode="amr_inject", border=border,
                     schedule_ref=schedule_ref)
    inj = injection.get_injector(nm)
    for k_len in (D, T):
        injection.check_accumulation_bound(inj, k_len, schedule=schedule_ref)
    qf, sq = quantize_int8_ste(q, axis=-1)
    kf, sk = quantize_int8_ste(kt, axis=-2)
    vf, sv = quantize_int8_ste(v, axis=-2)
    iq = jax.lax.stop_gradient(qf).astype(jnp.int32) + 128
    ik = jax.lax.stop_gradient(kf).astype(jnp.int32) + 128
    iv = jax.lax.stop_gradient(vf).astype(jnp.int32) + 128
    kw = jax.vmap(inj.pack_weights)(ik)            # (G, D, nb, Tw)
    vw = jax.vmap(inj.pack_weights)(iv)            # (G, T, nb, Pw)
    npad = vw.shape[-1] * _LANE_BITS
    # pad the value scales to whole words; pad columns are sliced off below
    sv_pad = jnp.pad(sv, ((0, 0), (0, 0), (0, npad - P)), constant_values=1.0)
    out = _attn_fused_inject_jit(iq, kw, vw, inj._value_masks, sq, sk, sv_pad,
                                 mask, lowered=inj.lowered, bm=bm,
                                 scale=scale, interpret=interpret)
    return out[:, :, :P]


def fused_attention_reference(q, kt, v, mask, *, border: int = 8,
                              method: str = "lut",
                              schedule_ref: str | None = None,
                              scale: float | None = None) -> jnp.ndarray:
    """The unfused seam composition the kernel must match bit for bit.

    Literally the models/attention.py chain on pre-folded operands: a
    grouped ``approx_matmul`` at site ``attn.qk``, the sqrt(D) rescale,
    NEG_INF masking, ``jax.nn.softmax``, and a grouped ``approx_matmul``
    at site ``attn.pv`` — under ``amr_lut`` (method "lut") or
    ``amr_inject`` (method "inject") numerics.  Compare under jit on the
    same backend: eager-vs-jit comparisons see XLA's usual 1-ulp rescale
    fusion noise, which is not a numerics difference.
    """
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    from repro.numerics.approx_matmul import AMRNumerics, approx_matmul

    D = q.shape[-1]
    scale = float(D) ** 0.5 if scale is None else float(scale)
    if method == "lut":
        if schedule_ref is not None:
            raise ValueError("schedule_ref requires method='inject'")
        nm = AMRNumerics(mode="amr_lut", border=border)
    else:
        nm = AMRNumerics(mode="amr_inject", border=border,
                         schedule_ref=schedule_ref)
    scores = approx_matmul(q, kt, nm, site="attn.qk") / scale
    scores = jnp.where(mask != 0, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return approx_matmul(probs, v, nm, site="attn.pv")

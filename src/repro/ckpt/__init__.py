"""Mesh-agnostic sharded checkpointing with async save + retention."""
from .checkpoint import CheckpointManager, restore_tree, save_tree

__all__ = ["CheckpointManager", "save_tree", "restore_tree"]

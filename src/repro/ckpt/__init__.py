"""Mesh-agnostic sharded checkpointing with async save + retention."""
from .checkpoint import (
    CheckpointManager,
    clean_stale_tmp,
    latest_step,
    restore_tree,
    save_tree,
)

__all__ = ["CheckpointManager", "save_tree", "restore_tree", "latest_step",
           "clean_stale_tmp"]

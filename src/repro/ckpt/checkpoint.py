"""Checkpointing: mesh-agnostic, atomic, async, with retention.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json     # flat key -> {file, shape, dtype}; treedef repr
        <key>.npy         # one logical (unsharded) array per leaf

Design choices for the 1000-node story (DESIGN.md §3):
  * *Mesh-agnostic*: leaves are saved as full logical arrays, so a restart
    may resize the mesh (elastic scaling) — restore() device_puts each leaf
    with the *new* mesh's sharding. On a real multi-host pod each host
    writes only the shards it owns into a tensorstore-like layout; the
    manifest/key scheme is identical, so this module is the single-host
    realisation of that protocol.
  * *Atomic*: writes go to ``.tmp-step_N`` then rename — a preempted save
    never corrupts the latest checkpoint.
  * *Async*: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread — training continues during the I/O.
  * *Retention*: keep the newest ``keep`` checkpoints, always keep step 0.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import ml_dtypes
import numpy as np

# numpy can't round-trip ml_dtypes (bf16/f8) through .npy — store the raw
# bytes as a same-width uint view and record the logical dtype in the manifest
_CUSTOM_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flat_items(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "name", getattr(e, "idx", e))))
            for e in path
        )
        out.append((key, leaf))
    return out


def save_tree(directory: str | Path, tree: Any, step: int) -> Path:
    """Synchronous atomic save. Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp-step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {}
    for i, (key, leaf) in enumerate(_flat_items(tree)):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _CUSTOM_DTYPES:
            arr = arr.view(_CUSTOM_DTYPES[logical][1])
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape), "dtype": logical}
    (tmp / "manifest.json").write_text(json.dumps({"step": step, "leaves": manifest}))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def restore_tree(path: str | Path, abstract_tree: Any, shardings: Any | None = None) -> Any:
    """Restore into the structure of ``abstract_tree`` (values ignored).

    shardings: optional matching tree of NamedSharding — enables restoring
    under a different mesh than the one that saved (elastic restart).
    """
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())["leaves"]
    items = _flat_items(abstract_tree)
    assert len(items) == len(manifest), (len(items), len(manifest))
    leaves = []
    flat_sh = jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    for i, (key, leaf) in enumerate(items):
        meta = manifest.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(path / meta["file"])
        if meta["dtype"] in _CUSTOM_DTYPES:
            arr = arr.view(_CUSTOM_DTYPES[meta["dtype"]][0])
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i]))
        else:
            leaves.append(jax.device_put(arr))
    treedef = jax.tree_util.tree_structure(abstract_tree)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*"))
    return steps[-1] if steps else None


def clean_stale_tmp(directory: str | Path) -> list[str]:
    """Remove ``.tmp-step_*`` debris left by a save killed mid-write.

    A preempted process can die between ``tmp.mkdir`` and the atomic
    rename; the half-written directory never matches the ``step_*`` glob
    (it can't shadow a good checkpoint) but would accumulate and confuse
    humans inspecting the directory.  Called on the restore path — the
    next process's first restore sweeps the previous life's debris.
    Returns the removed directory names.
    """
    directory = Path(directory)
    if not directory.exists():
        return []
    removed = []
    for p in directory.glob(".tmp-step_*"):
        shutil.rmtree(p, ignore_errors=True)
        removed.append(p.name)
    return sorted(removed)


class CheckpointManager:
    """Async save + retention + restore-latest."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, tree: Any, step: int) -> None:
        # snapshot on the caller thread (device_get) so training can mutate
        # the live state immediately after this returns
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()

        def _write():
            save_tree(self.directory, host_tree, step)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save(self, tree: Any, step: int) -> Path:
        self.wait()
        out = save_tree(self.directory, tree, step)
        self._gc()
        return out

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, abstract_tree: Any, shardings: Any | None = None):
        self.wait()
        clean_stale_tmp(self.directory)
        step = latest_step(self.directory)
        if step is None:
            return None, None
        tree = restore_tree(self.directory / f"step_{step:08d}", abstract_tree, shardings)
        return tree, step

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1]) for p in self.directory.glob("step_*"))
        for s in steps[:-self.keep]:
            if s == 0:
                continue
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)

"""Cross-architecture numerics conformance: one harness, many consumers.

``matrix`` runs tiny reduced variants of every registered config family
through train-step and prefill->decode paths under every registered
numerics mode, asserting the per-family invariants documented in
docs/testing.md.  ``tests/conformance/`` parametrizes over it for pytest;
``benchmarks/matrix_bench.py`` sweeps it into ``BENCH_matrix.json`` rows
gated by ``scripts/check_bench.py``.
"""
from .matrix import (
    ACTIVATION_SITES,
    PARITY_TOL,
    REPRESENTATIVE,
    arch_mode_arms,
    make_inputs,
    policy_for,
    run_decode_parity,
    run_inject_audit,
    run_noise_decorrelation,
    run_restart_arm,
    run_train_arm,
    tiny_config,
)

__all__ = ["REPRESENTATIVE", "PARITY_TOL", "ACTIVATION_SITES",
           "arch_mode_arms", "policy_for",
           "tiny_config", "make_inputs", "run_train_arm", "run_inject_audit",
           "run_decode_parity", "run_noise_decorrelation", "run_restart_arm"]

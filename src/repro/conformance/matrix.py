"""The conformance matrix: families x modes x paths, with invariants.

Every arm builds a ``get_reduced_config`` variant (validated by
``configs.validate_config``), swaps in the numerics policy under test, and
drives the REAL entry points — ``train.steps`` factories, ``forward``,
``prefill_with_cache``/``decode_step``, ``runtime.fault.FaultTolerantLoop``
— never reimplementations.  Invariants per arm:

  * train      — finite loss and grads over a few real optimizer steps,
                 non-degenerate logits (the model is actually computing).
  * audit      — amr_inject bit-identical to the LUT-gather oracle at every
                 dense call site (``numerics_scope(audit=AuditTrace())``,
                 the registry's ``ModeSpec.oracle`` hook).
  * parity     — prefill->decode logits match the full forward pass within
                 a per-mode tolerance (``PARITY_TOL``); amr_noise is exempt
                 (decode folds the cache position into the PRNG, full
                 forward has no position — by design they differ).
  * decorrel   — amr_noise draws differ across steps and are reproducible
                 within a (seed, step) coordinate.
  * restart    — a ``FaultTolerantLoop`` under amr_inject, preempted
                 mid-run, resumes from ckpt/ and reproduces the
                 uninterrupted float32 loss stream bitwise.

CPU-sized throughout: every shape is tiny, every kernel path runs in
interpret mode where needed (kernels/pallas_config autodetects).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import families, get_reduced_config, validate_config
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.models import decode_step, encode, forward, init_params
from repro.models.model import prefill_with_cache
from repro.numerics import (
    AMRNumerics,
    AuditTrace,
    mode_names,
    numerics_scope,
    root_key,
)
from repro.train.steps import loss_fn, make_train_state, make_train_step

__all__ = ["REPRESENTATIVE", "PARITY_TOL", "BORDER", "ACTIVATION_SITES",
           "arch_mode_arms", "policy_for", "tiny_config", "make_inputs",
           "run_train_arm", "run_inject_audit", "run_decode_parity",
           "run_noise_decorrelation", "run_restart_arm"]

# The paper's default approximate border for all conformance arms.
BORDER = 8

# One tier-1 representative arch per family; the rest of the family sweeps
# nightly (tests/conformance/test_family_modes.py) and in the full bench.
REPRESENTATIVE = {
    "dense": "gemma3-1b",     # swa+full pattern — covers both attn kinds
    "ssm": "mamba2-370m",
    "hybrid": "zamba2-1.2b",  # ssm + shared_attn groups
    "moe": "dbrx-132b",
    "audio": "whisper-small",
    "vlm": "internvl2-76b",
}

# Decode-vs-forward parity tolerance per mode (float32 logit max-abs-diff).
# Exact matches the long-standing handoff-test bound; int8-quantized modes
# get headroom for bin flips — a bf16 accumulation-order difference upstream
# can move an activation across an int8 boundary, stepping the output by a
# full product quantum. None = parity not applicable (amr_noise: decode
# folds the cache position into its PRNG coordinates, forward has none).
PARITY_TOL: dict[str, float | None] = {
    "exact": 0.15,
    "amr_lut": 0.75,
    "amr_inject": 0.75,
    "amr_lowrank": 0.75,
    "amr_noise": None,
    "amr_kernel": 0.75,
}


# Activation×activation seam sites each family's forward MUST route under
# a non-exact policy — the QK^T/PV score chain, the MoE grouped expert
# matmuls and the SSD scan readout are the serving hot path the paper's
# energy claim turns on (docs/paper_mapping.md).  ``run_inject_audit``'s
# per-site diffs are checked against this map per representative arch, so
# a call-site regression that silently drops a site back to plain einsum
# fails conformance, not just lint.
ACTIVATION_SITES: dict[str, set[str]] = {
    "dense": {"attn.qk", "attn.pv"},
    "ssm": {"ssm.scan"},
    "hybrid": {"attn.qk", "attn.pv", "ssm.scan"},
    "moe": {"attn.qk", "attn.pv", "moe.expert.w_gate", "moe.expert.w_up",
            "moe.expert.w_down"},
    "audio": {"attn.qk", "attn.pv"},   # cross-attn shares the seam sites
    "vlm": {"attn.qk", "attn.pv"},
}


def policy_for(mode: str, *, border: int = BORDER,
               schedule_ref: str | None = None,
               noise_seed: int = 0) -> AMRNumerics:
    """The conformance policy for a registry mode — registry-driven.

    Each ``ModeSpec`` declares its default params (amr_kernel pins rank=0,
    the full-LUT Pallas variant with bit-exact AMR semantics; amr_lowrank
    pins rank=4) and which overrides it accepts, so adding a mode needs no
    edit here: ``default_policy`` drops overrides the mode doesn't take.
    """
    from repro.numerics import default_policy

    return default_policy(mode, border=border, schedule_ref=schedule_ref,
                          noise_seed=noise_seed)


def tiny_config(arch: str, mode: str, **policy_kw: Any) -> ModelConfig:
    """Validated reduced config with the mode-under-test numerics."""
    cfg = validate_config(get_reduced_config(arch))
    return dataclasses.replace(cfg, numerics=policy_for(mode, **policy_kw))


def arch_mode_arms(archs=None, modes=None) -> list[tuple[str, str]]:
    """The (arch, mode) sweep grid, registry-ordered on both axes."""
    if archs is None:
        archs = [a for fam in families().values() for a in fam]
    if modes is None:
        modes = list(mode_names())
    return [(a, m) for a in archs for m in modes]


def make_inputs(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """Token batch + the stub-frontend extras a family needs (jnp arrays)."""
    data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch, seed=seed)
    out = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    rng = np.random.default_rng(seed + 1)
    if cfg.encoder_layers:
        out["extra"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_frames, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    elif cfg.vision_prefix:
        out["extra"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vision_prefix, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out


def _tree_finite(tree: Any) -> bool:
    return all(bool(jnp.isfinite(l).all())
               for l in jax.tree.leaves(tree)
               if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating))


def run_train_arm(arch: str, mode: str, *, steps: int = 2, batch: int = 2,
                  seq: int = 8, seed: int = 0, **policy_kw: Any) -> dict:
    """A few real optimizer steps; finiteness + non-degeneracy invariants."""
    cfg = tiny_config(arch, mode, **policy_kw)
    state = make_train_state(cfg, root_key(seed))
    train_step = jax.jit(make_train_step(cfg, total_steps=max(steps, 2)))
    batch0 = make_inputs(cfg, batch, seq, seed)

    # grad finiteness probed explicitly (the optimizer would smear a NaN
    # into every param before the loss showed it); with_logits=True makes
    # the one differentiated compile also serve the non-degeneracy check
    (_, (_, logits)), grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch0["tokens"], batch0["targets"],
                          batch0.get("extra"), step=state.step,
                          with_logits=True),
        has_aux=True))(state.params)
    grad_finite = _tree_finite(grads)

    losses = []
    for i in range(steps):
        state, metrics = train_step(state, make_inputs(cfg, batch, seq, seed + i))
        losses.append(float(metrics["loss"]))
    loss_finite = all(np.isfinite(losses))

    lg = np.asarray(logits, np.float32)
    # non-degenerate: finite, and the model actually discriminates over the
    # vocab (a collapsed/clipped stack emits near-constant rows)
    nondegenerate = bool(np.isfinite(lg).all()
                         and (lg.max(axis=-1) - lg.min(axis=-1)).min() > 1e-4)
    return {
        "kind": "train", "arch": arch, "mode": mode, "steps": steps,
        "loss_finite": loss_finite, "grad_finite": grad_finite,
        "nondegenerate": nondegenerate,
        "first_loss": losses[0], "final_loss": losses[-1],
    }


def run_inject_audit(arch: str, *, schedule_ref: str | None = None,
                     batch: int = 2, seq: int = 8, seed: int = 0) -> dict:
    """amr_inject forward under the audit scope: every dense call site's
    output compared against the LUT-gather oracle (grid-step units)."""
    cfg = tiny_config(arch, "amr_inject", schedule_ref=schedule_ref)
    params = init_params(cfg, root_key(seed))
    inputs = make_inputs(cfg, batch, seq, seed)
    trace = AuditTrace()

    @jax.jit
    def fwd(params, tokens, extra):
        with numerics_scope(step=jnp.zeros((), jnp.int32), audit=trace):
            logits, _ = forward(cfg, params, tokens, extra)
        return logits

    logits = fwd(params, inputs["tokens"], inputs.get("extra"))
    logits.block_until_ready()
    jax.effects_barrier()
    assert trace.calls > 0, f"{arch}: audit saw no approx_matmul call sites"
    return {
        "kind": "inject_audit", "arch": arch,
        "schedule": schedule_ref or "default",
        "bit_exact": trace.bit_exact(), "max_abs_diff": trace.max_abs_diff,
        "sites": len(trace.sites), "calls": trace.calls,
        "site_diffs": {s: e["max_abs_diff"] for s, e in sorted(trace.sites.items())},
    }


def run_decode_parity(arch: str, mode: str, *, seq: int = 12, batch: int = 2,
                      seed: int = 0, **policy_kw: Any) -> dict:
    """Prefill S-1 tokens, decode token S-1; final logits vs full forward."""
    tol = PARITY_TOL.get(mode, 0.75)
    if tol is None:
        return {"kind": "decode_parity", "arch": arch, "mode": mode,
                "applicable": False, "within_tol": True, "parity_diff": 0.0}
    cfg = tiny_config(arch, mode, **policy_kw)
    params = init_params(cfg, root_key(seed))
    inputs = make_inputs(cfg, batch, seq, seed)
    toks, extra = inputs["tokens"], inputs.get("extra")
    enc_out = encode(cfg, params, extra) if cfg.encoder_layers else None

    ref, _ = forward(cfg, params, toks, extra)
    # vision tokens prepend to the decoder sequence — the cache must hold them
    _, cache = prefill_with_cache(cfg, params, toks[:, : seq - 1],
                                  capacity=seq + cfg.vision_prefix,
                                  extra_embeddings=extra)
    lg, _ = decode_step(cfg, params, toks[:, seq - 1 : seq], cache, enc_out)
    diff = float(np.max(np.abs(np.asarray(lg[:, 0], np.float32)
                               - np.asarray(ref[:, -1], np.float32))))
    return {"kind": "decode_parity", "arch": arch, "mode": mode,
            "applicable": True, "within_tol": diff <= tol,
            "parity_diff": diff, "tol": tol}


def run_noise_decorrelation(arch: str, *, batch: int = 2, seq: int = 8,
                            seed: int = 0) -> dict:
    """amr_noise must differ across step coordinates and reproduce within
    one — the scope fold is doing its job at model scale."""
    cfg = tiny_config(arch, "amr_noise")
    params = init_params(cfg, root_key(seed))
    inputs = make_inputs(cfg, batch, seq, seed)

    @jax.jit
    def fwd(step, params, tokens, extra):
        with numerics_scope(step=step):
            logits, _ = forward(cfg, params, tokens, extra)
        return logits

    args = (params, inputs["tokens"], inputs.get("extra"))
    l0 = np.asarray(fwd(jnp.zeros((), jnp.int32), *args), np.float32)
    l0b = np.asarray(fwd(jnp.zeros((), jnp.int32), *args), np.float32)
    l1 = np.asarray(fwd(jnp.ones((), jnp.int32), *args), np.float32)
    return {
        "kind": "noise_decorrelation", "arch": arch,
        "reproducible": bool((l0 == l0b).all()),
        "steps_decorrelated": bool(np.abs(l0 - l1).max() > 0),
    }


# --------------------------------------------------------------------------
# restart bit-consistency (the fault story, end to end)
# --------------------------------------------------------------------------

def _build_loop(cfg: ModelConfig, ckpt_dir, data: SyntheticLM, losses: list,
                *, preempt_at: int | None = None, use_signal: bool = False,
                on_restore=None, ckpt_every: int = 2):
    """A FaultTolerantLoop whose step_fn records per-step float32 losses
    and (optionally) raises the preemption flag at global step
    ``preempt_at`` — via a real SIGTERM to this process or by setting the
    loop's event directly (the handler does exactly that)."""
    from repro.runtime.fault import FaultTolerantLoop

    train_step = jax.jit(make_train_step(cfg, total_steps=64))

    def step_fn(state, batch):
        step = int(state.step)
        state, metrics = train_step(state, batch)
        losses.append((step, float(metrics["loss"])))
        if preempt_at is not None and step == preempt_at - 1:
            if use_signal:
                os.kill(os.getpid(), __import__("signal").SIGTERM)
            else:
                loop._preempted.set()
        return state, metrics

    loop = FaultTolerantLoop(
        ckpt_dir=ckpt_dir,
        make_state=lambda: make_train_state(cfg, root_key(0)),
        step_fn=step_fn,
        batch_at=lambda i: {k: jnp.asarray(v) for k, v in data.batch_at(i).items()},
        ckpt_every=ckpt_every,
        on_restore=on_restore,
    )
    return loop


def run_restart_arm(arch: str = "gemma-2b", *, total_steps: int = 6,
                    preempt_at: int = 3, batch: int = 2, seq: int = 8,
                    use_signal: bool = False, schedule_ref: str | None = None,
                    on_restore=None, between_lives=None) -> dict:
    """Preempted-and-resumed amr_inject run vs uninterrupted: loss streams
    must be bitwise equal.

    The interrupted life additionally finds a stale ``.tmp-step_*`` dir
    (planted to simulate a save killed mid-write) that restore must ignore
    and clean.  ``between_lives`` runs after the kill, before the resumed
    loop exists — tests use it to wipe process-level state (e.g. the
    injection schedule registry) the way a real process death would.
    ``on_restore`` runs in the resumed life right after the checkpoint
    restore, before stepping — the hook that re-registers a DSE schedule
    handle after a process restart.
    """
    cfg = tiny_config(arch, "amr_inject", schedule_ref=schedule_ref)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch, seed=7)

    with tempfile.TemporaryDirectory() as base:
        ref_losses: list = []
        loop = _build_loop(cfg, os.path.join(base, "ref"), data, ref_losses)
        res = loop.run(total_steps, log=lambda *_: None)
        assert not res.preempted and res.steps_done == total_steps

        killed_losses: list = []
        loop = _build_loop(cfg, os.path.join(base, "kill"), data, killed_losses,
                           preempt_at=preempt_at, use_signal=use_signal)
        if use_signal:
            loop.install_preemption_handler()
        res = loop.run(total_steps, log=lambda *_: None)
        assert res.preempted, "loop was not preempted"
        done_at_kill = res.steps_done

        # simulate a save killed mid-write in the dead process
        tmp = os.path.join(base, "kill", f".tmp-step_{99:08d}")
        os.makedirs(tmp)
        with open(os.path.join(tmp, "leaf_00000.npy"), "wb") as f:
            f.write(b"partial")
        if between_lives is not None:
            between_lives()

        # "new process": a fresh loop on the same ckpt dir resumes
        loop2 = _build_loop(cfg, os.path.join(base, "kill"), data,
                            killed_losses, on_restore=on_restore)
        res2 = loop2.run(total_steps, log=lambda *_: None)
        assert not res2.preempted and res2.steps_done == total_steps
        tmp_cleaned = not os.path.exists(tmp)

    ref = dict(ref_losses)
    got = dict(killed_losses)  # resumed steps overwrite nothing: disjoint
    missing = sorted(set(ref) - set(got))
    diffs = [abs(ref[s] - got[s]) for s in ref if s in got]
    bit_exact = not missing and all(d == 0.0 for d in diffs)
    return {
        "kind": "restart", "arch": arch,
        "schedule": schedule_ref or "default",
        "bit_exact": bit_exact, "max_abs_diff": max(diffs, default=float("inf")),
        "steps": total_steps, "resumed_from": done_at_kill,
        "tmp_cleaned": tmp_cleaned,
        "ref_losses": [ref[s] for s in sorted(ref)],
        "resumed_losses": [got[s] for s in sorted(got)],
    }

"""Numerics policy: how the paper's approximate multiplier enters NN matmuls."""
from .approx_matmul import AMRNumerics, approx_matmul
from .quant import dequantize, quantize_int8

__all__ = ["AMRNumerics", "approx_matmul", "quantize_int8", "dequantize"]

"""Numerics policy: how the paper's approximate multiplier enters NN matmuls.

Mode dispatch is registry-driven (``numerics.registry``): implementations
register themselves, ``AMRNumerics`` validates against the registry at
construction, and ``MODES`` / CLI choices / docs tables all derive from
``registry.mode_names()`` — no string matching outside this package.

A matmul's policy is either a single ``AMRNumerics`` (the uniform legacy
form) or a site-resolved ``NumericsPolicy`` (``numerics.policy``):
``UniformPolicy`` wraps one design point bit-for-bit, ``PerLayerPolicy``
assigns different design points per flat layer index / call-site label —
the carrier for the model-level DSE (``core/dse/model_policy.py``).
"""
from .approx_matmul import AMRNumerics, approx_matmul
from .context import (AuditTrace, current_scope, noise_key, numerics_scope,
                      root_key)
from .policy import (NumericsPolicy, PerLayerPolicy, UniformPolicy, as_policy,
                     load_policy, policy_from_json, policy_summary,
                     policy_to_json, resolve_numerics, save_policy)
from .quant import dequantize, quantize_int8
from .registry import (ModeSpec, default_policy, get_mode, is_exact_mode,
                       mode_names, register_mode, validate_policy)

__all__ = ["AMRNumerics", "MODES", "approx_matmul", "quantize_int8",
           "dequantize", "numerics_scope", "current_scope", "noise_key",
           "root_key", "AuditTrace", "ModeSpec", "register_mode", "get_mode",
           "mode_names", "is_exact_mode", "validate_policy", "default_policy",
           "NumericsPolicy", "UniformPolicy", "PerLayerPolicy", "as_policy",
           "resolve_numerics", "policy_to_json", "policy_from_json",
           "save_policy", "load_policy", "policy_summary"]


def __getattr__(name: str):
    # MODES is derived from the live registry (PEP 562), never a snapshot.
    if name == "MODES":
        return mode_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Numerics policy: how the paper's approximate multiplier enters NN matmuls.

Mode dispatch is registry-driven (``numerics.registry``): implementations
register themselves, ``AMRNumerics`` validates against the registry at
construction, and ``MODES`` / CLI choices / docs tables all derive from
``registry.mode_names()`` — no string matching outside this package.
"""
from .approx_matmul import AMRNumerics, approx_matmul
from .context import AuditTrace, current_scope, noise_key, numerics_scope
from .quant import dequantize, quantize_int8
from .registry import ModeSpec, get_mode, mode_names, register_mode

__all__ = ["AMRNumerics", "MODES", "approx_matmul", "quantize_int8",
           "dequantize", "numerics_scope", "current_scope", "noise_key",
           "AuditTrace", "ModeSpec", "register_mode", "get_mode", "mode_names"]


def __getattr__(name: str):
    # MODES is derived from the live registry (PEP 562), never a snapshot.
    if name == "MODES":
        return mode_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

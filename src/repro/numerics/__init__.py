"""Numerics policy: how the paper's approximate multiplier enters NN matmuls."""
from .approx_matmul import MODES, AMRNumerics, approx_matmul
from .context import current_scope, noise_key, numerics_scope
from .quant import dequantize, quantize_int8

__all__ = ["AMRNumerics", "MODES", "approx_matmul", "quantize_int8",
           "dequantize", "numerics_scope", "current_scope", "noise_key"]

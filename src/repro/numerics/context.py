"""Ambient numerics scope: per-step / per-layer PRNG decorrelation.

``AMRNumerics`` is a *static* (hashable) policy object baked into jit
traces, so it cannot carry traced values like the training step counter or
a scan-carried layer index.  This module provides the thin trace-local
channel that does: ``numerics_scope(step=..., layer=...)`` is entered by
``train.steps`` (with ``state.step``) and by the model's layer scans (with
the group counter), and ``noise_key`` folds whatever is in scope — plus a
static per-call-site label — into the ``amr_noise`` PRNG key.

Without this, every ``amr_noise`` matmul in every layer at every step drew
the IDENTICAL noise tensor from ``PRNGKey(noise_seed)`` (the layers all
share one policy object), making accumulated error wildly unrepresentative
of a real approximate multiplier.  With it the key is

    fold_in(fold_in(fold_in(PRNGKey(seed), crc32(site)), step), layer)

where absent components are skipped — so a bare ``approx_matmul`` call
outside any scope stays reproducible, two call sites differ via ``site``,
two scanned layers differ via the traced ``layer`` index, and two training
steps differ via the traced ``step``.

Scopes nest (inner values override, absent inner values inherit) and are
(re-)entered INSIDE scan/checkpoint bodies, so a remat re-trace rebuilds
the identical keys — noise is deterministic given (seed, site, step, layer).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import zlib
from typing import Any

__all__ = ["numerics_scope", "current_scope", "noise_key", "NumericsScope"]


@dataclasses.dataclass(frozen=True)
class NumericsScope:
    """Traced decorrelation coordinates visible to approx_matmul."""

    step: Any = None   # traced int scalar (training step), or None
    layer: Any = None  # traced int scalar (flat layer index), or None


# Thread-local scope stack: scopes are entered/exited during Python tracing
# and may hold tracers, so concurrent traces (e.g. a train and an eval step
# jitted from different user threads) must never see each other's entries.
_TLS = threading.local()


def _stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


@contextlib.contextmanager
def numerics_scope(*, step=None, layer=None):
    """Provide step/layer decorrelation values to nested approx matmuls."""
    cur = current_scope()
    stack = _stack()
    stack.append(NumericsScope(
        step=step if step is not None else cur.step,
        layer=layer if layer is not None else cur.layer))
    try:
        yield
    finally:
        stack.pop()


def current_scope() -> NumericsScope:
    stack = _stack()
    return stack[-1] if stack else NumericsScope()


def _site_id(site: str) -> int:
    """Static 31-bit id of a call-site label (stable across processes)."""
    return zlib.crc32(site.encode()) & 0x7FFFFFFF


def noise_key(seed: int, site: str | None = None):
    """Derive the amr_noise PRNG key for one matmul call site.

    Folds the static ``site`` label and the ambient (possibly traced)
    ``step``/``layer`` scope into ``PRNGKey(seed)``; components that are
    absent are skipped, so the key is always well-defined.

    When the scope's ``step`` is a VECTOR of per-request decode positions
    (slot-batched decode, serve/engine.py), a batch of keys is returned —
    one per request, each the key a solo decode of that request at that
    position would derive.  ``matmul_amr_noise`` then draws each request's
    rows from its own stream, so batching never correlates (or shifts)
    per-request noise.
    """
    import jax

    key = jax.random.PRNGKey(seed)
    if site:
        key = jax.random.fold_in(key, _site_id(site))
    scope = current_scope()
    step, layer = scope.step, scope.layer
    if step is not None and getattr(step, "ndim", 0):
        def fold(s):
            k = jax.random.fold_in(key, s)
            return jax.random.fold_in(k, layer) if layer is not None else k

        return jax.vmap(fold)(step)
    if step is not None:
        key = jax.random.fold_in(key, step)
    if layer is not None:
        key = jax.random.fold_in(key, layer)
    return key

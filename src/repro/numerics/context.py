"""Ambient numerics scope: per-step / per-layer PRNG decorrelation.

``AMRNumerics`` is a *static* (hashable) policy object baked into jit
traces, so it cannot carry traced values like the training step counter or
a scan-carried layer index.  This module provides the thin trace-local
channel that does: ``numerics_scope(step=..., layer=...)`` is entered by
``train.steps`` (with ``state.step``) and by the model's layer scans (with
the group counter), and ``noise_key`` folds whatever is in scope — plus a
static per-call-site label — into the ``amr_noise`` PRNG key.

Without this, every ``amr_noise`` matmul in every layer at every step drew
the IDENTICAL noise tensor from ``PRNGKey(noise_seed)`` (the layers all
share one policy object), making accumulated error wildly unrepresentative
of a real approximate multiplier.  With it the key is

    fold_in(fold_in(fold_in(PRNGKey(seed), crc32(site)), step), layer)

where absent components are skipped — so a bare ``approx_matmul`` call
outside any scope stays reproducible, two call sites differ via ``site``,
two scanned layers differ via the traced ``layer`` index, and two training
steps differ via the traced ``step``.

Scopes nest (inner values override, absent inner values inherit) and are
(re-)entered INSIDE scan/checkpoint bodies, so a remat re-trace rebuilds
the identical keys — noise is deterministic given (seed, site, step, layer,
unit).

``unit`` is a fourth coordinate for vmapped sub-layer instances that share
one traced call site — e.g. the per-expert matmuls of an MoE layer, which
are ONE ``approx_matmul`` trace under ``jax.vmap``: without it every expert
drew the identical noise tensor (site/step/layer are all equal across the
map).  The instance index rides in as a vmapped operand and folds into the
key per instance.

The scope also carries the conformance AUDIT channel (``audit=``): an
:class:`AuditTrace` that, while in scope, makes ``approx_matmul`` compare
every call site's output against the mode's bit-exact oracle
(``registry.ModeSpec.oracle``) and record the per-site max-abs-diff — the
inject-vs-LUT bit-identity proof of ``tests/conformance`` runs on it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import zlib
from typing import Any

__all__ = ["numerics_scope", "current_scope", "noise_key", "NumericsScope",
           "AuditTrace"]


class AuditTrace:
    """Per-call-site record of |mode output - oracle output| maxima.

    Populated at RUN time through ``jax.debug.callback`` (so it works under
    jit / scan / remat traces); read it only after the audited computation
    has executed (``jax.effects_barrier()`` flushes pending callbacks).
    ``sites`` maps the static call-site label to ``{"calls", "max_abs_diff"}``.
    """

    def __init__(self):
        self.sites: dict[str, dict[str, Any]] = {}

    def record(self, site: str, diff) -> None:
        ent = self.sites.setdefault(site, {"calls": 0, "max_abs_diff": 0.0})
        ent["calls"] += 1
        ent["max_abs_diff"] = max(ent["max_abs_diff"], float(diff))

    @property
    def max_abs_diff(self) -> float:
        return max((e["max_abs_diff"] for e in self.sites.values()), default=0.0)

    @property
    def calls(self) -> int:
        return sum(e["calls"] for e in self.sites.values())

    def bit_exact(self) -> bool:
        return self.max_abs_diff == 0.0


@dataclasses.dataclass(frozen=True)
class NumericsScope:
    """Traced decorrelation coordinates visible to approx_matmul."""

    step: Any = None   # traced int scalar (training step), or None
    layer: Any = None  # traced int scalar (flat layer index), or None
    unit: Any = None   # traced int scalar (vmapped instance, e.g. expert), or None
    audit: Any = None  # AuditTrace recording oracle diffs, or None


# Thread-local scope stack: scopes are entered/exited during Python tracing
# and may hold tracers, so concurrent traces (e.g. a train and an eval step
# jitted from different user threads) must never see each other's entries.
_TLS = threading.local()


def _stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


@contextlib.contextmanager
def numerics_scope(*, step=None, layer=None, unit=None, audit=None):
    """Provide step/layer/unit decorrelation values (and the optional audit
    channel) to nested approx matmuls."""
    cur = current_scope()
    stack = _stack()
    stack.append(NumericsScope(
        step=step if step is not None else cur.step,
        layer=layer if layer is not None else cur.layer,
        unit=unit if unit is not None else cur.unit,
        audit=audit if audit is not None else cur.audit))
    try:
        yield
    finally:
        stack.pop()


def current_scope() -> NumericsScope:
    stack = _stack()
    return stack[-1] if stack else NumericsScope()


def _site_id(site: str) -> int:
    """Static 31-bit id of a call-site label (stable across processes)."""
    return zlib.crc32(site.encode()) & 0x7FFFFFFF


def noise_key(seed: int, site: str | None = None):
    """Derive the amr_noise PRNG key for one matmul call site.

    Folds the static ``site`` label and the ambient (possibly traced)
    ``step``/``layer`` scope into ``PRNGKey(seed)``; components that are
    absent are skipped, so the key is always well-defined.

    When the scope's ``step`` is a VECTOR of per-request decode positions
    (slot-batched decode, serve/engine.py), a batch of keys is returned —
    one per request, each the key a solo decode of that request at that
    position would derive.  ``matmul_amr_noise`` then draws each request's
    rows from its own stream, so batching never correlates (or shifts)
    per-request noise.
    """
    import jax

    key = jax.random.PRNGKey(seed)
    if site:
        key = jax.random.fold_in(key, _site_id(site))
    scope = current_scope()
    step, layer, unit = scope.step, scope.layer, scope.unit
    if step is not None and getattr(step, "ndim", 0):
        def fold(s):
            k = jax.random.fold_in(key, s)
            if layer is not None:
                k = jax.random.fold_in(k, layer)
            if unit is not None:
                k = jax.random.fold_in(k, unit)
            return k

        return jax.vmap(fold)(step)
    if step is not None:
        key = jax.random.fold_in(key, step)
    if layer is not None:
        key = jax.random.fold_in(key, layer)
    if unit is not None:
        key = jax.random.fold_in(key, unit)
    return key

"""Ambient numerics scope: per-step / per-layer PRNG decorrelation.

``AMRNumerics`` is a *static* (hashable) policy object baked into jit
traces, so it cannot carry traced values like the training step counter or
a scan-carried layer index.  This module provides the thin trace-local
channel that does: ``numerics_scope(step=..., layer=...)`` is entered by
``train.steps`` (with ``state.step``) and by the model's layer scans (with
the group counter), and ``noise_key`` folds whatever is in scope — plus a
static per-call-site label — into the ``amr_noise`` PRNG key.

Without this, every ``amr_noise`` matmul in every layer at every step drew
the IDENTICAL noise tensor from ``PRNGKey(noise_seed)`` (the layers all
share one policy object), making accumulated error wildly unrepresentative
of a real approximate multiplier.  With it the key is

    fold_in(fold_in(fold_in(PRNGKey(seed), crc32(site)), step), layer)

where absent components are skipped — so a bare ``approx_matmul`` call
outside any scope stays reproducible, two call sites differ via ``site``,
two scanned layers differ via the traced ``layer`` index, and two training
steps differ via the traced ``step``.

Scopes nest (inner values override, absent inner values inherit) and are
(re-)entered INSIDE scan/checkpoint bodies, so a remat re-trace rebuilds
the identical keys — noise is deterministic given (seed, site, step, layer,
unit).

``unit`` is a fourth coordinate for vmapped sub-layer instances that share
one traced call site — e.g. the per-expert matmuls of an MoE layer, which
are ONE ``approx_matmul`` trace under ``jax.vmap``: without it every expert
drew the identical noise tensor (site/step/layer are all equal across the
map).  The instance index rides in as a vmapped operand and folds into the
key per instance.

The scope also carries the conformance AUDIT channel (``audit=``): an
:class:`AuditTrace` that, while in scope, makes ``approx_matmul`` compare
every call site's output against the mode's bit-exact oracle
(``registry.ModeSpec.oracle``) and record the per-site max-abs-diff — the
inject-vs-LUT bit-identity proof of ``tests/conformance`` runs on it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import zlib
from typing import Any

__all__ = ["numerics_scope", "current_scope", "noise_key", "root_key",
           "NumericsScope", "AuditTrace"]


class AuditTrace:
    """Per-call-site record of |mode output - reference output| diffs.

    Populated at RUN time through ``jax.debug.callback`` (so it works under
    jit / scan / remat traces); read it only after the audited computation
    has executed (``jax.effects_barrier()`` flushes pending callbacks).
    ``sites`` maps the static call-site label to
    ``{"calls", "max_abs_diff", "sum_abs_diff"}``.

    ``compare`` selects the reference:
      * ``"oracle"`` (default) — the mode's bit-exact ``ModeSpec.oracle``,
        diffed in integer-product-grid steps.  The conformance matrix's
        inject-vs-LUT bit-identity proof (a real mismatch records >= 1.0).
      * ``"exact"`` — the exact float matmul of the same operands.  The diff
        is the mode's raw approximation error, and ``sum_abs_diff``
        accumulates per-call error MASS — what the model-level policy
        search (core/dse/model_policy.py) scores per-site sensitivity with.

    When the ambient scope carries a layer coordinate, per-``(site, layer)``
    records additionally accumulate in ``coords`` (the layer value arrives
    concrete at run time even when it is a traced scan counter).
    """

    def __init__(self, compare: str = "oracle"):
        if compare not in ("oracle", "exact"):
            raise ValueError(
                f"AuditTrace compare must be 'oracle' or 'exact', got {compare!r}")
        self.compare = compare
        self.sites: dict[str, dict[str, Any]] = {}
        self.coords: dict[tuple[str, int], dict[str, Any]] = {}

    @staticmethod
    def _accum(ent: dict, diff: float, mass: float) -> None:
        ent["calls"] += 1
        ent["max_abs_diff"] = max(ent["max_abs_diff"], diff)
        ent["sum_abs_diff"] += mass

    def record(self, site: str, diff, layer=None, mass=None) -> None:
        d = float(diff)
        m = d if mass is None else float(mass)
        zero = {"calls": 0, "max_abs_diff": 0.0, "sum_abs_diff": 0.0}
        self._accum(self.sites.setdefault(site, dict(zero)), d, m)
        if layer is not None:
            self._accum(self.coords.setdefault((site, int(layer)), dict(zero)),
                        d, m)

    @property
    def max_abs_diff(self) -> float:
        return max((e["max_abs_diff"] for e in self.sites.values()), default=0.0)

    @property
    def calls(self) -> int:
        return sum(e["calls"] for e in self.sites.values())

    def bit_exact(self) -> bool:
        return self.max_abs_diff == 0.0


@dataclasses.dataclass(frozen=True)
class NumericsScope:
    """Traced decorrelation coordinates visible to approx_matmul.

    ``static_layer`` is the one NON-traced coordinate: a plain Python int
    (or None) identifying the flat layer a call site sits in *at trace
    time*.  Per-layer policy resolution (numerics/policy.py) keys on it —
    a traced scan counter cannot select a static ``AMRNumerics``, so the
    model's layer loops set it to the representative in-group index when
    scanning (policy invariant across group copies) or to the true flat
    index when statically unrolled (models/model.py).
    """

    step: Any = None   # traced int scalar (training step), or None
    layer: Any = None  # traced int scalar (flat layer index), or None
    unit: Any = None   # traced int scalar (vmapped instance, e.g. expert), or None
    audit: Any = None  # AuditTrace recording oracle diffs, or None
    static_layer: int | None = None  # STATIC flat layer index (policy resolution)
    # Trace-time call-site shape channel: a mutable list that, while in
    # scope, receives one record per approx_matmul dispatch —
    # {"site", "k", "mode", "schedule"} with the STATIC contraction length
    # K.  Populated during Python tracing (works under jax.eval_shape, no
    # compile or execution needed); the static-analysis saturation proof
    # (repro.analysis.trace_contract) collects every call site's K this way
    # and checks it against each schedule's accumulator bound.
    shape_probe: Any = None


# Thread-local scope stack: scopes are entered/exited during Python tracing
# and may hold tracers, so concurrent traces (e.g. a train and an eval step
# jitted from different user threads) must never see each other's entries.
_TLS = threading.local()


def _stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


@contextlib.contextmanager
def numerics_scope(*, step=None, layer=None, unit=None, audit=None,
                   static_layer=None, shape_probe=None):
    """Provide step/layer/unit decorrelation values (and the optional audit
    channel / static policy-resolution layer / analysis shape probe) to
    nested approx matmuls."""
    cur = current_scope()
    stack = _stack()
    stack.append(NumericsScope(
        step=step if step is not None else cur.step,
        layer=layer if layer is not None else cur.layer,
        unit=unit if unit is not None else cur.unit,
        audit=audit if audit is not None else cur.audit,
        static_layer=static_layer if static_layer is not None else cur.static_layer,
        shape_probe=shape_probe if shape_probe is not None else cur.shape_probe))
    try:
        yield
    finally:
        stack.pop()


def current_scope() -> NumericsScope:
    stack = _stack()
    return stack[-1] if stack else NumericsScope()


def _site_id(site: str) -> int:
    """Static 31-bit id of a call-site label (stable across processes)."""
    return zlib.crc32(site.encode()) & 0x7FFFFFFF


def root_key(seed: int):
    """The blessed PRNG root: every key chain in the repo starts here.

    ``jax.random.PRNGKey`` appears exactly once in ``src/`` — here — so
    every key is derived (``split``/``fold_in``) from a root created in
    this module.  That is what makes the PR 4 PRNG-reuse
    bug class statically checkable: ``repro.analysis`` lint rule RPL002
    flags any other ``jax.random.PRNGKey`` call site in ``src/``, and the
    trace-contract analyzer requires every PRNG primitive in a step jaxpr
    to trace back through this module.
    """
    import jax

    return jax.random.PRNGKey(seed)


def noise_key(seed: int, site: str | None = None):
    """Derive the amr_noise PRNG key for one matmul call site.

    Folds the static ``site`` label and the ambient (possibly traced)
    ``step``/``layer`` scope into ``PRNGKey(seed)``; components that are
    absent are skipped, so the key is always well-defined.

    When the scope's ``step`` is a VECTOR of per-request decode positions
    (slot-batched decode, serve/engine.py), a batch of keys is returned —
    one per request, each the key a solo decode of that request at that
    position would derive.  ``matmul_amr_noise`` then draws each request's
    rows from its own stream, so batching never correlates (or shifts)
    per-request noise.
    """
    import jax

    key = root_key(seed)
    if site:
        key = jax.random.fold_in(key, _site_id(site))
    scope = current_scope()
    step, layer, unit = scope.step, scope.layer, scope.unit
    if step is not None and getattr(step, "ndim", 0):
        def fold(s):
            k = jax.random.fold_in(key, s)
            if layer is not None:
                k = jax.random.fold_in(k, layer)
            if unit is not None:
                k = jax.random.fold_in(k, unit)
            return k

        return jax.vmap(fold)(step)
    if step is not None:
        key = jax.random.fold_in(key, step)
    if layer is not None:
        key = jax.random.fold_in(key, layer)
    if unit is not None:
        key = jax.random.fold_in(key, unit)
    return key

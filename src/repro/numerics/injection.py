"""On-device AMR error injection: any ``reduction.Schedule`` as a matmul.

The bridge from the DSE frontier to end-to-end workload accuracy: a
searched candidate cell assignment — materialized by ``dse.materialize``
but with NO pre-built 256x256 LUT — is registered here, referenced from an
``AMRNumerics("amr_inject", schedule_ref=...)`` policy, and every matmul
under that policy then computes the *exact* per-sample AMR products of its
actual quantized operands by replaying the reduction circuit on-device
(``engine.CompiledInjector``), inside the jitted train/serve step.

Three pieces:

  * the schedule registry — ``AMRNumerics`` must stay hashable/static for
    jit, so custom schedules are registered once per process under a string
    handle (``register_schedule``) and the policy carries only the handle;
    ``schedule_ref=None`` resolves to the paper's default schedule for
    ``(n_digits=2, numerics.border)``.  Anonymous handles come from a
    monotonic counter that skips taken names — they are never recycled, so
    an explicit ``name="custom:1"`` registration can't be clobbered.
  * ``injected_matmul_int`` — the outer-product accumulation: the weight
    side is bit-packed ONCE per matmul (32 columns per uint32 word,
    ``CompiledInjector.pack_weights``) and each activation operand replays
    as full-word bit masks against it, so the weight-side gather/pack cost
    is shared by every activation row instead of being repeated per
    ``(row, k, col)`` pair as the PR 4 pairwise path did.  Chunked over
    rows AND K so ``max_pairs`` genuinely bounds the pairs replayed per
    scan step.  Bit-identical to the LUT-gather oracle at any chunking
    (integer addition is associative).
  * the weight-pack cache — for CONCRETE (non-traced) weights, e.g. the
    frozen weights of an eager serving loop or a benchmark, the packed
    words are cached across calls keyed on array identity, with weakref
    eviction so an updated weight array always repacks (never stale).

The Pallas form of the same replay lives in ``kernels/inject_replay`` and
is selected per policy via ``AMRNumerics.inject_impl`` (docs/numerics.md).
"""
from __future__ import annotations

import weakref

import numpy as np

from repro.core import engine, reduction

__all__ = ["register_schedule", "resolve_schedule", "get_injector",
           "injected_matmul_int", "injected_matmul_grouped", "plan_chunks",
           "check_accumulation_bound", "schedule_label", "packed_weights"]

# Registered custom schedules (DSE candidates etc.), keyed by handle.
# Default design points (schedule_ref=None) are NOT cached here — they go
# through engine.get_injector's lru_cache, one compile per border process-wide.
_SCHEDULES: dict[str, reduction.Schedule] = {}
_INJECTORS: dict[str, engine.CompiledInjector] = {}

# Anonymous-handle counter: monotonic across registrations AND replacements,
# skipping explicitly-taken names, so handles are never silently reused.
_ANON_COUNTER = 0

# Upper bound on operand pairs replayed per scan step (memory knob: the
# replay holds ~n_wires uint32 words per 32 pairs).
MAX_PAIRS_PER_CHUNK = 1 << 18


def register_schedule(schedule: reduction.Schedule, name: str | None = None) -> str:
    """Register a custom schedule; returns the handle for ``schedule_ref``.

    The numerics matmul path quantizes to int8, so only 2-digit schedules
    (whose MRSD range strictly contains int8) are accepted.  Re-registering
    an existing name replaces the schedule and drops its compiled injector;
    anonymous handles (``name=None``) draw from a monotonic counter that
    skips taken names, so they never collide with an explicit
    ``custom:<n>`` registration and are never recycled.
    """
    global _ANON_COUNTER
    if schedule.n_digits != 2:
        raise ValueError(
            f"amr_inject matmuls run on int8 operands: need a 2-digit "
            f"schedule, got n_digits={schedule.n_digits}")
    if name is None:
        while True:
            name = f"custom:{_ANON_COUNTER}"
            _ANON_COUNTER += 1
            if name not in _SCHEDULES:
                break
    _SCHEDULES[name] = schedule
    _INJECTORS.pop(name, None)
    return name


def resolve_schedule(numerics) -> reduction.Schedule:
    """The schedule an ``amr_inject`` policy refers to."""
    if numerics.schedule_ref is None:
        return reduction.get_schedule(2, numerics.border)
    try:
        return _SCHEDULES[numerics.schedule_ref]
    except KeyError:
        raise KeyError(
            f"numerics.schedule_ref={numerics.schedule_ref!r} is not "
            f"registered in this process — call "
            f"numerics.injection.register_schedule(schedule) first") from None


def get_injector(numerics) -> engine.CompiledInjector:
    """Compiled injector for a policy (cached per handle / default border)."""
    if numerics.schedule_ref is None:
        return engine.get_injector(2, numerics.border)  # shared lru_cache
    inj = _INJECTORS.get(numerics.schedule_ref)
    if inj is None:
        inj = engine.compile_injector(resolve_schedule(numerics))
        _INJECTORS[numerics.schedule_ref] = inj
    return inj


def schedule_label(inj: engine.CompiledInjector,
                   schedule: str | None = None) -> str:
    """Human handle of the schedule an injector replays.

    The registered handle when the caller has one (``schedule_ref``), else
    the design-point label derived from the compiled schedule itself — the
    SAME string the static saturation proof (repro.analysis.trace_contract)
    keys its per-schedule report on, so runtime guard errors and analyzer
    rows correlate directly.
    """
    if schedule is not None:
        return schedule
    s = inj.schedule
    return f"default(n_digits={s.n_digits}, border={s.border})"


def check_accumulation_bound(inj: engine.CompiledInjector, k: int, *,
                             schedule: str | None = None) -> None:
    """Trace-time guard: K products must fit the int32 accumulator.

    The injected matmul accumulates K exact products per output element in
    int32; ``inj.max_abs_product`` is the exact max |product| over the
    int8 x int8 domain (computed once at injector compile time), so the
    worst-case partial sum is ``K * max|product|``.  ``schedule`` names the
    registered-schedule handle in the error (``schedule_label``), matching
    the analyzer's saturation-report rows.
    """
    worst = k * inj.max_abs_product
    if worst >= 2**31:
        raise ValueError(
            f"amr_inject int32 accumulator can saturate: schedule "
            f"{schedule_label(inj, schedule)}: K={k} with "
            f"max|product|={inj.max_abs_product} gives K*max|product| = "
            f"{worst} >= 2**31 = {2**31}; keep K <= "
            f"{(2**31 - 1) // inj.max_abs_product} for this schedule "
            f"(or split the contraction before the matmul)")


def plan_chunks(rows: int, k: int, n_words: int, max_pairs: int) -> tuple[int, int]:
    """(row_chunk, k_chunk) bounding the pairs replayed per scan step.

    Picks the largest divisors of ``rows``/``k`` with
    ``row_chunk * k_chunk * n_words * 32 <= max_pairs`` (K first: a wider K
    chunk amortizes more of the scan overhead).  Chunks are divisors so
    scan steps stay uniform with no padding.  The floor is one row x one k
    per step — ``n_words * 32`` pairs, the width of a single packed replay,
    which is not further divisible.
    """
    from repro.kernels.amr_matmul.tiling import _largest_divisor_leq

    budget = max(1, max_pairs // engine._LANE_BITS)  # words per step
    kc = _largest_divisor_leq(k, max(1, budget // n_words))
    rc = _largest_divisor_leq(rows, max(1, budget // (kc * n_words)))
    return rc, kc


class _WeightPackCache:
    """Packed-weight-word cache for concrete IMMUTABLE operand arrays.

    Keyed on the (injector, array) object identities; each entry holds a
    weakref to the source array whose collection evicts the entry, so a
    recycled ``id`` can never alias a stale pack — and an updated weight
    array (a NEW object: jax arrays are immutable) always repacks.  Only
    ``jax.Array`` instances may be cached (``packed_weights`` enforces it):
    a mutable numpy array updated IN PLACE would keep its identity and
    silently serve the stale pack.  Inside a jit trace operands are
    tracers and the cache is bypassed entirely.
    """

    def __init__(self, maxsize: int = 64):
        self._packs: dict[tuple, tuple] = {}
        self._maxsize = maxsize

    def get(self, inj: engine.CompiledInjector, ib):
        import jax

        if isinstance(ib, jax.core.Tracer) or not isinstance(ib, jax.Array):
            # A traced (or otherwise non-concrete) operand has no stable
            # object identity across traces: caching its pack under id()
            # would serve one trace's garbage to the next.  This bites
            # exactly when the B side is an ACTIVATION (QK^T / PV / grouped
            # expert matmuls) — those must take the pack-free in-trace
            # route (packed_weights / injected_matmul_grouped), never this
            # cache.
            raise TypeError(
                f"WEIGHT_PACKS caches packs of concrete jax.Array weights "
                f"keyed on array identity; got {type(ib).__name__}. Traced "
                f"activation operands must be lane-packed inside the trace "
                f"(packed_weights() bypasses the cache for them).")
        key = (id(inj), id(ib))
        hit = self._packs.get(key)
        if hit is not None:
            return hit[2]
        packed = inj.pack_weights(ib)
        try:
            ref = weakref.ref(ib, lambda _r, key=key: self._packs.pop(key, None))
        except TypeError:
            return packed  # not weakref-able: never cache (id could recycle)
        while len(self._packs) >= self._maxsize:  # FIFO eviction
            self._packs.pop(next(iter(self._packs)))
        # the strong injector ref pins id(inj) for the entry's lifetime
        self._packs[key] = (ref, inj, packed)
        return packed

    def clear(self) -> None:
        self._packs.clear()

    def __len__(self) -> int:
        return len(self._packs)


WEIGHT_PACKS = _WeightPackCache()


def packed_weights(inj: engine.CompiledInjector, ib):
    """Weight-side bit-pack of ``ib`` (K, N): cached when concrete.

    Traced operands (inside jit) pack in-trace — still once per matmul,
    shared across all activation rows; concrete ``jax.Array`` operands
    (eager serving loops, benchmarks) hit the process-level
    ``WEIGHT_PACKS`` cache.  Anything else (e.g. a numpy array, mutable
    in place under an unchanged identity) packs fresh every call.
    """
    import jax

    if isinstance(ib, jax.core.Tracer) or not isinstance(ib, jax.Array):
        return inj.pack_weights(ib)
    return WEIGHT_PACKS.get(inj, ib)


def injected_matmul_int(inj: engine.CompiledInjector, ia, ib,
                        max_pairs: int = MAX_PAIRS_PER_CHUNK, *,
                        packed_ib=None, schedule: str | None = None):
    """Exact integer AMR matmul: ``out[.., m, n] = sum_k AMR(ia[.., m, k], ib[k, n])``.

    ``ia``: (..., M, K) and ``ib``: (K, N) traced int32 operand indices
    (value + 128).  Returns (..., M, N) int32 — bit-identical to summing
    LUT-gathered products, computed by the outer-product bit-sliced replay:
    the weight side is lane-packed once (``packed_weights``), activations
    replay as full-word masks against it, and accumulation runs under
    ``lax.scan`` over row and K chunks sized by ``plan_chunks`` so at most
    ``max_pairs`` operand pairs are in flight per step.  Raises
    ``ValueError`` at trace time when K could saturate the int32
    accumulator (``check_accumulation_bound``).  ``packed_ib`` short-cuts
    the weight-side pack with a precomputed ``pack_weights(ib)`` result
    (e.g. one pack fed to many jitted calls over frozen weights).
    """
    import jax
    import jax.numpy as jnp

    *lead, M, K = ia.shape
    N = ib.shape[-1]
    check_accumulation_bound(inj, K, schedule=schedule)
    rows = int(np.prod(lead, dtype=np.int64)) * M if lead else M
    ia2 = ia.reshape(rows, K)
    yw = packed_ib if packed_ib is not None else packed_weights(inj, ib)
    n_words = yw.shape[-1]
    npad = n_words * engine._LANE_BITS
    rc, kc = plan_chunks(rows, K, n_words, max_pairs)
    nr, nk = rows // rc, K // kc
    ys = yw.reshape(nk, kc, *yw.shape[1:])           # (nk, kc, n_opbits, W)
    xs = ia2.reshape(nr, rc, nk, kc).transpose(0, 2, 1, 3)  # (nr, nk, rc, kc)

    def k_body(acc, xy):
        idx_c, y_c = xy                              # (rc, kc), (kc, n_opbits, W)
        prods = inj.products_outer(inj.operand_masks(idx_c), y_c)
        return acc + jnp.sum(prods, axis=1, dtype=jnp.int32), None

    def row_block(idx_row):                          # (nk, rc, kc) -> (rc, npad)
        acc0 = jnp.zeros((rc, npad), jnp.int32)
        if nk == 1:  # no scan wrapper for the single-chunk case
            acc, _ = k_body(acc0, (idx_row[0], ys[0]))
        else:
            acc, _ = jax.lax.scan(k_body, acc0, (idx_row, ys))
        return acc

    if nr == 1:
        out = row_block(xs[0])[None]
    else:
        _, out = jax.lax.scan(lambda c, x: (c, row_block(x)), None, xs)
    return out.reshape(rows, npad)[:, :N].reshape(*lead, M, N)


def injected_matmul_grouped(inj: engine.CompiledInjector, ia, ib,
                            max_pairs: int = MAX_PAIRS_PER_CHUNK, *,
                            schedule: str | None = None,
                            impl: str = "xla"):
    """Activation×activation form: per-group B operands, packed on the fly.

    ``ia``: (G, M, K) and ``ib``: (G, K, N) traced int32 operand indices —
    one independent matmul per group (attention heads, MoE experts, SSD
    scan states).  Returns (G, M, N) int32, bit-identical to running
    ``injected_matmul_int`` per group.  Here the B side is a traced
    ACTIVATION, so there is no reusable weight pack: the identity-keyed
    ``WEIGHT_PACKS`` cache is structurally invalid (and rejects tracers,
    see ``_WeightPackCache.get``) and each group's lane pack is instead
    built inside the trace, under ``jax.vmap`` of the unbatched replay —
    packed words exist only inside the executable and are rebuilt from the
    live operands on every call.  The int32-saturation guard is the same
    one the weight path applies (``check_accumulation_bound`` on K).

    ``impl`` selects the per-group replay: ``"xla"`` (the outer-product
    replay, chunked under ``max_pairs``) or ``"pallas"`` (the
    ``inject_replay`` kernel, batched over the group axis by vmap's
    pallas_call batching rule — one extra grid dimension).
    """
    import jax

    if ia.ndim != 3 or ib.ndim != 3 or ia.shape[0] != ib.shape[0]:
        raise ValueError(
            f"injected_matmul_grouped wants ia (G, M, K) and ib (G, K, N) "
            f"with matching G, got {ia.shape} / {ib.shape}")
    check_accumulation_bound(inj, ia.shape[-1], schedule=schedule)
    if impl == "pallas":
        from repro.kernels.inject_replay import inject_replay_matmul  # lazy

        return jax.vmap(
            lambda x, y: inject_replay_matmul(inj, x, y, schedule=schedule)
        )(ia, ib)
    return jax.vmap(
        lambda x, y: injected_matmul_int(inj, x, y, max_pairs,
                                         schedule=schedule))(ia, ib)


def _injected_matmul_pairs(inj: engine.CompiledInjector, ia, ib,
                           max_pairs: int = MAX_PAIRS_PER_CHUNK, *,
                           schedule: str | None = None):
    """The PR 4 pairwise replay path, kept as a reference implementation.

    Broadcasts every ``(row, k, col)`` operand pair and replays them
    individually (value->bits gather + lane packing PER PAIR, weight bits
    re-gathered for every activation row) — the baseline
    ``benchmarks/inject_bench.py`` measures the outer-product path against.
    Note its K-only chunking reproduces the PR 4 memory-knob bypass: when
    ``rows * N > max_pairs`` each step still replays ``rows * N`` pairs.
    """
    import jax
    import jax.numpy as jnp

    *lead, M, K = ia.shape
    N = ib.shape[-1]
    check_accumulation_bound(inj, K, schedule=schedule)
    rows = int(np.prod(lead, dtype=np.int64)) * M if lead else M
    ia2 = ia.reshape(rows, K)
    kc = max(1, min(K, max_pairs // max(rows * N, 1)))
    while K % kc:  # largest divisor <= kc: chunks stay uniform, no padding
        kc -= 1
    steps = K // kc
    ia_s = ia2.reshape(rows, steps, kc).transpose(1, 0, 2)  # (steps, rows, kc)
    ib_s = ib.reshape(steps, kc, N)

    def body(acc, xs):
        ia_c, ib_c = xs
        pa = jnp.broadcast_to(ia_c[:, :, None], (rows, kc, N)).reshape(-1)
        pb = jnp.broadcast_to(ib_c[None, :, :], (rows, kc, N)).reshape(-1)
        prods = inj.products(pa, pb).reshape(rows, kc, N)
        return acc + jnp.sum(prods, axis=1, dtype=jnp.int32), None

    if steps == 1:  # no scan wrapper for the single-chunk (oracle-size) case
        acc, _ = body(jnp.zeros((rows, N), jnp.int32), (ia_s[0], ib_s[0]))
    else:
        acc, _ = jax.lax.scan(body, jnp.zeros((rows, N), jnp.int32), (ia_s, ib_s))
    return acc.reshape(*lead, M, N)

"""On-device AMR error injection: any ``reduction.Schedule`` as a matmul.

The bridge from the DSE frontier to end-to-end workload accuracy: a
searched candidate cell assignment — materialized by ``dse.materialize``
but with NO pre-built 256x256 LUT — is registered here, referenced from an
``AMRNumerics("amr_inject", schedule_ref=...)`` policy, and every matmul
under that policy then computes the *exact* per-sample AMR products of its
actual quantized operands by replaying the reduction circuit on-device
(``engine.CompiledInjector``), inside the jitted train/serve step.

Two pieces:

  * the schedule registry — ``AMRNumerics`` must stay hashable/static for
    jit, so custom schedules are registered once per process under a string
    handle (``register_schedule``) and the policy carries only the handle;
    ``schedule_ref=None`` resolves to the paper's default schedule for
    ``(n_digits=2, numerics.border)``.
  * ``injected_matmul_int`` — the K-chunked product accumulation: the
    (rows, k_chunk, N) operand-pair block is replayed per scan step and
    accumulated in int32, so peak memory is bounded by ``max_pairs``
    instead of the full (rows, K, N) product tensor the ``amr_lut`` oracle
    materializes.  The int32 sum is bit-identical to the LUT-gather oracle
    at any chunking (integer addition is associative).
"""
from __future__ import annotations

import numpy as np

from repro.core import engine, reduction

__all__ = ["register_schedule", "resolve_schedule", "get_injector",
           "injected_matmul_int"]

# Registered custom schedules (DSE candidates etc.), keyed by handle.
# Default design points (schedule_ref=None) are NOT cached here — they go
# through engine.get_injector's lru_cache, one compile per border process-wide.
_SCHEDULES: dict[str, reduction.Schedule] = {}
_INJECTORS: dict[str, engine.CompiledInjector] = {}

# Upper bound on operand pairs replayed per scan step (memory knob: the
# replay holds ~n_wires uint32 words per 32 pairs).
MAX_PAIRS_PER_CHUNK = 1 << 18


def register_schedule(schedule: reduction.Schedule, name: str | None = None) -> str:
    """Register a custom schedule; returns the handle for ``schedule_ref``.

    The numerics matmul path quantizes to int8, so only 2-digit schedules
    (whose MRSD range strictly contains int8) are accepted.  Re-registering
    an existing name replaces the schedule and drops its compiled injector.
    """
    if schedule.n_digits != 2:
        raise ValueError(
            f"amr_inject matmuls run on int8 operands: need a 2-digit "
            f"schedule, got n_digits={schedule.n_digits}")
    handle = name if name is not None else f"custom:{len(_SCHEDULES)}"
    _SCHEDULES[handle] = schedule
    _INJECTORS.pop(handle, None)
    return handle


def resolve_schedule(numerics) -> reduction.Schedule:
    """The schedule an ``amr_inject`` policy refers to."""
    if numerics.schedule_ref is None:
        return reduction.get_schedule(2, numerics.border)
    try:
        return _SCHEDULES[numerics.schedule_ref]
    except KeyError:
        raise KeyError(
            f"numerics.schedule_ref={numerics.schedule_ref!r} is not "
            f"registered in this process — call "
            f"numerics.injection.register_schedule(schedule) first") from None


def get_injector(numerics) -> engine.CompiledInjector:
    """Compiled injector for a policy (cached per handle / default border)."""
    if numerics.schedule_ref is None:
        return engine.get_injector(2, numerics.border)  # shared lru_cache
    inj = _INJECTORS.get(numerics.schedule_ref)
    if inj is None:
        inj = engine.compile_injector(resolve_schedule(numerics))
        _INJECTORS[numerics.schedule_ref] = inj
    return inj


def injected_matmul_int(inj: engine.CompiledInjector, ia, ib,
                        max_pairs: int = MAX_PAIRS_PER_CHUNK):
    """Exact integer AMR matmul: ``out[.., m, n] = sum_k AMR(ia[.., m, k], ib[k, n])``.

    ``ia``: (..., M, K) and ``ib``: (K, N) traced int32 operand indices
    (value + 128).  Returns (..., M, N) int32 — bit-identical to summing
    LUT-gathered products, computed via the on-device bit-sliced replay in
    K-chunks of at most ``max_pairs`` operand pairs (``lax.scan``
    accumulation keeps peak memory flat; exact for K up to ~2**14 before
    the int32 accumulator could saturate, far beyond oracle shapes).
    """
    import jax
    import jax.numpy as jnp

    *lead, M, K = ia.shape
    N = ib.shape[-1]
    rows = int(np.prod(lead, dtype=np.int64)) * M if lead else M
    ia2 = ia.reshape(rows, K)
    kc = max(1, min(K, max_pairs // max(rows * N, 1)))
    while K % kc:  # largest divisor <= kc: chunks stay uniform, no padding
        kc -= 1
    steps = K // kc
    ia_s = ia2.reshape(rows, steps, kc).transpose(1, 0, 2)  # (steps, rows, kc)
    ib_s = ib.reshape(steps, kc, N)

    def body(acc, xs):
        ia_c, ib_c = xs
        pa = jnp.broadcast_to(ia_c[:, :, None], (rows, kc, N)).reshape(-1)
        pb = jnp.broadcast_to(ib_c[None, :, :], (rows, kc, N)).reshape(-1)
        prods = inj.products(pa, pb).reshape(rows, kc, N)
        return acc + jnp.sum(prods, axis=1, dtype=jnp.int32), None

    if steps == 1:  # no scan wrapper for the single-chunk (oracle-size) case
        acc, _ = body(jnp.zeros((rows, N), jnp.int32), (ia_s[0], ib_s[0]))
    else:
        acc, _ = jax.lax.scan(body, jnp.zeros((rows, N), jnp.int32), (ia_s, ib_s))
    return acc.reshape(*lead, M, N)

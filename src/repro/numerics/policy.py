"""Site-resolved numerics policies: one model, many multipliers.

``AMRNumerics`` is one multiplier design; real models tolerate
approximation unevenly per layer (survey literature: error tolerance is
application- AND site-dependent), so the model-level DSE
(``core/dse/model_policy.py``) assigns a different design point per layer.
This module is the API that carries such an assignment through the model:

  * :class:`NumericsPolicy` — the resolver protocol.  Anything with
    ``resolve(site, layer) -> AMRNumerics`` (and ``policies()`` for
    validation/serialization) can sit in ``ModelConfig.numerics``.
  * :class:`UniformPolicy` — one ``AMRNumerics`` everywhere.  Resolves to
    the SAME policy object at every call site, so the traced computation is
    bit-for-bit identical to passing the bare ``AMRNumerics`` (the legacy
    shorthand, which remains supported everywhere).
  * :class:`PerLayerPolicy` — a mapping keyed on the ``numerics_scope``
    coordinates already threaded through the model: the flat layer index
    (``layer_kinds()`` order) and/or the static call-site label
    (``"mlp.w_gate"``, ``"attn.wq"``, ...).  Precedence:
    ``(layer, site) > layer > site > default``.

Resolution happens at TRACE time: ``approx_matmul`` / ``layers.dense``
resolve the ambient ``current_scope().static_layer`` (a plain Python int
established by the model's layer loops — never a tracer), so a policy that
varies per layer forces the model to statically unroll its layer loop,
while a repeat-invariant policy keeps the compact ``lax.scan`` (see
``models/model.py``).  Serving closes the resolved policies over the single
jitted decode step, so heterogeneous policies never retrace per request.

Policies are hashable (static under jit, like ``AMRNumerics``) and
serialize to JSON (:func:`policy_to_json` / :func:`policy_from_json`), so a
searched assignment is a committable artifact.  ``schedule_ref`` handles
serialize as strings; re-registering the underlying DSE schedule after a
restart is the consumer's job (the ``FaultTolerantLoop(on_restore=...)``
hook — docs/numerics.md#policy-files).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Protocol, runtime_checkable

from .approx_matmul import AMRNumerics

__all__ = [
    "NumericsPolicy", "UniformPolicy", "PerLayerPolicy", "as_policy",
    "resolve_numerics", "numerics_to_json", "numerics_from_json",
    "policy_to_json", "policy_from_json", "save_policy", "load_policy",
    "policy_summary",
]


@runtime_checkable
class NumericsPolicy(Protocol):
    """Resolver protocol: ``ModelConfig.numerics`` may hold any of these."""

    def resolve(self, site: str | None = None,
                layer: int | None = None) -> AMRNumerics:
        """The multiplier design for one call site.  ``layer`` is the flat
        static layer index (``cfg.layer_kinds()`` order) or None outside the
        decoder stack (encoder layers, bare calls)."""
        ...

    def policies(self) -> tuple[AMRNumerics, ...]:
        """Every distinct ``AMRNumerics`` this policy can resolve to
        (validation / serialization / label surface)."""
        ...


@dataclasses.dataclass(frozen=True)
class UniformPolicy:
    """One design point everywhere — the bit-for-bit wrapper of the legacy
    global ``AMRNumerics`` semantics (resolves to the same object at every
    site, so traces are identical)."""

    numerics: AMRNumerics = AMRNumerics("exact")

    def resolve(self, site: str | None = None,
                layer: int | None = None) -> AMRNumerics:
        return self.numerics

    def policies(self) -> tuple[AMRNumerics, ...]:
        return (self.numerics,)

    def repeat_invariant(self, group_size: int, n_repeat: int) -> bool:
        return True

    def is_exact(self) -> bool:
        return self.numerics.is_exact()


def _as_items(m, n_keys: int):
    """dict | iterable of tuples -> canonical sorted tuple-of-tuples."""
    if m is None:
        return ()
    items = m.items() if isinstance(m, dict) else m
    out = []
    for it in items:
        it = tuple(it) if not isinstance(it, tuple) else it
        if len(it) == 2 and n_keys == 2 and isinstance(it[0], tuple):
            it = (*it[0], it[1])  # {(layer, site): nm} dict form
        if len(it) != n_keys + 1:
            raise ValueError(f"malformed policy entry {it!r}")
        out.append(it)
    return tuple(sorted(out, key=lambda t: tuple(map(str, t[:-1]))))


@dataclasses.dataclass(frozen=True)
class PerLayerPolicy:
    """Heterogeneous assignment keyed on the numerics_scope coordinates.

    ``layers`` maps flat layer indices (``cfg.layer_kinds()`` order),
    ``sites`` maps static call-site labels, ``layer_sites`` pins one call
    site inside one layer.  Dicts are accepted and canonicalised to sorted
    tuples (the policy must stay hashable — it is static under jit).

    Precedence: ``(layer, site)`` > ``layer`` > ``site`` > ``default``.
    Calls outside the decoder layer loops (encoder stack, bare
    ``approx_matmul``) resolve with ``layer=None`` and therefore fall back
    to ``site``/``default`` — layer-keyed entries only apply to the decoder
    stack whose flat indices they name.

    Site keys match by DOTTED PREFIX: a lookup tries the exact label
    first, then walks up the dotted hierarchy — an entry ``"moe.expert"``
    covers ``"moe.expert.w_gate"``/``"moe.expert.w_up"``/... unless a
    longer (more specific) entry exists.  The walk applies within each
    precedence level, so an exact-or-prefix ``(layer, site)`` entry still
    beats a plain ``layer`` entry, which beats any ``site`` entry.
    """

    default: AMRNumerics = AMRNumerics("exact")
    layers: Any = ()       # ((layer, AMRNumerics), ...)
    sites: Any = ()        # ((site, AMRNumerics), ...)
    layer_sites: Any = ()  # ((layer, site, AMRNumerics), ...)
    # Force the statically-unrolled layer loop even when the assignment is
    # repeat-invariant.  The model-policy sensitivity probe needs it: audit
    # debug-callback effects are dropped inside grad-of-scan (jax
    # partial-eval limitation), while the unrolled loop records fine.
    static_unroll: bool = False

    def __post_init__(self):
        object.__setattr__(self, "layers", _as_items(self.layers, 1))
        object.__setattr__(self, "sites", _as_items(self.sites, 1))
        object.__setattr__(self, "layer_sites", _as_items(self.layer_sites, 2))
        from . import registry

        for nm in self.policies():
            if not isinstance(nm, AMRNumerics):
                raise ValueError(
                    f"PerLayerPolicy entries must be AMRNumerics, got {nm!r}")
            registry.validate_policy(nm)
        for layer, _ in self.layers:
            if not isinstance(layer, int):
                raise ValueError(f"layer keys must be int, got {layer!r}")
        for layer, site, _ in self.layer_sites:
            if not isinstance(layer, int) or not isinstance(site, str):
                raise ValueError(
                    f"layer_sites keys must be (int, str), got {(layer, site)!r}")

    # maps are derived (cached in __dict__, which frozen dataclasses keep)
    @property
    def _layer_map(self) -> dict:
        m = self.__dict__.get("_layer_map_cache")
        if m is None:
            m = {k: v for k, v in self.layers}
            self.__dict__["_layer_map_cache"] = m
        return m

    @property
    def _site_map(self) -> dict:
        m = self.__dict__.get("_site_map_cache")
        if m is None:
            m = {k: v for k, v in self.sites}
            self.__dict__["_site_map_cache"] = m
        return m

    @property
    def _layer_site_map(self) -> dict:
        m = self.__dict__.get("_layer_site_map_cache")
        if m is None:
            m = {(layer, site): v for layer, site, v in self.layer_sites}
            self.__dict__["_layer_site_map_cache"] = m
        return m

    @staticmethod
    def _site_lookup(m: dict, key, site: str):
        """Exact site match first, then the longest dotted prefix: an
        entry keyed ``"moe.expert"`` resolves ``"moe.expert.w_up"``."""
        while True:
            nm = m.get(key(site))
            if nm is not None or "." not in site:
                return nm
            site = site.rsplit(".", 1)[0]

    def resolve(self, site: str | None = None,
                layer: int | None = None) -> AMRNumerics:
        if layer is not None:
            layer = int(layer)
            if site is not None:
                nm = self._site_lookup(self._layer_site_map,
                                       lambda s: (layer, s), site)
                if nm is not None:
                    return nm
            nm = self._layer_map.get(layer)
            if nm is not None:
                return nm
        if site is not None:
            nm = self._site_lookup(self._site_map, lambda s: s, site)
            if nm is not None:
                return nm
        return self.default

    def policies(self) -> tuple[AMRNumerics, ...]:
        seen: list[AMRNumerics] = [self.default]
        for _, nm in self.layers:
            if nm not in seen:
                seen.append(nm)
        for _, nm in self.sites:
            if nm not in seen:
                seen.append(nm)
        for _, _, nm in self.layer_sites:
            if nm not in seen:
                seen.append(nm)
        return tuple(seen)

    def is_exact(self) -> bool:
        return all(nm.is_exact() for nm in self.policies())

    def repeat_invariant(self, group_size: int, n_repeat: int) -> bool:
        """True when every scanned group copy resolves identically — the
        model may then keep its compact ``lax.scan`` over layer groups (one
        traced body) instead of statically unrolling (models/model.py)."""
        if self.static_unroll:
            return False
        for i in range(group_size):
            flats = [i + g * group_size for g in range(n_repeat)]
            if len({self._layer_map.get(f) for f in flats}) > 1:
                return False
            flatset = set(flats)
            sites = {s for (f, s) in self._layer_site_map if f in flatset}
            for s in sites:
                if len({self._layer_site_map.get((f, s)) for f in flats}) > 1:
                    return False
        return True


def as_policy(numerics) -> NumericsPolicy | None:
    """Wrap a bare ``AMRNumerics`` as a :class:`UniformPolicy` (None passes
    through; policies pass through)."""
    if numerics is None or isinstance(numerics, (UniformPolicy, PerLayerPolicy)):
        return numerics
    if isinstance(numerics, AMRNumerics):
        return UniformPolicy(numerics)
    if hasattr(numerics, "resolve"):
        return numerics
    raise TypeError(f"not a numerics policy: {numerics!r}")


def resolve_numerics(numerics, site: str | None = None):
    """Resolve a policy (or pass a bare ``AMRNumerics``/None through) at the
    ambient static layer coordinate — the single resolution point used by
    ``layers.dense`` and ``approx_matmul`` dispatch."""
    if numerics is None or isinstance(numerics, AMRNumerics):
        return numerics
    from .context import current_scope

    return numerics.resolve(site, current_scope().static_layer)


# ------------------------------------------------------------------ JSON
# Schema (docs/numerics.md#policy-files):
#   numerics: {"mode": str, "border": int, "rank": int, "noise_seed": int,
#              "schedule_ref": str|null, "inject_impl": str|null}
#   uniform:  {"kind": "uniform", "numerics": {...}}
#   per_layer:{"kind": "per_layer", "default": {...},
#              "layers": {"<flat index>": {...}},
#              "sites": {"<site label>": {...}},
#              "layer_sites": [[layer, site, {...}], ...],
#              "meta": {...}}        # optional, preserved opaque

_NUMERICS_FIELDS = ("mode", "border", "rank", "noise_seed", "schedule_ref",
                    "inject_impl")


def numerics_to_json(nm: AMRNumerics) -> dict:
    return {f: getattr(nm, f) for f in _NUMERICS_FIELDS}


def numerics_from_json(d: dict) -> AMRNumerics:
    unknown = set(d) - set(_NUMERICS_FIELDS)
    if unknown:
        raise ValueError(
            f"unknown AMRNumerics fields in policy JSON: {sorted(unknown)}; "
            f"valid fields: {_NUMERICS_FIELDS}")
    return AMRNumerics(**d)


def policy_to_json(policy) -> dict:
    policy = as_policy(policy)
    if isinstance(policy, UniformPolicy):
        return {"kind": "uniform", "numerics": numerics_to_json(policy.numerics)}
    if isinstance(policy, PerLayerPolicy):
        return {
            "kind": "per_layer",
            "default": numerics_to_json(policy.default),
            "layers": {str(k): numerics_to_json(v) for k, v in policy.layers},
            "sites": {s: numerics_to_json(v) for s, v in policy.sites},
            "layer_sites": [[k, s, numerics_to_json(v)]
                            for k, s, v in policy.layer_sites],
        }
    raise TypeError(f"cannot serialize policy of type {type(policy).__name__}")


def policy_from_json(obj: dict) -> NumericsPolicy:
    kind = obj.get("kind")
    if kind == "uniform":
        return UniformPolicy(numerics_from_json(obj["numerics"]))
    if kind == "per_layer":
        return PerLayerPolicy(
            default=numerics_from_json(obj.get("default", {"mode": "exact"})),
            layers=tuple((int(k), numerics_from_json(v))
                         for k, v in obj.get("layers", {}).items()),
            sites=tuple((s, numerics_from_json(v))
                        for s, v in obj.get("sites", {}).items()),
            layer_sites=tuple((int(k), s, numerics_from_json(v))
                              for k, s, v in obj.get("layer_sites", [])),
        )
    raise ValueError(
        f"unknown policy kind {kind!r}; expected 'uniform' or 'per_layer'")


def save_policy(policy, path, *, meta: dict | None = None) -> None:
    obj = policy_to_json(policy)
    if meta:
        obj["meta"] = meta
    # tmp + rename (the ckpt/ protocol): a policy artifact is consumed by
    # other processes (--policy-file, restart re-registration) — a crash
    # mid-write must never leave a torn JSON at the real path (RPL006)
    tmp = os.fspath(path) + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_policy(path) -> NumericsPolicy:
    """Load a policy JSON artifact.  NOTE: ``schedule_ref`` handles must
    already be registered in this process (``injection.register_schedule``
    or the ``FaultTolerantLoop`` ``on_restore`` hook) — construction
    validates each entry against the mode registry."""
    with open(path) as f:
        obj = json.load(f)
    return policy_from_json(obj)


def policy_summary(policy) -> str:
    """Short human label for a (possibly heterogeneous) policy, e.g.
    ``perlayer[3l+1s: inject b6-b10]`` — launch/cli.policy_label dispatches
    here for non-uniform policies."""
    policy = as_policy(policy)
    if policy is None or isinstance(policy, UniformPolicy):
        raise ValueError("policy_summary is for heterogeneous policies")
    modes: dict[str, list[int]] = {}
    for nm in policy.policies():
        modes.setdefault(nm.mode, []).append(nm.border)
    parts = []
    for mode, borders in modes.items():
        if mode == "exact":
            parts.append("exact")
            continue
        short = mode.removeprefix("amr_")
        lo, hi = min(borders), max(borders)
        parts.append(f"{short} b{lo}" + (f"-b{hi}" if hi != lo else ""))
    n_l = len(policy.layers) + len({k for k, _, _ in policy.layer_sites})
    n_s = len(policy.sites)
    cov = f"{n_l}l" + (f"+{n_s}s" if n_s else "")
    return f"perlayer[{cov}: {'; '.join(parts)}]"

"""Symmetric int8 quantization for approximate-multiplier matmuls.

The AMR-MUL LUT operates on int8 operands (2 MRSD digits); activations and
weights are quantized symmetrically per-tensor or per-channel, multiplied
approximately in the integer domain, and rescaled. Scales use absmax over
the reduction-relevant axis; all ops are jit/vmap/pjit-safe.

Training note: ``jnp.round`` has zero derivative, which would cut gradients
through every approximate matmul (QAT 101). ``quantize_int8_ste`` is the
straight-through form — forward is the quantized value, backward passes the
identity — matching how approximate-hardware-aware training is actually
done (the forward models the AMR-MUL circuit; the backward is a surrogate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def _absmax_scale(x, axis, eps):
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, eps) / INT8_MAX


def quantize_int8(x: jnp.ndarray, axis=None, eps: float = 1e-8):
    """Symmetric absmax quantization (hard int8; zero gradient through q).

    axis=None -> per-tensor scale; axis=k -> scale reduced over axis k
    (per-channel over the remaining dims). Returns (q_int8, scale) with
    x ~= q * scale.
    """
    scale = _absmax_scale(x, axis, eps)
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX - 1, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_int8_ste(x: jnp.ndarray, axis=None, eps: float = 1e-8):
    """Straight-through quantization: float values on the int8 grid.

    Returns (q_float, scale): q_float holds exact int8 values in f32 with
    d(q_float)/dx == 1/scale (identity through round/clip).
    """
    scale = _absmax_scale(x, axis, eps)
    xs = x.astype(jnp.float32) / scale
    q = jnp.clip(jnp.round(xs), -INT8_MAX - 1, INT8_MAX)
    q = xs + jax.lax.stop_gradient(q - xs)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale

"""Approximate matmul modes — the AMR-MUL as a NN numerics policy.

Modes (DESIGN.md §2/§3; docs/numerics.md has the full dispatch table):
  exact        — jnp.einsum in the requested dtype (baseline).
  amr_lut      — bit-exact AMR-MUL semantics per scalar product: int8
                 quantize, per-element gather from the 256x256 LUT,
                 accumulate in int32. Paper-faithful; VPU-bound on TPU.
                 The ORACLE the other integer paths are asserted against
                 (small shapes only: it materializes (.., M, K, N)).
  amr_inject   — on-device error injection: the SAME bit-exact products as
                 amr_lut, computed by replaying the reduction circuit
                 (engine.CompiledInjector) on the actual quantized operands
                 inside the jit trace — works for ANY reduction.Schedule,
                 including DSE candidate assignments with no materialized
                 LUT (numerics.schedule_ref), and trains through an STE
                 backward. K-chunked accumulation keeps memory flat.
  amr_lowrank  — beyond-paper MXU form: C = (A@B + U(A)@V(B)) * scales,
                 rank-r SVD factors of the LUT error table. rank=256 is
                 bit-equivalent to amr_lut up to fp32 accumulation.
  amr_noise    — training-scale surrogate: exact matmul + Gaussian error
                 with moments matched to the measured AMR-MUL error table
                 (paper Fig. 6 shows the relative error is ~Gaussian, mu~0).
                 Noise decorrelates across call sites / layers / steps via
                 numerics.context (site labels + the ambient scope).
  amr_kernel   — the production Pallas kernel path (kernels/amr_matmul):
                 low-rank MXU kernel at numerics.rank, or the bit-exact
                 full-table LUT-gather kernel when rank == 0. Compiled on
                 real TPU backends, interpreter mode on CPU/GPU
                 (REPRO_PALLAS_INTERPRET overrides; kernels/pallas_config).

All functions take A: (..., M, K) and B: (K, N) **or** a batched
B: (..., K, N) whose leading dims broadcast against A's — the weight-matmul
form dense layers consume, and the activation×activation form attention
scores (QK^T), attention-value contraction (PV), the MoE expert grouped
matmul and the SSD scan readout consume.  Quantization is always per-row
of A (axis=-1) and per-column of B (axis=-2 — identical to axis=0 for the
2-D weight form), so a batched call is bit-identical to stacking the
per-group un-batched calls.  jit/pjit-safe; the LUT and factors are
closed-over constants (baked into the executable), pulled from
core/lut.py's process-level caches — never rebuilt per call site.

Dispatch goes through the mode REGISTRY (numerics/registry.py): each
``matmul_amr_*`` registers ``(name, impl, required_params)`` at the bottom
of this module, ``AMRNumerics`` validates mode/params against the registry
at construction, and ``MODES`` is derived from it — external callers never
string-match mode names.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from . import registry
from .context import current_scope, noise_key
from .quant import quantize_int8, quantize_int8_ste

# A registered mode name — see numerics.registry.mode_names()
Mode = str


def __getattr__(name: str):
    # MODES stays importable (`from repro.numerics import MODES`) but is
    # derived from the registry, so late registrations are never stale.
    if name == "MODES":
        return registry.mode_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class AMRNumerics:
    """Policy object threaded through models; hashable/static for jit.

    Construction validates ``mode`` and its required parameters against the
    mode registry — an invalid policy fails HERE with a message naming the
    valid modes, not deep inside a jit trace.
    """

    mode: Mode = "exact"
    border: int = 8          # approximate border column (paper Table I/II)
    rank: int = 8            # low-rank error rank (amr_lowrank/amr_kernel; 0 in
                             # amr_kernel mode selects the full-LUT variant)
    noise_seed: int = 0
    # amr_inject: handle of a registered custom schedule (DSE candidate);
    # None = the paper's default schedule for (n_digits=2, border).  Handles
    # come from numerics.injection.register_schedule (process-level registry
    # — the policy itself must stay hashable for jit).
    schedule_ref: str | None = None
    # amr_inject implementation: "xla" (outer-product replay in the trace),
    # "pallas" (kernels/inject_replay), or None = backend autodetect with
    # the REPRO_INJECT_IMPL env override (kernels/pallas_config).
    inject_impl: str | None = None

    def __post_init__(self):
        registry.validate_policy(self)

    def is_exact(self) -> bool:
        return self.mode == _EXACT_SPEC.name


def _lut_constants(border: int):
    return lut_lib.table_array(border)


def _lowrank_constants(border: int, rank: int):
    return lut_lib.factor_arrays(border, rank)


def _noise_constants(border: int) -> tuple[float, float]:
    s = lut_lib.error_stats(border)
    return s["mean"], s["std"]


def matmul_exact(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.matmul(a, b)


def _lut_matmul(a: jnp.ndarray, b: jnp.ndarray, table, max_abs: int,
                what: str, quantizer=quantize_int8) -> jnp.ndarray:
    """Shared LUT-gather matmul core: quantize, gather, int32-accumulate.

    ``quantizer`` selects the int8 front end: ``quantize_int8`` (hard int8,
    the amr_lut mode) or ``quantize_int8_ste`` (float-on-the-int8-grid —
    what the inject path uses; its audit oracle must quantize IDENTICALLY,
    bf16 inputs round differently through the two forms).

    Raises ``ValueError`` at trace time when the contraction length could
    saturate the int32 accumulator (K * max|product| >= 2**31) — the same
    guard ``injection.injected_matmul_int`` applies, so oracle and injected
    path reject exactly the same shapes instead of silently wrapping.
    """
    k = a.shape[-1]
    if k * max_abs >= 2**31:
        raise ValueError(
            f"{what} int32 accumulator can saturate: K={k} with "
            f"max|product|={max_abs} gives K*max|product| = {k * max_abs} "
            f">= 2**31 = {2**31}; keep K <= {(2**31 - 1) // max_abs} "
            f"(or split the contraction before the matmul)")
    qa, sa = quantizer(a, axis=-1)               # per-row scale (..., M, 1)
    qb, sb = quantizer(b, axis=-2)               # per-col scale (..., 1, N)
    ia = jax.lax.stop_gradient(qa).astype(jnp.int32) + 128  # (..., M, K)
    ib = jax.lax.stop_gradient(qb).astype(jnp.int32) + 128  # (..., K, N)
    # the index arrays broadcast their (possibly batched) leading dims
    prods = table[ia[..., :, :, None], ib[..., None, :, :]]  # (..., M, K, N)
    acc = prods.sum(axis=-2).astype(jnp.float32)
    return acc * sa * sb


def matmul_amr_lut(a: jnp.ndarray, b: jnp.ndarray, border: int) -> jnp.ndarray:
    """Bit-exact AMR-MUL matmul via LUT gather (oracle; small shapes only)."""
    return _lut_matmul(a, b, _lut_constants(border),
                       lut_lib.table_max_abs(border),
                       f"amr_lut(border={border})")


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def matmul_amr_lowrank(a: jnp.ndarray, b: jnp.ndarray, border: int, rank: int) -> jnp.ndarray:
    """MXU formulation of AMR-MUL semantics (§Perf cell P, iteration 3).

    Forward: augmented-K single dot (same lane layout as kernels/amr_matmul)
    — per k the contraction lanes are [exact, err_1..err_r] on BOTH sides,
    ONE matmul over K*(1+r) with bf16 error lanes (int8-grid exact lanes are
    bf16-exact). No f32 (K,N,r) correction tensor materialises/reshards.

    Backward (custom_vjp): plain full-precision matmul vjp — the explicit
    straight-through surrogate. Guarantees the (1+r)x flops are paid ONLY on
    the forward pass instead of hoping XLA DCEs dead augmented-lane grads.
    """
    return _lowrank_fwd(a, b, border, rank)[0]


def _lowrank_fwd(a, b, border, rank):
    u, v = _lowrank_constants(border, rank)
    qa, sa = quantize_int8_ste(a, axis=-1)
    qb, sb = quantize_int8_ste(b, axis=-2)
    ia = jax.lax.stop_gradient(qa).astype(jnp.int32) + 128
    ib = jax.lax.stop_gradient(qb).astype(jnp.int32) + 128
    K = a.shape[-1]
    ua = u[ia].astype(jnp.bfloat16)              # (..., M, K, r) 1-D LUTs
    vb = v[ib].astype(jnp.bfloat16)              # (..., K, N, r)
    a_aug = jnp.concatenate([qa[..., None].astype(jnp.bfloat16), ua], axis=-1)
    a_aug = a_aug.reshape(*a.shape[:-1], K * (1 + rank))
    b_aug = jnp.concatenate([qb[..., :, None, :].astype(jnp.bfloat16),
                             jnp.moveaxis(vb, -1, -2)], axis=-2)
    b_aug = b_aug.reshape(*b.shape[:-2], K * (1 + rank), b.shape[-1])
    out = jnp.matmul(a_aug, b_aug, preferred_element_type=jnp.float32)
    return out * sa * sb, (a, b)


def _reduce_to_shape(g: jnp.ndarray, shape: tuple) -> jnp.ndarray:
    """Sum a gradient down to ``shape`` (undo matmul leading-dim broadcast)."""
    if g.shape == tuple(shape):
        return g
    extra = g.ndim - len(shape)
    if extra:
        g = g.sum(axis=tuple(range(extra)))
    keep = tuple(i for i, (gd, sd) in enumerate(zip(g.shape, shape))
                 if gd != sd)
    return g.sum(axis=keep, keepdims=True) if keep else g


def _lowrank_bwd(border, rank, res, g):
    a, b = res
    ga = jnp.matmul(g, jnp.swapaxes(b, -1, -2).astype(g.dtype))
    gb = jnp.matmul(jnp.swapaxes(a, -1, -2).astype(g.dtype), g) \
        if b.ndim > 2 else \
        jnp.matmul(a.reshape(-1, a.shape[-1]).T.astype(g.dtype),
                   g.reshape(-1, g.shape[-1]))
    return (_reduce_to_shape(ga, a.shape).astype(a.dtype),
            _reduce_to_shape(gb, b.shape).astype(b.dtype))


matmul_amr_lowrank.defvjp(_lowrank_fwd, _lowrank_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def matmul_amr_kernel(a: jnp.ndarray, b: jnp.ndarray, border: int, rank: int) -> jnp.ndarray:
    """Pallas-kernel-backed AMR matmul (the servable hot path).

    Forward: kernels/amr_matmul — low-rank MXU kernel at ``rank``, or the
    bit-exact full-table gather kernel when ``rank == 0``; tiling and
    interpret mode resolve per backend (autotune table + autodetect).
    Backward: the same straight-through full-precision surrogate as
    amr_lowrank, so serving and training share one policy surface.
    """
    return _kernel_fwd(a, b, border, rank)[0]


def _kernel_fwd(a, b, border, rank):
    from repro.kernels.amr_matmul.ops import (amr_matmul,  # lazy: pkg cycle
                                              amr_matmul_grouped)

    if b.ndim == 2:
        a2 = a.reshape(-1, a.shape[-1])
        out = amr_matmul(a2, b, border=border, rank=max(rank, 1),
                         method="lut" if rank == 0 else "lowrank")
        return out.reshape(*a.shape[:-1], b.shape[-1]), (a, b)
    # activation×activation form: B carries leading batch dims.  rank == 0
    # runs the grouped full-LUT Pallas kernel (one grid axis per group —
    # the MoE grouped-matmul variant, docs/kernels.md); rank > 0 falls back
    # to the XLA augmented-K batched matmul, the same math the low-rank
    # kernel implements per block.
    a3, b3, lead = _broadcast_groups(a, b)
    if rank == 0:
        out = amr_matmul_grouped(a3, b3, border=border)
    else:
        out = _lowrank_fwd(a3, b3, border, rank)[0]
    return out.reshape(*lead, a.shape[-2], b.shape[-1]), (a, b)


def _broadcast_groups(a: jnp.ndarray, b: jnp.ndarray):
    """Broadcast A/B leading dims together and flatten them to one group
    axis: (..., M, K), (..., K, N) -> (G, M, K), (G, K, N), lead-shape."""
    lead = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
    a3 = jnp.broadcast_to(a, (*lead, *a.shape[-2:]))
    b3 = jnp.broadcast_to(b, (*lead, *b.shape[-2:]))
    g = math.prod(lead) if lead else 1
    return (a3.reshape(g, *a.shape[-2:]), b3.reshape(g, *b.shape[-2:]), lead)


matmul_amr_kernel.defvjp(_kernel_fwd, _lowrank_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul_amr_inject(a: jnp.ndarray, b: jnp.ndarray, numerics: "AMRNumerics") -> jnp.ndarray:
    """On-device error injection: exact per-sample AMR products of the
    actual quantized operands, for ANY schedule (docs/numerics.md).

    Forward: quantize (STE), replay the reduction circuit on-device for the
    operand pairs of this matmul, rescale — bit-identical to the
    ``matmul_amr_lut`` oracle when the schedule matches, but never
    materializes a 256x256 LUT or the (.., M, K, N) product tensor, and
    accepts DSE candidate schedules via ``numerics.schedule_ref``.  The
    replay runs either as XLA ops in the surrounding trace
    (``injection.injected_matmul_int``, row+K-chunked) or as the Pallas
    injection-replay kernel (``kernels/inject_replay``), selected by
    ``numerics.inject_impl`` (None = backend autodetect, docs/kernels.md);
    both share the weight-side bit-pack and are bit-identical.

    Backward: the straight-through full-precision surrogate shared with
    amr_lowrank/amr_kernel, so a searched design point can be dropped
    straight into ``train_step`` and its real loss impact measured.
    """
    return _inject_fwd(a, b, numerics)[0]


def _inject_fwd(a, b, numerics):
    from repro.kernels.pallas_config import resolve_inject_impl  # lazy:
    from . import injection  # keeps module import light / breaks pkg cycle

    inj = injection.get_injector(numerics)
    qa, sa = quantize_int8_ste(a, axis=-1)
    qb, sb = quantize_int8_ste(b, axis=-2)
    ia = jax.lax.stop_gradient(qa).astype(jnp.int32) + 128  # (..., M, K)
    ib = jax.lax.stop_gradient(qb).astype(jnp.int32) + 128  # (..., K, N)
    handle = numerics.schedule_ref  # None = default design point (self-labels)
    if ib.ndim > 2:
        # activation×activation form: the B operand is traced and batched,
        # so there is no reusable weight pack — injection's grouped route
        # lane-packs each group on the fly inside the trace (same replay,
        # same int32-saturation guard; injection.injected_matmul_grouped).
        ia3, ib3, lead = _broadcast_groups(ia, ib)
        acc = injection.injected_matmul_grouped(
            inj, ia3, ib3, schedule=handle,
            impl=resolve_inject_impl(numerics.inject_impl))
        acc = acc.reshape(*lead, ia.shape[-2], ib.shape[-1])
    elif resolve_inject_impl(numerics.inject_impl) == "pallas":
        from repro.kernels.inject_replay import inject_replay_matmul

        acc = inject_replay_matmul(inj, ia, ib, schedule=handle)  # int32, exact
    else:
        acc = injection.injected_matmul_int(inj, ia, ib,
                                            schedule=handle)      # int32, exact
    return acc.astype(jnp.float32) * sa * sb, (a, b)


def _inject_bwd(numerics, res, g):
    return _lowrank_bwd(None, None, res, g)  # same STE surrogate


matmul_amr_inject.defvjp(_inject_fwd, _inject_bwd)


# Exported product tables of registered custom schedules, keyed by handle —
# same lifetime/keying as injection's per-handle injector cache.
_ORACLE_TABLES: dict[str, tuple] = {}


def _inject_oracle(a: jnp.ndarray, b: jnp.ndarray, numerics: "AMRNumerics") -> jnp.ndarray:
    """LUT-gather reference of the amr_inject products (the audit oracle).

    Gathers from a product table built INDEPENDENTLY of the on-device
    replay — ``core/lut``'s (2, border) table for the paper-default
    schedule, or ``dse.lut_from_schedule`` for a registered DSE candidate
    (``numerics.schedule_ref``) — so a zero audit diff proves the injector's
    circuit replay bit-identical to the tabulated multiplier, not merely
    self-consistent.  Quantizes with the SAME ``quantize_int8_ste`` front
    end as ``_inject_fwd``: on bf16 activations the hard-int8 form rounds
    in bf16 and would feed the table different operands.
    """
    if numerics.schedule_ref is None:
        table = _lut_constants(numerics.border)
        max_abs = lut_lib.table_max_abs(numerics.border)
        what = f"amr_inject(border={numerics.border}) oracle"
    else:
        table, max_abs = _oracle_table(numerics)
        what = f"amr_inject[{numerics.schedule_ref}] oracle"
    return _lut_matmul(a, b, table, max_abs, what, quantizer=quantize_int8_ste)


def _oracle_table(numerics):
    cached = _ORACLE_TABLES.get(numerics.schedule_ref)
    if cached is None:
        import numpy as np

        from repro.core.dse.export import lut_from_schedule  # lazy: pkg cycle
        from . import injection

        tab = lut_from_schedule(injection.resolve_schedule(numerics))
        with jax.ensure_compile_time_eval():
            cached = (jnp.asarray(tab, jnp.int32), int(np.abs(tab).max()))
        _ORACLE_TABLES[numerics.schedule_ref] = cached
    return cached


def _key_batch(key: jax.Array) -> int | None:
    """Leading batch size of a batched PRNG key array, or None for one key.

    ``noise_key`` returns a BATCH of keys when the ambient scope's step is a
    per-request position vector (slot-batched decode, serve/engine.py): one
    key per request, so each slot's noise stream depends only on ITS OWN
    decode position — batched decode draws the same noise a solo decode of
    that request would.
    """
    try:
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            return key.shape[0] if key.ndim else None
    except (AttributeError, TypeError):
        pass
    return key.shape[0] if key.ndim > 1 else None  # raw uint32 keys: (B, 2)


def matmul_amr_noise(a: jnp.ndarray, b: jnp.ndarray, border: int, key: jax.Array) -> jnp.ndarray:
    """Surrogate: exact matmul + error noise with AMR-MUL-matched moments.

    Per-element product error has mean mu and std sigma (from the LUT);
    a K-length accumulation contributes N(K*mu, sqrt(K)*sigma) in the int8
    domain, rescaled by the quantization scales.

    ``key`` may be a batch of keys (one per leading-axis group of rows —
    per-request keys in slot-batched decode); each group then draws from
    its own stream, decorrelating noise per request.
    """
    mu, sigma = _noise_constants(border)
    qa, sa = quantize_int8_ste(a, axis=-1)
    qb, sb = quantize_int8_ste(b, axis=-2)
    k = a.shape[-1]
    exact = jnp.matmul(qa, qb)
    nb = _key_batch(key)
    if nb is None:
        draw = jax.random.normal(key, exact.shape)
    else:
        rows = math.prod(exact.shape[:-1])
        if rows % nb:
            raise ValueError(
                f"amr_noise got {nb} per-request keys but {rows} output rows "
                f"({exact.shape}); rows must divide evenly across requests")
        per = rows // nb
        draw = jax.vmap(lambda kk: jax.random.normal(kk, (per, exact.shape[-1])))(key)
        draw = draw.reshape(exact.shape)
    noise = mu * k + jnp.sqrt(float(k)) * sigma * draw
    return (exact + noise) * sa * sb


def approx_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    numerics: "AMRNumerics | None" = None,
    *,
    key: jax.Array | None = None,
    site: str | None = None,
) -> jnp.ndarray:
    """Dispatch a matmul under the given numerics policy (None = exact).

    ``numerics`` may be a single ``AMRNumerics`` or any ``NumericsPolicy``
    resolver (numerics/policy.py) — the latter resolves HERE, at trace
    time, against the static ``site`` label and the ambient scope's
    ``static_layer`` coordinate, so per-layer heterogeneous policies bake
    into the trace with zero run-time dispatch.

    ``site`` is a static call-site label (e.g. ``"mlp.w_gate"``); together
    with the ambient ``numerics_scope`` (step / layer) it decorrelates the
    amr_noise PRNG stream per call site, layer and training step — an
    explicit ``key`` overrides the derivation entirely.

    Dispatch is registry-driven: ``numerics.mode`` selects the impl
    registered in ``numerics.registry`` (modes were validated when the
    policy was constructed).

    When the ambient scope carries an AUDIT channel
    (``numerics_scope(audit=AuditTrace())``), a reference is evaluated
    alongside the impl and the per-site (and, when a layer coordinate is in
    scope, per-(site, layer)) diff recorded at run time via
    ``jax.debug.callback`` — read the trace after ``jax.effects_barrier()``.
    The default ``AuditTrace(compare="oracle")`` diffs against the mode's
    bit-exact ``oracle`` in product-grid steps (the conformance matrix's
    inject-vs-LUT bit-identity proof); ``AuditTrace(compare="exact")``
    diffs against the exact float matmul and accumulates error mass (the
    model-level policy search's sensitivity probe).
    """
    scope = current_scope()
    if numerics is not None and not isinstance(numerics, AMRNumerics):
        numerics = numerics.resolve(site, scope.static_layer)
    if scope.shape_probe is not None:
        # static trace-time record (works under jax.eval_shape): the
        # saturation proof in repro.analysis collects every site's K here
        scope.shape_probe.append({
            "site": site or "<unlabeled>",
            "k": int(a.shape[-1]),
            "mode": "exact" if numerics is None else numerics.mode,
            "schedule": getattr(numerics, "schedule_ref", None),
        })
    if numerics is None or numerics.is_exact():
        return matmul_exact(a, b)
    spec = registry.get_mode(numerics.mode)
    out = spec.impl(a, b, numerics, key=key, site=site)
    audit = scope.audit
    if audit is not None:
        diff = mass = None
        if getattr(audit, "compare", "oracle") == "exact":
            err = jnp.abs(out.astype(jnp.float32)
                          - matmul_exact(a, b).astype(jnp.float32))
            diff, mass = jnp.max(err), jnp.sum(err)
        elif spec.oracle is not None:
            ref = spec.oracle(a, b, numerics)
            diff = _grid_diff(out, ref, a, b)
            mass = diff
        if diff is not None:
            cb = partial(audit.record, site or "<unlabeled>")
            if scope.layer is not None:
                jax.debug.callback(
                    lambda d, m, layer: cb(d, layer=layer, mass=m),
                    diff, mass, scope.layer)
            else:
                jax.debug.callback(lambda d, m: cb(d, mass=m), diff, mass)
    return out


def _grid_diff(out, ref, a, b):
    """Max |out - ref| in integer-product-grid steps (audit metric).

    Audited modes share one quantization convention (per-row scales of A,
    per-column scales of B); impl and oracle outputs are both
    ``float(acc) * sa * sb`` with bitwise-identical scales, so any REAL
    semantic difference is >= 1 step on the int32 accumulator grid.
    Comparing after dividing the scales back out makes the audit immune to
    XLA compiling the two (mathematically identical) rescale chains with
    different FMA contraction — observed ~1-ulp float noise that is not a
    numerics difference.  Sub-quantum float noise rounds to 0.0; a genuine
    product mismatch records >= 1.0.  (The reconstruction is exact while
    |acc| < 2**24, i.e. for oracle-sized shapes — the regime the
    conformance matrix audits.)
    """
    quantum = quantize_int8(a, axis=-1)[1] * quantize_int8(b, axis=-2)[1]
    return jnp.max(jnp.abs(jnp.round(out / quantum) - jnp.round(ref / quantum)))


# --------------------------------------------------------------------------
# mode registration — canonical order; this block IS the MODES list
# --------------------------------------------------------------------------

def _require_border(nm) -> None:
    if not isinstance(nm.border, int) or nm.border < 0:
        raise ValueError(
            f"numerics mode {nm.mode!r} needs a non-negative integer border, "
            f"got {nm.border!r}")


def _validate_rank(nm, *, minimum: int) -> None:
    _require_border(nm)
    if not isinstance(nm.rank, int) or nm.rank < minimum:
        raise ValueError(
            f"numerics mode {nm.mode!r} needs an integer rank >= {minimum}, "
            f"got {nm.rank!r}")


def _validate_inject(nm) -> None:
    _require_border(nm)
    if nm.inject_impl is not None:
        from repro.kernels.pallas_config import INJECT_IMPLS  # lazy: pkg cycle

        if nm.inject_impl not in INJECT_IMPLS:
            raise ValueError(
                f"inject_impl must be one of {INJECT_IMPLS} (or None = "
                f"backend autodetect), got {nm.inject_impl!r}")
    if nm.schedule_ref is not None and not isinstance(nm.schedule_ref, str):
        raise ValueError(
            f"schedule_ref must be a registered-schedule handle (str) or "
            f"None, got {nm.schedule_ref!r}")


_EXACT_SPEC = registry.register_mode(
    "exact", lambda a, b, nm, *, key=None, site=None: matmul_exact(a, b),
    description="jnp.einsum in the requested dtype (baseline)", exact=True)

registry.register_mode(
    "amr_lut",
    lambda a, b, nm, *, key=None, site=None: matmul_amr_lut(a, b, nm.border),
    required_params=("border",), validate=_require_border,
    description="bit-exact LUT-gather oracle (small shapes)")

registry.register_mode(
    "amr_inject",
    lambda a, b, nm, *, key=None, site=None: matmul_amr_inject(a, b, nm),
    required_params=("border",), validate=_validate_inject,
    oracle=_inject_oracle,
    accepts_params=("schedule_ref", "inject_impl"),
    description="on-device exact error injection (any schedule)")

registry.register_mode(
    "amr_lowrank",
    lambda a, b, nm, *, key=None, site=None: matmul_amr_lowrank(
        a, b, nm.border, nm.rank),
    required_params=("border", "rank"),
    validate=partial(_validate_rank, minimum=1),
    defaults={"rank": 4},
    description="MXU low-rank error factorization")

registry.register_mode(
    "amr_noise",
    lambda a, b, nm, *, key=None, site=None: matmul_amr_noise(
        a, b, nm.border,
        key if key is not None else noise_key(nm.noise_seed, site)),
    required_params=("border", "noise_seed"), validate=_require_border,
    description="Gaussian surrogate with AMR-matched moments")

registry.register_mode(
    "amr_kernel",
    lambda a, b, nm, *, key=None, site=None: matmul_amr_kernel(
        a, b, nm.border, nm.rank),
    required_params=("border", "rank"),
    validate=partial(_validate_rank, minimum=0),
    defaults={"rank": 0},
    description="Pallas kernel path (rank 0 = full-LUT variant)")

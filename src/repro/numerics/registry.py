"""Numerics-mode registry: the single source of truth for dispatch.

``approx_matmul`` used to end in a hand-maintained 6-way ``if/elif`` over
mode-name strings, mirrored by a ``MODES`` tuple and by ``choices=`` lists
in every launcher — three surfaces that could drift independently.  This
module replaces all of them: each ``matmul_amr_*`` implementation registers
itself as a :class:`ModeSpec` via :func:`register_mode`, ``AMRNumerics``
validates its mode/params against the registry at construction, and
everything that needs the list of valid modes (dispatch, CLI ``choices``,
error messages, docs tables) derives it from :func:`mode_names`.

External callers NEVER match mode-name strings: models, serving, benches
and launchers dispatch only through ``approx_matmul`` and build their CLI
surfaces from the registry (``launch/cli.py``).

Registered impls share one calling convention::

    impl(a, b, numerics, *, key=None, site=None) -> jnp.ndarray

where ``a: (..., M, K)``, ``b: (K, N)``, ``numerics`` is the (validated)
policy object, and ``key``/``site`` feed the amr_noise PRNG derivation
(ignored by deterministic modes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["ModeSpec", "register_mode", "unregister_mode", "get_mode",
           "mode_names", "is_exact_mode", "validate_policy", "default_policy"]

Impl = Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class ModeSpec:
    """One numerics mode: name, implementation, and its parameter contract.

    ``required_params`` are ``AMRNumerics`` field names that must be
    non-None for this mode; ``validate`` is an optional extra check run at
    policy construction (raise ``ValueError`` with a clear message).

    ``oracle`` is an optional bit-exact reference implementation
    ``(a, b, numerics) -> ndarray`` of the same product semantics — when a
    ``numerics_scope(audit=AuditTrace())`` is active, ``approx_matmul``
    evaluates it alongside ``impl`` at every call site and records the
    per-site max-abs-diff (the conformance matrix's inject-vs-LUT
    bit-identity proof rides on this hook).

    ``defaults`` are mode-declared default parameter values (field -> value)
    applied by :func:`default_policy` — how generic consumers (the
    conformance matrix, benches) construct a representative policy for ANY
    registered mode without string-matching mode names.  ``accepts_params``
    names the AMRNumerics fields the mode meaningfully consumes beyond its
    required ones; :func:`default_policy` silently drops overrides for
    fields a mode ignores, so one caller-side kwargs dict serves every mode.
    """

    name: str
    impl: Impl
    required_params: tuple[str, ...] = ()
    description: str = ""
    validate: Callable[[Any], None] | None = None
    oracle: Impl | None = None
    defaults: tuple[tuple[str, Any], ...] = ()
    accepts_params: tuple[str, ...] = ()
    # True for modes whose impl IS the exact float matmul (no approximation).
    # Generic consumers branch on this property via :func:`is_exact_mode`
    # instead of string-matching the mode name (lint rule RPL001).
    exact: bool = False


# Registration order is preserved — it defines the canonical MODES order
# shown in CLIs, error messages and docs.
_REGISTRY: dict[str, ModeSpec] = {}


def register_mode(
    name: str,
    impl: Impl,
    *,
    required_params: tuple[str, ...] = (),
    description: str = "",
    validate: Callable[[Any], None] | None = None,
    oracle: Impl | None = None,
    defaults: dict[str, Any] | None = None,
    accepts_params: tuple[str, ...] = (),
    exact: bool = False,
) -> ModeSpec:
    """Register a numerics mode. Names are unique — re-registration is an
    error (use :func:`unregister_mode` first if a test needs to replace
    one), so a typo'd duplicate can never silently shadow a real mode."""
    if not name or not isinstance(name, str):
        raise ValueError(f"mode name must be a non-empty string, got {name!r}")
    if name in _REGISTRY:
        raise ValueError(
            f"numerics mode {name!r} is already registered; "
            f"unregister_mode({name!r}) first to replace it")
    spec = ModeSpec(name=name, impl=impl, required_params=tuple(required_params),
                    description=description, validate=validate, oracle=oracle,
                    defaults=tuple(sorted((defaults or {}).items())),
                    accepts_params=tuple(accepts_params), exact=exact)
    _REGISTRY[name] = spec
    return spec


def unregister_mode(name: str) -> None:
    """Remove a registered mode (test hook; no-op if absent)."""
    _REGISTRY.pop(name, None)


def mode_names() -> tuple[str, ...]:
    """Valid mode names, in registration (canonical) order."""
    return tuple(_REGISTRY)


def is_exact_mode(name: str) -> bool:
    """Whether a registered mode's impl is the exact float matmul.

    The registry-driven replacement for ``mode != "exact"`` comparisons in
    generic consumers (benches, sweep builders) — mode-name string matching
    outside ``numerics/`` is a lint violation (RPL001)."""
    return get_mode(name).exact


def get_mode(name: str) -> ModeSpec:
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown numerics mode {name!r}; valid modes: {mode_names()}")
    return spec


def validate_policy(numerics: Any) -> None:
    """Validate a numerics policy against the registry.

    Accepts a single ``AMRNumerics`` (called from its ``__post_init__`` so
    an invalid policy fails at construction with a message naming the valid
    modes / the offending parameter — not deep inside a jit trace) OR any
    :class:`~repro.numerics.policy.NumericsPolicy` resolver, in which case
    EVERY distinct entry it can resolve to (``policies()``) is validated.
    """
    entries = numerics.policies() if hasattr(numerics, "policies") else (numerics,)
    for nm in entries:
        spec = get_mode(nm.mode)
        for p in spec.required_params:
            if getattr(nm, p, None) is None:
                raise ValueError(
                    f"numerics mode {nm.mode!r} requires parameter {p!r} "
                    f"(got None); required params: {spec.required_params}")
        if spec.validate is not None:
            spec.validate(nm)


def default_policy(mode: str, **overrides: Any) -> Any:
    """Construct a representative ``AMRNumerics`` for ``mode`` from its
    registry-declared defaults — the registry-driven replacement for the
    mode-name ``if/elif`` ladders generic consumers (conformance matrix,
    benches) used to hand-maintain.

    ``overrides`` may name ANY parameter a caller passes for other modes;
    fields the mode neither requires, defaults, nor declares in
    ``accepts_params`` are silently dropped (a custom registered mode then
    flows through such callers with no caller edits), and ``None`` values
    are dropped too (mode defaults win over an unset caller slot).
    """
    from .approx_matmul import AMRNumerics  # lazy: registry loads first

    spec = get_mode(mode)
    kwargs: dict[str, Any] = dict(spec.defaults)
    accepted = set(spec.required_params) | set(spec.accepts_params) | set(
        k for k, _ in spec.defaults)
    for k, v in overrides.items():
        if k in accepted and v is not None:
            kwargs[k] = v
    return AMRNumerics(mode=mode, **kwargs)

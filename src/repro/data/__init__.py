"""Data pipeline: deterministic synthetic LM stream + memmap corpus loader."""
from .pipeline import MemmapDataset, SyntheticLM

__all__ = ["SyntheticLM", "MemmapDataset"]

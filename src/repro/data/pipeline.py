"""Data pipeline.

``SyntheticLM`` — stateless, index-addressable batches (batch i is a pure
function of (seed, i)): restarts and elastic resharding resume mid-stream
with no iterator state to checkpoint. Sequences follow a noisy affine
recurrence over the vocab, so models *can* learn them — the quickstart
example shows a real loss drop, not noise.

``MemmapDataset`` — packed uint16/uint32 token files, windowed without
copying (np.memmap); per-host sharding by process index for multi-host.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    noise: float = 0.05
    n_hosts: int = 1
    host_id: int = 0

    def batch_at(self, index: int) -> dict[str, np.ndarray]:
        """Deterministic batch for global step ``index`` (host's slice)."""
        rng = np.random.default_rng((self.seed, index, self.host_id))
        b = self.batch // self.n_hosts
        a = 6364136223846793005 % self.vocab or 5
        c = 1442695040888963407 % self.vocab or 7
        x0 = rng.integers(0, self.vocab, (b, 1))
        toks = [x0]
        for _ in range(self.seq_len):
            nxt = (a * toks[-1] + c) % self.vocab
            flip = rng.random((b, 1)) < self.noise
            rand = rng.integers(0, self.vocab, (b, 1))
            toks.append(np.where(flip, rand, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)
        return {"tokens": seq[:, : self.seq_len], "targets": seq[:, 1 : self.seq_len + 1]}

    def __iter__(self):
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


@dataclasses.dataclass
class MemmapDataset:
    path: str | Path
    seq_len: int
    batch: int
    dtype: str = "uint16"
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // self.seq_len

    def batch_at(self, index: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, index, self.host_id))
        b = self.batch // self.n_hosts
        starts = rng.integers(0, self._n_windows, b) * self.seq_len
        toks = np.stack([self._data[s : s + self.seq_len + 1] for s in starts])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1

"""Architecture configs (one module per assigned arch) + shape registry."""
from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from .registry import (
    ALL_NAMES,
    ARCH_NAMES,
    families,
    family_of,
    get_config,
    get_reduced_config,
)
from .validation import validate_config

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "ARCH_NAMES", "ALL_NAMES", "get_config", "get_reduced_config",
           "family_of", "families", "validate_config"]

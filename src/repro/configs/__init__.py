"""Architecture configs (one module per assigned arch) + shape registry."""
from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from .registry import ARCH_NAMES, get_config, get_reduced_config

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "ARCH_NAMES", "get_config", "get_reduced_config"]

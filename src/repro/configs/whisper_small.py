"""whisper-small [audio] — enc-dec transformer backbone [arXiv:2212.04356].

Assignment: 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865 — realised as
12 encoder + 12 decoder layers (whisper-small structure). The conv/mel
frontend is a STUB per the assignment: ``input_specs`` supplies precomputed
1500-frame embeddings; the encoder is a bidirectional transformer over
them, the decoder cross-attends per layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,          # decoder depth (scan); encoder_layers below
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    mlp_act="gelu",
    encoder_layers=12,
    encoder_frames=1500,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, encoder_layers=2, encoder_frames=16)

"""minitron-8b [dense] — width/depth-pruned Nemotron-4 [arXiv:2407.14679].

Assignment: 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
(Source model uses squared-ReLU MLPs; we keep the zoo-uniform gated MLP and
note the substitution — structure/FLOPs are identical for roofline purposes.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    mlp_act="swiglu",
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256)

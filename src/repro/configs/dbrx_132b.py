"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

Assignment: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16e top-4.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752,
                  dispatch_shard="local"),
    mlp_act="swiglu",
    rope_theta=5e5,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64))

"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].

Assignment: 48L d_model=2048 16H (kv=16) d_ff=1408 vocab=163840,
MoE 64e top-6 (fine-grained experts). The source model additionally has
shared experts; omitted here (noted in DESIGN.md) — routing/compute shape
is dominated by the 64-way fine-grained experts.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  dispatch_shard="local"),
    mlp_act="swiglu",
    rope_theta=5e4,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab=256, moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32))

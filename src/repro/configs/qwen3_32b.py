"""qwen3-32b [dense] — qk_norm + GQA [hf:Qwen/Qwen3-8B family].

Assignment: 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
head_dim=128 (Qwen3 attention operates wider than d_model: 64*128=8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    mlp_act="swiglu",
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256)

"""Config system: model architecture + input-shape + numerics descriptors.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(one file per arch, exact constants from the assignment table). Shapes are
global (LM-family): train_4k / prefill_32k / decode_32k / long_500k.
``reduced()`` returns a tiny same-family config for CPU smoke tests; the
full config is only ever traced abstractly (dry-run, eval_shape).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

from repro.numerics import AMRNumerics, NumericsPolicy

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
AttnKind = Literal["full", "swa", "none"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int          # per-expert hidden size
    # dispatch-buffer sharding strategy (§Perf lever):
    #   "replicate" — (E,C,D) buffers unsharded (XLA gathers tokens; baseline)
    #   "batch"     — capacity dim C sharded on data axes (REFUTED in §Perf:
    #                 the global argsort misaligns slots with shards and XLA
    #                 falls back to dense all-reduces)
    #   "expert"    — expert parallelism: E sharded on "model" (all-to-all)
    #   "local"     — shard_map over the data axes: routing, sort and
    #                 capacity buffers are shard-local; experts TP on
    #                 "model" with one psum after w_down (no cross-DP
    #                 dispatch traffic by construction)
    dispatch_shard: str = "replicate"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    expand: int = 2           # d_inner = expand * d_model
    chunk: int = 256          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class LayerPattern:
    """Heterogeneous depth structure as repeated groups of block kinds.

    ``kinds`` is the per-layer mixer sequence inside one group, e.g.
    gemma3 = ('swa',)*5 + ('full',) repeated; zamba2 = ('ssm',)*5 + ('shared_attn',).
    The model scans over ``n_repeat`` stacked copies of the group.
    """

    kinds: tuple[str, ...]
    n_repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.kinds) * self.n_repeat


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0            # >0: width for 'swa' layers
    pattern: LayerPattern | None = None  # None -> homogeneous 'full' (or 'ssm')

    # family extensions
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    mlp_act: Literal["swiglu", "geglu", "gelu"] = "swiglu"

    # enc-dec (whisper): encoder consumes precomputed frame embeddings (stub)
    encoder_layers: int = 0
    encoder_frames: int = 0            # fixed encoder sequence (1500 for whisper)

    # vlm: prefix of precomputed patch embeddings (stub frontend)
    vision_prefix: int = 0

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # the paper's technique: numerics policy for matmuls — one AMRNumerics
    # design point everywhere (the legacy shorthand), or a site-resolved
    # NumericsPolicy (UniformPolicy / PerLayerPolicy, numerics/policy.py)
    # assigning per-layer / per-call-site design points.  Both are hashable
    # statics; launch/cli.py loads PerLayerPolicy artifacts (--policy-file).
    numerics: AMRNumerics | NumericsPolicy = AMRNumerics("exact")

    # which layers the mixer is (derived when pattern is None)
    default_mixer: str = "full"

    # remat policy for training: 'none' | 'block' (checkpoint each layer)
    remat: str = "block"

    # parameter sharding policy over the "data" axis (§Perf lever):
    #   'fsdp'  — params + optimizer state sharded (ZeRO-3): min memory,
    #             but weights re-gather EVERY microbatch
    #   'zero1' — optimizer state sharded, bf16 params replicated: gathers
    #             once per step at the update; needs params to fit HBM
    param_shard: str = "fsdp"

    # fully unroll layer scans when lowering (dry-run cost extraction: XLA's
    # cost_analysis counts while-loop bodies once, so the roofline lowering
    # unrolls; deployment lowering keeps the scan for small HLO)
    unroll_layers: bool = False

    def layer_kinds(self) -> tuple[str, ...]:
        if self.pattern is not None:
            return self.pattern.kinds * self.pattern.n_repeat
        return (self.default_mixer,) * self.n_layers

    def supports_long_context(self) -> bool:
        """True when the arch has a sub-quadratic sequence mechanism.

        SSM state is O(1) in seq; sliding-window layers cap their KV cache at
        the window. Hybrids qualify: their few full-attention applications
        decode linearly per step with a model-sharded KV cache (DESIGN.md
        §Arch-applicability). Pure full-attention archs are skipped.
        """
        kinds = set(self.layer_kinds())
        return ("ssm" in kinds) or ("swa" in kinds)

    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (enc-dec included)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
    # §Perf instrumentation shape (not an assigned cell): two microbatches
    # in one lowering, for marginal-vs-hoistable cost separation
    "train_4k_x2": ShapeConfig("train_4k_x2", 4096, 32, "train"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason-if-not) — DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, "pure full-attention arch: 500k dense KV cache is not deployable (DESIGN.md)"
    return True, ""

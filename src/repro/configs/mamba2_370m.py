"""mamba2-370m [ssm] — attention-free SSD (state-space duality)
[arXiv:2405.21060].

Assignment: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
Pure Mamba2 blocks (no MLP, matching the paper's architecture: the
expand-2 in-projection plays the FFN role).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,        # unused (attention-free); kept for config uniformity
    n_kv_heads=16,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    default_mixer="ssm",
    ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, expand=2),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, expand=2, chunk=16),
    )

"""gemma3-1b [dense] — 5:1 local:global attention, 128k-class context
[hf:google/gemma-3-1b-pt].

Assignment: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
Pattern realised as 2 scan groups of 13 layers (11 sliding-window + 2
global) = 26 layers at the source 5:1 ratio; window 512 per the model card.
"""
from repro.configs.base import LayerPattern, ModelConfig

_GROUP = ("swa",) * 5 + ("full",) + ("swa",) * 5 + ("full",) + ("swa",)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    sliding_window=512,
    pattern=LayerPattern(kinds=_GROUP, n_repeat=2),
    rope_theta=1e6,
    mlp_act="geglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, sliding_window=8,
        pattern=LayerPattern(kinds=("swa", "full"), n_repeat=2))

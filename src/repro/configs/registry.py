"""Arch registry: ``--arch <id>`` resolution for launcher/dryrun/benchmarks."""
from __future__ import annotations

import importlib

from .base import ModelConfig

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "mamba2-370m": "mamba2_370m",
    "qwen3-32b": "qwen3_32b",
    "gemma3-1b": "gemma3_1b",
    "minitron-8b": "minitron_8b",
    "gemma-2b": "gemma_2b",
    "dbrx-132b": "dbrx_132b",
    "moonshot-v1-16b-a3b": "moonshot_16b_a3b",
    "whisper-small": "whisper_small",
    "internvl2-76b": "internvl2_76b",
    "amr-paper-100m": "amr_paper",
}

ARCH_NAMES = [n for n in _MODULES if n != "amr-paper-100m"]  # the 10 assigned
ALL_NAMES = list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced_config(name: str) -> ModelConfig:
    return _module(name).reduced()


def family_of(name: str) -> str:
    """The workload family ('dense'/'ssm'/'hybrid'/'moe'/'audio'/'vlm') of a
    registered arch — read from its config, so registry and configs can
    never disagree."""
    return get_config(name).family


def families() -> dict[str, list[str]]:
    """All registered families -> arch names, in registry order (the
    conformance matrix's sweep axes derive from this, so a newly registered
    arch is swept automatically)."""
    out: dict[str, list[str]] = {}
    for n in ALL_NAMES:
        out.setdefault(family_of(n), []).append(n)
    return out

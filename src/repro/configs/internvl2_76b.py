"""internvl2-76b [vlm] — InternViT frontend + Llama3-70B-class LM backbone
[arXiv:2404.16821].

Assignment: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision frontend is a STUB per the assignment: ``input_specs`` provides
256 precomputed patch embeddings per sample which are linearly projected
and prepended to the token sequence (total seq matches the shape spec).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    rope_theta=5e5,
    mlp_act="swiglu",
    vision_prefix=256,
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, vision_prefix=8)

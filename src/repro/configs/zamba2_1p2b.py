"""zamba2-1.2b [hybrid] — Mamba2 backbone + zamba-style *shared* attention
blocks [arXiv:2411.15242].

Assignment: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64. Realised as 2 scan groups of (18 mamba2 + 1 shared-attn)
= 38 layers; the attention+MLP block re-uses ONE shared parameter set
across its applications (true zamba weight sharing).
"""
from repro.configs.base import LayerPattern, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    pattern=LayerPattern(kinds=("ssm",) * 18 + ("shared_attn",), n_repeat=2),
    ssm=SSMConfig(d_state=64, head_dim=64, n_groups=1, expand=2),
    mlp_act="geglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        pattern=LayerPattern(kinds=("ssm", "shared_attn"), n_repeat=2),
        ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, expand=2, chunk=16),
    )

"""amr-paper-100m — the paper's own end-to-end artifact: a ~100M-param LM
whose matmuls run under AMR-MUL numerics (examples/train_lm_approx.py).

border=8 matches the 2-digit (int8-class) design point the paper highlights
(§IV.A: delay/power/energy/area improved 2%/32%/34%/23% at MARED 1.06e-1;
we default to the MXU low-rank form, rank 16).
"""
from repro.configs.base import ModelConfig
from repro.numerics import AMRNumerics

CONFIG = ModelConfig(
    name="amr-paper-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=32000,
    mlp_act="swiglu",
    tie_embeddings=True,
    numerics=AMRNumerics("amr_lowrank", border=8, rank=16),
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=128, vocab=256)

"""Structural validation of ModelConfig instances.

``get_reduced_config`` shrinks every architecture to a CPU-sized variant,
and a shrink that breaks a divisibility invariant (GQA head grouping, SSM
state heads, MoE top-k) fails DEEP inside a jit trace with a reshape error
naming none of the offending fields.  ``validate_config`` checks every
invariant the model assembly relies on and raises ``ValueError`` messages
naming config fields — the conformance matrix runs it on every registered
config (full and reduced) before building anything.
"""
from __future__ import annotations

from .base import ModelConfig

__all__ = ["validate_config"]


def _fail(cfg: ModelConfig, msg: str) -> None:
    raise ValueError(f"config {cfg.name!r}: {msg}")


def validate_config(cfg: ModelConfig) -> ModelConfig:
    """Check cross-field invariants; returns ``cfg`` so calls can chain."""
    if cfg.n_layers <= 0 or cfg.d_model <= 0 or cfg.vocab <= 0:
        _fail(cfg, f"n_layers/d_model/vocab must be positive, got "
                   f"{cfg.n_layers}/{cfg.d_model}/{cfg.vocab}")

    kinds = cfg.layer_kinds()
    if cfg.pattern is not None and cfg.pattern.n_layers != cfg.n_layers:
        _fail(cfg, f"pattern covers {cfg.pattern.n_layers} layers "
                   f"({cfg.pattern.kinds} x {cfg.pattern.n_repeat}) but "
                   f"n_layers={cfg.n_layers}")

    has_attn = any(k in ("full", "swa", "shared_attn", "cross") for k in kinds)
    if has_attn or cfg.encoder_layers:
        if cfg.n_heads <= 0 or cfg.n_kv_heads <= 0 or cfg.head_dim <= 0:
            _fail(cfg, f"attention needs positive n_heads/n_kv_heads/head_dim, "
                       f"got {cfg.n_heads}/{cfg.n_kv_heads}/{cfg.head_dim}")
        if cfg.n_heads % cfg.n_kv_heads:
            _fail(cfg, f"GQA grouping needs n_kv_heads | n_heads, got "
                       f"n_heads={cfg.n_heads}, n_kv_heads={cfg.n_kv_heads}")
    if any(k == "swa" for k in kinds) and cfg.sliding_window <= 0:
        _fail(cfg, f"'swa' layers need sliding_window > 0, got "
                   f"{cfg.sliding_window}")

    if any(k == "ssm" for k in kinds):
        if cfg.ssm is None:
            _fail(cfg, "'ssm' layers need cfg.ssm (SSMConfig)")
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        if d_inner % s.head_dim:
            _fail(cfg, f"SSM needs head_dim | d_inner: d_inner = expand * "
                       f"d_model = {s.expand} * {cfg.d_model} = {d_inner}, "
                       f"head_dim={s.head_dim}")
        n_heads = d_inner // s.head_dim
        if n_heads % s.n_groups:
            _fail(cfg, f"SSM needs n_groups | (d_inner/head_dim): "
                       f"{n_heads} heads, n_groups={s.n_groups}")
        if s.d_state <= 0 or s.conv_width <= 0 or s.chunk <= 0:
            _fail(cfg, f"SSM d_state/conv_width/chunk must be positive, got "
                       f"{s.d_state}/{s.conv_width}/{s.chunk}")

    if cfg.moe is not None:
        m = cfg.moe
        if m.n_experts <= 0 or m.d_ff_expert <= 0:
            _fail(cfg, f"MoE needs positive n_experts/d_ff_expert, got "
                       f"{m.n_experts}/{m.d_ff_expert}")
        if not 0 < m.top_k <= m.n_experts:
            _fail(cfg, f"MoE needs 0 < top_k <= n_experts, got "
                       f"top_k={m.top_k}, n_experts={m.n_experts}")
    elif cfg.family == "moe":
        _fail(cfg, "family 'moe' but cfg.moe is None")

    if cfg.family in ("ssm", "hybrid") and cfg.ssm is None:
        _fail(cfg, f"family {cfg.family!r} but cfg.ssm is None")
    if cfg.family == "audio" and not cfg.encoder_layers:
        _fail(cfg, "family 'audio' but encoder_layers == 0")
    if cfg.encoder_layers and cfg.encoder_frames <= 0:
        _fail(cfg, f"encoder_layers={cfg.encoder_layers} needs "
                   f"encoder_frames > 0, got {cfg.encoder_frames}")
    if cfg.family == "vlm" and cfg.vision_prefix <= 0:
        _fail(cfg, "family 'vlm' but vision_prefix == 0")
    return cfg

"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

Assignment: 18L d_model=2048 8H (GQA kv=1 — MQA) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    mlp_act="geglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256)

"""Training launcher: real end-to-end driver on whatever devices exist.

Composes every substrate layer: config registry -> data pipeline -> sharded
train state -> pjit'd train step -> fault-tolerant loop with async
checkpointing, preemption handling, straggler monitoring, and elastic
restore (mesh-agnostic checkpoints re-shard onto the current topology).

  PYTHONPATH=src python -m repro.launch.train --arch amr-paper-100m \
      --reduced --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config, get_reduced_config
from repro.data import SyntheticLM
from repro.launch.cli import add_numerics_args, apply_pallas_interpret, numerics_from_args
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.numerics import root_key
from repro.parallel import sharding as shard_lib
from repro.runtime import FaultTolerantLoop, Heartbeat
from repro.train.steps import make_train_state, make_train_step


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="amr-paper-100m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--tp", type=int, default=1, help="model-parallel size")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=0)
    add_numerics_args(ap)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    apply_pallas_interpret(args, tag="train")
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    nm = numerics_from_args(args)
    if nm is not None:
        from repro.launch.cli import policy_label

        cfg = dataclasses.replace(cfg, numerics=nm)
        print(f"[train] numerics policy: {policy_label(nm)}")

    mesh = make_host_mesh(model_parallel=args.tp)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                       seed=args.seed)
    step_raw = make_train_step(cfg, peak_lr=args.lr, warmup=20,
                               total_steps=args.steps,
                               microbatch=args.microbatch or None)

    def make_state():
        with mesh_context(mesh):
            state = make_train_state(cfg, root_key(args.seed))
            specs = shard_lib.param_specs(mesh, state, cfg)
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                              is_leaf=lambda x: isinstance(x, P))
            return jax.device_put(state, sh)

    def remesh(host_state):
        # elastic restart: re-shard a (host-side) restored state onto the
        # mesh we have NOW (may differ from the saving run's topology)
        specs = shard_lib.param_specs(mesh, host_state, cfg)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(host_state, sh)

    jitted = jax.jit(step_raw, donate_argnums=(0,))

    def step_fn(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        with mesh_context(mesh):
            return jitted(state, batch)

    hb = Heartbeat(Path(args.ckpt_dir) / "heartbeat.json")
    hb.start()
    loop = FaultTolerantLoop(
        ckpt_dir=args.ckpt_dir, make_state=make_state, step_fn=step_fn,
        batch_at=data.batch_at, ckpt_every=args.ckpt_every, remesh=remesh,
        heartbeat=hb)
    loop.install_preemption_handler()
    t0 = time.time()
    result = loop.run(args.steps)
    hb.stop()
    tok_s = result.steps_done * args.batch * args.seq / max(time.time() - t0, 1e-9)
    print(f"[train] done: {result.steps_done} steps, {result.restarts} restarts, "
          f"preempted={result.preempted}, ~{tok_s:.0f} tok/s")


if __name__ == "__main__":
    main()

"""Roofline analysis over dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh) cell, all in seconds per step,
computed from the per-device compiled HLO (cost_analysis + collective
parse) and TPU v5e constants (launch/mesh.py):

  t_compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  t_memory     = HLO_bytes_per_device / HBM_BW
  t_collective = collective_bytes_per_device / ICI_LINK_BW

Derived:
  bottleneck        = argmax of the three terms
  MODEL_FLOPS       = flops_mult * N(_active) * tokens_per_step  (6ND train,
                      2ND prefill/decode), per device
  useful_ratio      = MODEL_FLOPS / HLO_FLOPs   (remat/redundancy waste)
  roofline_fraction = (MODEL_FLOPS/PEAK) / max(terms) — the MFU upper bound
                      the compiled artifact allows; §Perf's score.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16


def analyse(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "cost" not in rec:
        return None
    chips = rec["chips"]
    scale = rec.get("cost_scale", 1)
    # train cells: cost lowering covers ONE microbatch; scale to the full
    # step and add the (once-per-step) optimizer's analytic footprint:
    # ~25 flops and ~26 bytes per sharded fp32 master/moment element.
    opt_flops = 25.0 * rec["params"] / chips if rec["kind"] == "train" else 0.0
    opt_bytes = 26.0 * rec["params"] / chips if rec["kind"] == "train" else 0.0
    # fused_bytes = fusion-aware TPU traffic model (dryrun.fused_traffic_bytes);
    # raw bytes_accessed (CPU-pipeline, unfused) kept as the pessimistic bound
    raw_bytes = rec["cost"]["bytes_accessed"]
    bytes_est = rec["cost"].get("fused_bytes", raw_bytes)
    flops_dev = rec["cost"]["flops"] * scale + opt_flops
    bytes_dev = bytes_est * scale + opt_bytes
    coll_dev = rec["collectives"]["total_bytes"] * scale
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    n = rec["active_params"]
    model_flops = rec["flops_mult"] * n * rec["tokens_per_step"] / chips
    bound = max(terms.values()) or 1e-30
    return {
        "cell": rec["cell"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_per_dev": model_flops,
        "useful_ratio": model_flops / flops_dev if flops_dev else 0.0,
        "roofline_fraction": (model_flops / PEAK_FLOPS_BF16) / bound,
        "fits": rec.get("fits"),
        "peak_gb": rec["memory"]["peak_bytes"] / 2**30 if "memory" in rec else None,
    }


def table(dry_dir: Path, mesh_filter: str | None = None) -> list[dict]:
    rows = []
    for f in sorted(dry_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if mesh_filter and not rec["cell"].endswith(mesh_filter):
            continue
        row = analyse(rec)
        if row is None:
            rows.append({"cell": rec["cell"], "status": rec.get("status"),
                         "reason": rec.get("reason") or rec.get("error", "")[:100]})
        else:
            rows.append(row)
    return rows


def fmt_row(r: dict) -> str:
    if "t_compute_s" not in r:
        return f"| {r['cell']} | {r.get('status')} | {r.get('reason','')} |"
    return ("| {cell} | {tc:.2e} | {tm:.2e} | {tl:.2e} | {b} | {ur:.2f} | {rf:.3f} | "
            "{gb:.1f} |").format(
        cell=r["cell"], tc=r["t_compute_s"], tm=r["t_memory_s"],
        tl=r["t_collective_s"], b=r["bottleneck"], ur=r["useful_ratio"],
        rf=r["roofline_fraction"], gb=r["peak_gb"] or 0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = table(Path(args.dir), args.mesh)
    print("| cell | t_comp | t_mem | t_coll | bottleneck | useful | roofline_frac | peak_GB |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))


if __name__ == "__main__":
    main()

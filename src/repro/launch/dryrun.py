import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
#   Only the dry-run uses placeholder devices (system design: smoke tests and
#   benches see the single real CPU device).

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this produces (JSON artifact under experiments/dryrun/):
  * compiled.memory_analysis()  — per-device bytes: proves the cell fits HBM
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes accessed
  * collective byte totals      — parsed from the post-SPMD optimized HLO
  * derived roofline terms      — see launch/roofline.py

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both        # full campaign
"""
import argparse
import json
import re
import time
import traceback
from collections import defaultdict
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, shape_applicable
from repro.configs.registry import ARCH_NAMES
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.parallel import sharding as shard_lib
from repro.train import steps as steps_lib

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        key = "f8" if dt.startswith("f8") else dt
        total += n * _DTYPE_BYTES.get(key, 4)
    return total


_HEAVY_OPS = (
    "dot", "fusion", "custom-call", "convolution", "gather", "scatter",
    "reduce", "reduce-window", "sort", "dynamic-slice", "dynamic-update-slice",
    "copy", "transpose", "concatenate", "pad", "parameter",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "select-and-scatter", "cumsum",
)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+([a-z][\w\-]*)\(")


def fused_traffic_bytes(hlo_text: str) -> float:
    """Fusion-aware HBM traffic model from the optimized HLO.

    The CPU backend's ``bytes accessed`` prices every op — including
    elementwise/convert/broadcast chains a TPU pipeline would fuse — and
    overstates traffic by ~10-50x. This estimator sums operand+output bytes
    only for ops that form fusion *boundaries* on TPU (dots, fusions,
    gathers/scatters, data movement, collectives, parameters), skipping ops
    inside fusion/reduce sub-computations. Recorded as cost.fused_bytes;
    the roofline memory term uses it (raw value kept alongside).
    """
    # first pass: computations referenced as fusion/reducer bodies — their
    # interiors do not touch HBM (while bodies excluded from this set: they
    # execute as real code, and in cost mode loops are unrolled anyway)
    fused_bodies = set()
    for line in hlo_text.splitlines():
        if " fusion(" in line or " reduce(" in line or " scatter(" in line \
                or "-start(" in line or " sort(" in line or " reduce-window(" in line:
            for name in _CALLS_RE.findall(line):
                fused_bodies.add(name)

    total = 0.0
    current = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if "{" in line and "->" in line else None
        if hdr:
            current = hdr.group(1)
            continue
        if current in fused_bodies:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(1)
        if any(op == h or op.startswith(h + ".") for h in _HEAVY_OPS):
            total += _shape_bytes(line)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-class result-buffer bytes of every collective in the per-device HLO."""
    out: dict[str, float] = defaultdict(float)
    count: dict[str, int] = defaultdict(int)
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2).lower()
        b = _shape_bytes(shape_txt)
        out[op] += b
        count[op] += 1
    return {"bytes": dict(out), "counts": dict(count), "total_bytes": sum(out.values())}


def _shardings(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, multi_pod: bool, microbatch: str = "auto",
               cost_mode: bool = False, cfg_override=None):
    """Returns (jitted fn, abstract args (donatable), meta) for one cell.

    cost_mode: fully unroll layer scans and disable microbatching so
    cost_analysis/collective parsing count every layer (XLA prices
    while-loop bodies once). Deployment mode keeps scans (small HLO,
    realistic memory picture).
    """
    import dataclasses as _dc
    cfg = cfg_override or get_config(arch)
    deploy_microbatch = microbatch
    if cost_mode:
        cfg = _dc.replace(cfg, unroll_layers=True)
        microbatch = "1"
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    dp = shard_lib.data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    cost_scale = 1
    if shape.kind == "train" and cost_mode:
        # cost extraction: fwd+bwd of ONE deploy-sized microbatch with layers
        # unrolled; per-step cost = n_micro x this + analytic optimizer terms
        # (roofline.py). Full-batch unrolled would not fit memory and would
        # distort the collective schedule. n_micro follows the --microbatch
        # override so microbatch-count sweeps measure the FSDP re-gather tax.
        import dataclasses as _dc2
        n_micro = ((shape.global_batch // dp_size) if deploy_microbatch == "auto"
                   else max(int(deploy_microbatch), 1))
        cost_scale = n_micro
        small_shape = _dc2.replace(shape,
                                   global_batch=shape.global_batch // n_micro)
        params = specs_lib.abstract_params(cfg)
        batch = specs_lib.train_specs(cfg, small_shape)
        p_specs = shard_lib.param_specs(mesh, params, cfg)
        batch_specs = {k: shard_lib.batch_partition_spec(mesh, v.shape[0], len(v.shape))
                       for k, v in batch.items()}
        step = steps_lib.make_grads_step(cfg)
        fn = jax.jit(
            step,
            in_shardings=(_shardings(mesh, p_specs), _shardings(mesh, batch_specs)),
            out_shardings=_shardings(mesh, p_specs),
        )
        args = (params, batch)
        tokens = shape.global_batch * shape.seq_len
        flops_mult = 6
    elif shape.kind == "train":
        state = specs_lib.abstract_train_state(cfg)
        batch = specs_lib.train_specs(cfg, shape)
        state_specs = shard_lib.param_specs(mesh, state, cfg)  # rules cover opt-state mirrors
        batch_specs = {k: shard_lib.batch_partition_spec(mesh, v.shape[0], len(v.shape))
                       for k, v in batch.items()}
        mb = (shape.global_batch // dp_size) if microbatch == "auto" else int(microbatch)
        step = steps_lib.make_train_step(cfg, microbatch=mb if mb > 1 else None)
        fn = jax.jit(
            step,
            in_shardings=(_shardings(mesh, state_specs), _shardings(mesh, batch_specs)),
            out_shardings=(_shardings(mesh, state_specs),
                           NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )
        args = (state, batch)
        tokens = shape.global_batch * shape.seq_len
        flops_mult = 6
    elif shape.kind == "prefill":
        params = specs_lib.abstract_params(cfg)
        batch = specs_lib.prefill_specs(cfg, shape)
        p_specs = shard_lib.param_specs(mesh, params, cfg)
        batch_specs = {k: shard_lib.batch_partition_spec(mesh, v.shape[0], len(v.shape))
                       for k, v in batch.items()}
        step = steps_lib.make_prefill_step(cfg)
        logits_spec = shard_lib.batch_partition_spec(mesh, shape.global_batch, 2)
        fn = jax.jit(
            step,
            in_shardings=(_shardings(mesh, p_specs), _shardings(mesh, batch_specs)),
            out_shardings=NamedSharding(mesh, logits_spec),
        )
        args = (params, batch)
        tokens = shape.global_batch * shape.seq_len
        flops_mult = 2
    else:  # decode
        params = specs_lib.abstract_params(cfg)
        cache, batch = specs_lib.decode_specs(cfg, shape)
        p_specs = shard_lib.param_specs(mesh, params, cfg)
        c_specs = shard_lib.cache_specs(mesh, cache, shape.global_batch)
        batch_specs = {k: shard_lib.batch_partition_spec(mesh, v.shape[0], len(v.shape))
                       for k, v in batch.items()}
        step = steps_lib.make_serve_step(cfg)
        tok_spec = shard_lib.batch_partition_spec(mesh, shape.global_batch, 1)
        fn = jax.jit(
            step,
            in_shardings=(_shardings(mesh, p_specs), _shardings(mesh, c_specs),
                          _shardings(mesh, batch_specs)),
            out_shardings=(NamedSharding(mesh, tok_spec), _shardings(mesh, c_specs)),
            donate_argnums=(1,),
        )
        args = (params, cache, batch)
        tokens = shape.global_batch  # one new token per sequence
        flops_mult = 2

    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "tokens_per_step": tokens,
        "flops_mult": flops_mult,
        "cost_scale": cost_scale,
        "params": specs_lib.param_count(cfg),
        "active_params": specs_lib.active_param_count(cfg),
    }
    return fn, args, mesh, meta


def _memory_record(compiled) -> dict:
    ma = compiled.memory_analysis()
    rec = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "hbm_per_chip": mesh_lib.HBM_PER_CHIP,
    }
    rec["peak_bytes"] = (rec["argument_bytes"] + rec["temp_bytes"]
                         + rec["output_bytes"] - rec["alias_bytes"])
    return rec


def _cost_record(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             microbatch: str = "auto", with_cost: bool = True,
             cfg_override=None, tag_suffix: str = "") -> dict:
    """Two-phase dry-run for one cell.

    Phase 1 (deployment): scan-over-layers (+ microbatch for train) — small
    HLO, realistic per-device memory; proves the sharding compiles and fits.
    Phase 2 (cost, single-pod roofline cells only): layers unrolled, no
    microbatch — cost_analysis and the collective parse then count every
    layer exactly (XLA prices while bodies once; DESIGN.md §Dry-run).
    """
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}{tag_suffix}"
    rec: dict = {"cell": tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
    else:
        t0 = time.time()
        try:
            fn, args, mesh, meta = build_cell(arch, shape_name, multi_pod, microbatch,
                                              cfg_override=cfg_override)
            rec.update(meta)
            with jax.set_mesh(mesh):
                compiled = fn.lower(*args).compile()
            rec["compile_s"] = round(time.time() - t0, 1)
            rec["memory"] = _memory_record(compiled)
            rec["fits"] = rec["memory"]["peak_bytes"] <= mesh_lib.HBM_PER_CHIP
            rec["deploy_cost"] = _cost_record(compiled)  # while bodies priced once
            del compiled

            if with_cost:
                t1 = time.time()
                fn, args, mesh, meta2 = build_cell(arch, shape_name, multi_pod, microbatch,
                                                   cost_mode=True, cfg_override=cfg_override)
                with jax.set_mesh(mesh):
                    compiled = fn.lower(*args).compile()
                rec["cost_compile_s"] = round(time.time() - t1, 1)
                rec["cost_scale"] = meta2["cost_scale"]
                rec["cost"] = _cost_record(compiled)
                txt = compiled.as_text()
                rec["cost"]["fused_bytes"] = fused_traffic_bytes(txt)
                rec["collectives"] = collective_bytes(txt)
                rec["unrolled_memory"] = _memory_record(compiled)
                del compiled, txt
            rec["status"] = "ok"
        except Exception as e:  # noqa: BLE001 — recorded failure is the artifact
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-2000:])
    out_dir.mkdir(parents=True, exist_ok=True)
    # tmp + rename: dry-run artifacts are read by sweep aggregators that may
    # run while cells are still being written (RPL006)
    dst = out_dir / f"{tag}.json"
    tmp = out_dir / f"{tag}.json.tmp"
    tmp.write_text(json.dumps(rec, indent=1))
    os.replace(tmp, dst)
    print(f"[dryrun] {tag}: {rec['status']}"
          + (f" compile={rec.get('compile_s')}s/{rec.get('cost_compile_s', 0)}s"
             if rec.get("compile_s") else "")
          + (f" ({rec.get('reason') or rec.get('error', '')[:160]})"
             if rec["status"] != "ok" else ""), flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="every arch x shape")
    ap.add_argument("--microbatch", default="auto")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_bad = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                # roofline table is single-pod; multi-pod proves the "pod"
                # axis shards (deployment compile only)
                rec = run_cell(arch, shape, mp, out_dir, args.microbatch,
                               with_cost=not mp)
                n_bad += rec["status"] == "error"
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()

"""Serving launcher: continuous-batching engine over the slot-decode path.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 8 --slots 4 --prompt-len 32 --gen 32 \
      --numerics amr_kernel --border 8 --rank 8

Thin CLI over ``repro.serve.ServeEngine``: requests enter a FIFO queue,
map onto fixed decode slots of one shared KV cache, and every live slot
advances with a single jitted masked decode step (no recompiles as
requests finish / join). ``--numerics`` overrides the config's matmul
policy (choices come from the numerics mode registry) so serving
exercises the approximate multiplier end to end.

Throughput reporting: ``--warmup`` (default on) first runs one throwaway
request cycle so prefill+decode compilation is paid OUTSIDE the timed
window, then the report separates steady-state decode tokens/s (decode
steps only) from end-to-end wall time (queue + prefill + decode).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.registry import get_config, get_reduced_config
from repro.launch.cli import add_numerics_args, apply_pallas_interpret, numerics_from_args
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.models import init_params
from repro.numerics import root_key
from repro.runtime import Heartbeat
from repro.serve import Request, ServeEngine


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of generation requests to serve")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (continuous batching width)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", dest="warmup", action="store_false",
                    help="skip the compile-warmup request cycle (timings then "
                         "include compilation)")
    ap.add_argument("--heartbeat", default=None,
                    help="path for the serve heartbeat JSON (runtime.fault)")
    add_numerics_args(ap)
    args = ap.parse_args(argv)

    apply_pallas_interpret(args, tag="serve")
    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    nm = numerics_from_args(args)
    if nm is not None:
        from repro.launch.cli import policy_label

        cfg = dataclasses.replace(cfg, numerics=nm)
        print(f"[serve] numerics policy: {policy_label(nm)}")

    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)
    capacity = args.prompt_len + args.gen
    prompts = [tuple(int(t) for t in rng.integers(0, cfg.vocab, args.prompt_len))
               for _ in range(args.requests)]
    hb = Heartbeat(Path(args.heartbeat)) if args.heartbeat else None

    with mesh_context(mesh):
        params = init_params(cfg, root_key(args.seed))
        engine = ServeEngine(cfg, params, n_slots=args.slots, capacity=capacity,
                             heartbeat=hb, log=print)
        if args.warmup:
            # one throwaway cycle compiles prefill (this prompt length),
            # insert and the masked decode step outside the timed window
            print("[serve] warmup: compiling prefill + decode")
            engine.submit(Request(prompt=prompts[0], max_new_tokens=2))
            engine.run()
            engine.completions.clear()
            engine.steps_done = 0
            engine.decode_seconds = 0.0
            engine.decode_tokens = 0

        for p in prompts:
            engine.submit(Request(prompt=p, max_new_tokens=args.gen))
        t0 = time.monotonic()
        done = engine.run()
        wall = time.monotonic() - t0

    total_tokens = sum(len(c.tokens) for c in done)
    lat = sorted(c.total_s for c in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {wall:.2f}s "
          f"({total_tokens / wall:.1f} tok/s end-to-end)")
    if engine.decode_seconds > 0:
        # steady-state decode rate: tokens produced by masked decode steps
        # only (excludes queue wait + prefill + any compile)
        print(f"[serve] steady-state decode: {engine.decode_tokens} tokens / "
              f"{engine.decode_seconds:.2f}s = "
              f"{engine.decode_tokens / engine.decode_seconds:.1f} tok/s")
    print(f"[serve] latency p50 {lat[len(lat) // 2] * 1e3:.0f}ms "
          f"max {lat[-1] * 1e3:.0f}ms; stats {engine.stats()}")
    print("[serve] sample:", list(done[0].tokens)[:16])


if __name__ == "__main__":
    main()

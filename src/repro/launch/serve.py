"""Serving launcher: prefill a batch of prompts, then batched greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen 32 \
      --numerics amr_kernel --border 8 --rank 8

``--numerics`` overrides the config's matmul policy so serving exercises
the approximate multiplier end to end; ``amr_kernel`` runs the Pallas
kernel path (compiled on real TPU, interpreter mode on CPU/GPU).
``--pallas-interpret {auto,0,1}`` sets the ``REPRO_PALLAS_INTERPRET``
override before any kernel traces (docs/kernels.md).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_reduced_config
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.models import init_params
from repro.numerics import AMRNumerics
from repro.train.steps import make_serve_step


def prefill_into_cache(cfg, params, tokens, capacity):
    """One-shot prefill -> decode cache (models.prefill_with_cache)."""
    from repro.models.model import prefill_with_cache
    _, cache = prefill_with_cache(cfg, params, tokens, capacity)
    return cache


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--numerics", default=None,
                    choices=["exact", "amr_lut", "amr_inject", "amr_lowrank",
                             "amr_noise", "amr_kernel"],
                    help="override the config's matmul numerics policy")
    ap.add_argument("--border", type=int, default=8,
                    help="approximate border column for the AMR modes")
    ap.add_argument("--rank", type=int, default=8,
                    help="low-rank error rank; 0 with amr_kernel = full-LUT kernel")
    ap.add_argument("--inject-impl", default="auto", choices=["auto", "xla", "pallas"],
                    help="amr_inject replay implementation: XLA outer-product "
                         "replay or the Pallas kernel (auto = backend detect)")
    ap.add_argument("--pallas-interpret", default=None, choices=["auto", "0", "1"],
                    help="set REPRO_PALLAS_INTERPRET before any kernel traces")
    args = ap.parse_args(argv)

    if args.pallas_interpret is not None:
        from repro.kernels.pallas_config import ENV_VAR, default_interpret

        os.environ[ENV_VAR] = args.pallas_interpret
        print(f"[serve] {ENV_VAR}={args.pallas_interpret} "
              f"(resolved interpret={default_interpret()})")

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.numerics is not None:
        impl = None if args.inject_impl == "auto" else args.inject_impl
        cfg = dataclasses.replace(cfg, numerics=AMRNumerics(
            args.numerics, border=args.border, rank=args.rank,
            inject_impl=impl))
        print(f"[serve] numerics policy: {cfg.numerics}")
    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
                          jnp.int32)

    with mesh_context(mesh):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        print(f"[serve] prefilling {args.batch}x{args.prompt_len}")
        cache = prefill_into_cache(cfg, params, prompts,
                                   args.prompt_len + args.gen)

        serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        tok = prompts[:, -1:]
        out = []
        t0 = time.time()
        for _ in range(args.gen):
            nxt, cache = serve(params, cache, {"token": tok})
            tok = nxt[:, None]
            out.append(np.asarray(nxt))
        dt = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"[serve] generated {gen.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("[serve] sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()

"""input_specs: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers against
these. For decode shapes the cache spec is derived with jax.eval_shape over
init_cache (abstract; no memory is touched).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import init_cache


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    text = S - cfg.vision_prefix if cfg.vision_prefix else S
    batch: dict[str, Any] = {
        "tokens": _sds((B, text), jnp.int32),
        "targets": _sds((B, text), jnp.int32),
    }
    if cfg.vision_prefix:
        batch["extra"] = _sds((B, cfg.vision_prefix, cfg.d_model), cfg.dtype)
    elif cfg.encoder_layers:
        batch["extra"] = _sds((B, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    spec = train_specs(cfg, shape)
    spec.pop("targets")
    return spec


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple[Any, dict]:
    """(cache_spec_tree, batch_specs) for one decode step with a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(partial(init_cache, cfg, B, S))
    batch: dict[str, Any] = {"token": _sds((B, 1), jnp.int32)}
    if cfg.encoder_layers:
        batch["enc_out"] = _sds((B, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    return cache, batch


def abstract_params(cfg: ModelConfig) -> Any:
    from repro.models import init_params
    from repro.numerics import root_key
    return jax.eval_shape(lambda: init_params(cfg, root_key(0)))


def abstract_train_state(cfg: ModelConfig) -> Any:
    from repro.numerics import root_key
    from repro.train.steps import make_train_state
    return jax.eval_shape(lambda: make_train_state(cfg, root_key(0)))


def param_count(cfg: ModelConfig) -> int:
    import math
    tree = abstract_params(cfg)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: experts count at top_k/n_experts weight (for 6*N_active*D)."""
    tree = abstract_params(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        names = [str(getattr(e, "key", getattr(e, "name", ""))) for e in path]
        if cfg.moe and any(n_ in ("w_gate", "w_up", "w_down") for n_ in names) \
                and len(leaf.shape) >= 3:
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total

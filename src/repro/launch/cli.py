"""Shared numerics CLI surface for every launcher / driver.

One place defines how a numerics policy is expressed on a command line
(``--numerics/--modes --border --rank --noise-seed --inject-impl
--pallas-interpret``, plus ``--policy-file`` for searched per-layer
artifacts) and how parsed args become an ``AMRNumerics`` or a
site-resolved ``NumericsPolicy``.  Choices are derived from the mode
REGISTRY (``repro.numerics.mode_names``) — adding a mode in numerics/
makes it appear in every CLI with no edits here, and no launcher
string-matches mode names.  The ``--numerics`` flags remain the uniform
shorthand: they build one ``AMRNumerics``, which every model entry point
still accepts directly.
"""
from __future__ import annotations

import argparse
import os
from typing import Callable

from repro.numerics import AMRNumerics, get_mode, load_policy, mode_names


def add_numerics_args(
    ap: argparse.ArgumentParser,
    *,
    multi: bool = False,
    default: str | None = None,
    rank_default: int = 8,
) -> None:
    """Attach the numerics policy flags to ``ap``.

    ``multi=False`` adds ``--numerics`` (single mode, choices from the
    registry); ``multi=True`` adds ``--modes`` (comma list — comparison
    drivers training several arms). ``default=None`` means "keep the
    config's policy" for single-mode launchers.
    """
    g = ap.add_argument_group("numerics policy")
    if multi:
        g.add_argument(
            "--modes", default=default,
            help=f"comma list of numerics modes from: {', '.join(mode_names())}")
    else:
        g.add_argument(
            "--numerics", default=default, choices=list(mode_names()),
            help="override the config's matmul numerics policy")
    g.add_argument("--border", type=int, default=8,
                   help="approximate border column for the AMR modes")
    g.add_argument("--rank", type=int, default=rank_default,
                   help="low-rank error rank; 0 with amr_kernel = full-LUT kernel")
    g.add_argument("--noise-seed", type=int, default=0,
                   help="PRNG seed for the Gaussian-surrogate mode")
    g.add_argument("--inject-impl", default="auto",
                   choices=["auto", *_inject_impls()],
                   help="injection replay implementation (auto = backend detect)")
    g.add_argument("--pallas-interpret", default=None, choices=["auto", "0", "1"],
                   help="set REPRO_PALLAS_INTERPRET before any kernel traces")
    g.add_argument("--policy-file", default=None, metavar="JSON",
                   help="load a (possibly per-layer) numerics policy artifact "
                        "(numerics.save_policy / scripts/policy_search.py); "
                        "overrides the uniform --numerics shorthand")


def _inject_impls() -> tuple[str, ...]:
    from repro.kernels.pallas_config import INJECT_IMPLS

    return INJECT_IMPLS


def apply_pallas_interpret(args, log: Callable[[str], None] = print,
                           tag: str = "launch") -> None:
    """Honour ``--pallas-interpret`` BEFORE any kernel traces happen."""
    value = getattr(args, "pallas_interpret", None)
    if value is None:
        return
    from repro.kernels.pallas_config import ENV_VAR, default_interpret

    os.environ[ENV_VAR] = value
    log(f"[{tag}] {ENV_VAR}={value} (resolved interpret={default_interpret()})")


def numerics_from_args(args, mode: str | None = None):
    """Parsed args -> numerics policy (None = keep the config's policy).

    ``--policy-file`` (when no explicit ``mode`` is forced) loads a saved
    policy artifact — uniform or per-layer — and wins over the uniform
    ``--numerics`` shorthand; NOTE any ``schedule_ref`` handles inside must
    already be registered in this process (docs/numerics.md#policy-files).
    Otherwise builds one ``AMRNumerics``; ``mode`` overrides the parsed
    mode — multi-arm drivers call this once per entry of ``--modes``.
    Validation (unknown mode, bad params) happens in the ``AMRNumerics``
    constructor against the registry, so the error names the valid modes.
    """
    path = getattr(args, "policy_file", None)
    if mode is None and path:
        return load_policy(path)
    m = mode if mode is not None else getattr(args, "numerics", None)
    if m is None:
        return None
    impl = None if args.inject_impl == "auto" else args.inject_impl
    return AMRNumerics(m, border=args.border, rank=args.rank,
                       noise_seed=getattr(args, "noise_seed", 0),
                       inject_impl=impl)


def parse_modes(args) -> list[str]:
    """Split a ``--modes`` comma list; empty entries dropped."""
    raw = getattr(args, "modes", None) or ""
    return [m.strip() for m in raw.split(",") if m.strip()]


def policy_label(nm) -> str:
    """Human label like ``amr_lowrank(b=8,r=16)`` — which parameters are
    shown is driven by the registry's required_params, not by mode names.
    Heterogeneous policies summarize via ``numerics.policy_summary``
    (``perlayer[18l: inject b14-b22]``); a ``UniformPolicy`` labels as its
    single design point."""
    from repro.numerics import UniformPolicy, policy_summary

    if isinstance(nm, UniformPolicy):
        nm = nm.numerics
    if not isinstance(nm, AMRNumerics) and hasattr(nm, "resolve"):
        return policy_summary(nm)
    req = get_mode(nm.mode).required_params
    parts = []
    if "border" in req:
        parts.append(f"b={nm.border}")
    if "rank" in req:
        parts.append(f"r={nm.rank}")
    return f"{nm.mode}({','.join(parts)})" if parts else nm.mode

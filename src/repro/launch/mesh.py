"""Production meshes.

Importing this module never touches jax device state — meshes are built by
functions only. The dry-run (and ONLY the dry-run) forces 512 host devices
via XLA_FLAGS before any jax import (launch/dryrun.py lines 1-2).

Target hardware: TPU v5e pods — 256 chips/pod (16x16), 2 pods = 512 chips.
Axes: "data" (batch + FSDP), "model" (tensor parallel), "pod" (cross-pod DP).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


# Hardware constants for the roofline (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link (~ per-chip usable)
HBM_PER_CHIP = 16 * 2**30       # bytes

"""Production meshes.

Importing this module never touches jax device state — meshes are built by
functions only. The dry-run (and ONLY the dry-run) forces 512 host devices
via XLA_FLAGS before any jax import (launch/dryrun.py lines 1-2).

Target hardware: TPU v5e pods — 256 chips/pod (16x16), 2 pods = 512 chips.
Axes: "data" (batch + FSDP), "model" (tensor parallel), "pod" (cross-pod DP).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # legacy jax: make_mesh has no axis_types kwarg
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where it exists; on legacy jax the ``Mesh``
    itself is the context manager that activates it (single-device launcher
    runs — the production dry-run always uses modern jax)."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


# Hardware constants for the roofline (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link (~ per-chip usable)
HBM_PER_CHIP = 16 * 2**30       # bytes

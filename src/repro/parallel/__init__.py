"""Distribution: sharding rules, mesh helpers, compressed collectives."""
from .sharding import batch_partition_spec, cache_specs, data_axes, param_specs

__all__ = ["param_specs", "cache_specs", "batch_partition_spec", "data_axes"]

"""Distributed-optimization collectives: int8-compressed gradient all-reduce.

Cross-pod (DCI) gradient all-reduce is the bandwidth-critical collective of
the multi-pod mesh (DESIGN.md §3). ``compressed_psum_tree`` reduces wire
bytes 4x (f32) / 2x (bf16) by per-leaf absmax int8 quantization:

    scale = psum_max(|g|) / 127       (one scalar per leaf, exact)
    g_hat = dequant(psum(quant(g)))

Error is bounded by 0.5 ulp_int8 * n_shards per element and is unbiased in
expectation with stochastic rounding (optional). Wrapped in shard_map so
the quantized representation is what crosses the links.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _compress_psum_leaf(g: jnp.ndarray, axis: str, stochastic_key=None):
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)).astype(jnp.float32), axis)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    x = g.astype(jnp.float32) / scale
    if stochastic_key is not None:
        x = x + jax.random.uniform(stochastic_key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return (total.astype(jnp.float32) * scale).astype(g.dtype)


def compressed_psum_tree(grads: Any, axis: str, stochastic: bool = False,
                         key=None) -> Any:
    """psum every leaf of ``grads`` over ``axis`` in int8 wire format.

    Must be called inside shard_map/pmap with ``axis`` bound.
    """
    leaves, treedef = jax.tree.flatten(grads)
    keys = (jax.random.split(key, len(leaves)) if stochastic and key is not None
            else [None] * len(leaves))
    out = [_compress_psum_leaf(g, axis, k) for g, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def make_compressed_dp_allreduce(mesh, axis: str = "pod"):
    """shard_map-wrapped tree all-reduce over one mesh axis (e.g. cross-pod)."""
    from jax.experimental.shard_map import shard_map

    def reduce_tree(grads):
        spec = jax.tree.map(lambda _: P(), grads)
        f = shard_map(
            lambda g: compressed_psum_tree(g, axis),
            mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False)
        return f(grads)

    return reduce_tree

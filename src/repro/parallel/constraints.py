"""Logical activation sharding constraints (mesh-agnostic ``pin``).

``pin(x, "batch", None, "tp")`` applies jax.lax.with_sharding_constraint
with the ambient mesh's axes: "batch" -> ("pod","data") (whichever exist),
"tp" -> "model". Every dim is divisibility-guarded; with no ambient mesh
(unit tests, single-device examples) it is a no-op.

Why explicit pins: GSPMD propagation through reshape(head-split) + rope +
GQA einsums can drop the batch sharding entirely when head counts don't
divide the model axis (observed: gemma-2b MQA attention replicated to
global batch). Pinning activations at module boundaries keeps the
partitioner honest — this is what production JAX LM stacks do.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P


def _ambient_axes() -> dict[str, int]:
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is None:
        # Legacy jax (pre-AxisType): no ambient-mesh API at all. Single-device
        # model code must still run (smoke tests, examples), so pins degrade
        # to no-ops exactly as they do with no mesh set.
        return {}
    m = get_mesh()
    if m is None or not m.axis_names:
        return {}
    return dict(zip(m.axis_names, m.axis_sizes))


def ambient_axis_size(name: str) -> int:
    """Size of a mesh axis in the ambient mesh (1 when absent/no mesh)."""
    return _ambient_axes().get(name, 1)


def pin(x, *dims):
    """dims entries: None | 'batch' | 'tp' (one per array dim)."""
    axes = _ambient_axes()
    if not axes:
        return x
    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp_size = math.prod(axes[a] for a in dp) if dp else 1
    tp_size = axes.get("model", 1)
    spec = []
    for d, size in zip(dims, x.shape):
        if d == "batch" and dp and size % dp_size == 0:
            spec.append(dp if len(dp) > 1 else dp[0])
        elif d == "tp" and "model" in axes and size % tp_size == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))

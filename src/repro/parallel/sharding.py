"""Sharding rules: parameter FSDP x TP, activation DP, cache layouts.

Scheme (DESIGN.md §3):
  * "model" axis — tensor parallelism: column-parallel in-projections
    (wq/wk/wv/w_gate/w_up/in_proj), row-parallel out-projections
    (wo/w_down/out_proj); vocab-parallel embeddings/logits.
  * "data" axis — batch data-parallelism AND parameter FSDP (GSPMD
    all-gathers params forward, reduce-scatters grads backward).
  * "pod" axis (multi-pod mesh) — pure data parallelism: activations shard
    on ("pod","data"); parameters replicate across pods so FSDP gathers
    stay intra-pod (ICI), and only gradient all-reduce crosses the DCI.

Every rule is divisibility-guarded: a dim is only sharded if the axis size
divides it (e.g. vocab 50280 on 16-way "model" stays replicated).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# parameter-leaf name -> (dims to try sharding, axis per dim), applied to the
# TRAILING dims (stack/repeat leading axes get None automatically).
_COL = {"last": "model", "second": "data"}    # column-parallel: (D, F)
_ROW = {"last": "data", "second": "model"}    # row-parallel:   (F, D)

_RULES: dict[str, dict[str, str]] = {
    "wq": _COL, "wk": _COL, "wv": _COL, "w_gate": _COL, "w_up": _COL,
    "wz": _COL, "wx": _COL, "vision_proj": _COL,
    "wb": {"second": "data"}, "wc": {"second": "data"}, "wdt": {"second": "data"},
    "wo": _ROW, "w_down": _ROW, "out_proj": _ROW,
    "embed": {"last": "data", "second": "model"},    # (V, D): vocab-parallel
    "lm_head": {"last": "data", "second": "model"},
    "conv_x": {"last": "model"},
    "conv_bias_x": {"last": "model"},
    "router": {"second": "data"},
}

# names whose "model"-axis sharding must respect a *head* structure: splitting
# inside a head's dim makes GSPMD drop batch sharding through rope/GQA
# reshapes (observed on MQA archs) — replicate instead when heads don't divide.
_HEAD_GATED = {"wq": "q", "wo": "q", "wk": "kv", "wv": "kv",
               "wz": "ssm", "wx": "ssm", "out_proj": "ssm",
               "conv_x": "ssm", "conv_bias_x": "ssm"}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes carrying the batch dimension (('pod','data') on multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def _guard(mesh: Mesh, dim: int, axis):
    return axis if (axis is not None and dim % _axis_size(mesh, axis) == 0) else None


def _heads_ok(mesh: Mesh, cfg, gate: str) -> bool:
    if cfg is None:
        return True
    m = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if gate == "q":
        return cfg.n_heads % m == 0
    if gate == "kv":
        return cfg.n_kv_heads % m == 0
    if gate == "ssm":
        if cfg.ssm is None:
            return True
        n_heads = cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim
        return n_heads % m == 0
    return True


def _leaf_spec(mesh: Mesh, path_names: list[str], shape: tuple[int, ...], cfg=None) -> P:
    name = path_names[-1] if path_names else ""
    rule = _RULES.get(name)
    spec: list[Any] = [None] * len(shape)
    # ZeRO-1: live (bf16) params lose their "data"-axis FSDP sharding —
    # no per-microbatch weight gathers; optimizer-state mirrors (under
    # '.opt.') stay data-sharded, so the once-per-step update reduce-
    # scatters grads and all-gathers fresh params exactly once.
    zero1_live = (cfg is not None and getattr(cfg, "param_shard", "fsdp") == "zero1"
                  and "opt" not in path_names)
    # expert-parallel MoE (cfg.moe.dispatch_shard == "expert"): shard the
    # expert dim on "model" instead of the FFN dim (dispatch all-to-all)
    if (cfg is not None and getattr(cfg, "moe", None) is not None
            and cfg.moe.dispatch_shard == "expert"
            and name in ("w_gate", "w_up", "w_down") and len(shape) >= 3
            and shape[-3] == cfg.moe.n_experts):
        spec[-3] = _guard(mesh, shape[-3], "model")
        spec[-2] = None if zero1_live else _guard(mesh, shape[-2], "data")
        return P(*spec)
    if rule and len(shape) >= 1:
        gate = _HEAD_GATED.get(name)
        heads_ok = gate is None or _heads_ok(mesh, cfg, gate)
        last = rule.get("last")
        second = rule.get("second")
        if not heads_ok:
            last = None if last == "model" else last
            second = None if second == "model" else second
        if zero1_live:
            last = None if last == "data" else last
            second = None if second == "data" else second
        spec[-1] = _guard(mesh, shape[-1], last)
        if len(shape) >= 2 and second is not None:
            spec[-2] = _guard(mesh, shape[-2], second)
        # avoid double-assigning the same axis (1-D params etc.)
        if len(shape) >= 2 and spec[-1] is not None and spec[-1] == spec[-2]:
            spec[-2] = None
    return P(*spec)


def _path_names(path) -> list[str]:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            names.append(e.name)
    return names


def param_specs(mesh: Mesh, abstract_params: Any, cfg=None) -> Any:
    """PartitionSpec tree matching an (abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(mesh, _path_names(path), leaf.shape, cfg),
        abstract_params,
    )


def param_shardings(mesh: Mesh, abstract_params: Any, cfg=None) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(mesh, abstract_params, cfg),
                        is_leaf=lambda x: isinstance(x, P))


def batch_partition_spec(mesh: Mesh, batch: int, rank: int) -> P:
    """Tokens/targets: shard dim0 on the data axes when divisible."""
    dp = data_axes(mesh)
    axis = dp if batch % _axis_size(mesh, dp) == 0 else None
    return P(axis, *([None] * (rank - 1)))


def cache_specs(mesh: Mesh, abstract_cache: Any, batch: int) -> Any:
    """Decode caches: (repeat, B, ...) leaves — B on data axes, heads on model.

    KVCache: k/v (R, B, C, n_kv, hd) -> (None, dp, None, 'model'|None, None)
    SSMState: conv (R, B, W, C) -> (None, dp, None, 'model'|None)
              h (R, B, H, N, P) -> (None, dp, 'model'|None, None, None)
    length (R,) replicated.
    """
    dp = data_axes(mesh)
    b_axis = dp if batch % _axis_size(mesh, dp) == 0 else None

    def leaf(path, l):
        names = _path_names(path)
        shape = l.shape
        name = names[-1] if names else ""
        if name in ("k", "v") and len(shape) == 5:
            kv_axis = _guard(mesh, shape[3], "model")
            # kv heads indivisible (GQA/MQA on a wide model axis): shard the
            # cache SEQUENCE dim instead — flash-decoding-style sequence
            # parallelism; each model shard holds/reads a slice of the
            # context, XLA reduces the softmax stats (a 192 GB/device qwen3
            # decode cache becomes 12 GB).
            seq_axis = None if kv_axis else _guard(mesh, shape[2], "model")
            return P(None, b_axis, seq_axis, kv_axis, None)
        if name == "conv" and len(shape) == 4:
            return P(None, b_axis, None, _guard(mesh, shape[3], "model"))
        if name == "h" and len(shape) == 5:
            return P(None, b_axis, _guard(mesh, shape[2], "model"), None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf, abstract_cache)

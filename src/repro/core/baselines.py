"""Approximate *binary* (BNS) multiplier baselines the paper compares against.

Functional (bit-accurate) models of three families from the paper's Fig. 4
comparison set, plus the exact BNS multiplier, so the comparison benchmark
is self-contained:

  * ``exact_mul``      — exact two's-complement multiply.
  * ``drum``           — DRUM(k) [15]: dynamic-range unbiased; keeps the k
                         leading bits from the MSB of |x|, forces the kept
                         LSB to 1 (unbiasing), multiplies, shifts back.
  * ``trunc_mul``      — LETAM-class [13] truncation: zeroes the low
                         (width - t) bits of each |operand| before
                         multiplying (simple truncation baseline).

All operate on int64 arrays of signed operands of a given bit width; cost
estimates reuse the calibrated CostModel basis with BNS structural counts
(see benchmarks/fig4_comparison.py).
"""
from __future__ import annotations

import numpy as np


def exact_mul(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    return np.asarray(x, dtype=np.int64) * np.asarray(y, dtype=np.int64)


def _leading_bit(v: np.ndarray) -> np.ndarray:
    """floor(log2(v)) for v >= 1 (0 for v == 0)."""
    v = v.astype(np.uint64)
    out = np.zeros(v.shape, dtype=np.int64)
    for shift in (32, 16, 8, 4, 2, 1):
        m = v >= (np.uint64(1) << np.uint64(shift))
        out[m] += shift
        v = np.where(m, v >> np.uint64(shift), v)
    return out


def drum(x: np.ndarray, y: np.ndarray, k: int) -> np.ndarray:
    """DRUM(k) dynamic-range unbiased approximate multiply (signed)."""
    x = np.asarray(x, dtype=np.int64)
    y = np.asarray(y, dtype=np.int64)
    sign = np.sign(x) * np.sign(y)
    ax, ay = np.abs(x), np.abs(y)

    def approx_abs(v):
        lead = _leading_bit(np.maximum(v, 1))
        shift = np.maximum(lead - (k - 1), 0)
        kept = v >> shift
        kept = np.where(shift > 0, kept | 1, kept)  # unbias: set kept LSB
        return kept, shift

    kx, sx = approx_abs(ax)
    ky, sy = approx_abs(ay)
    return sign * ((kx * ky) << (sx + sy))


def trunc_mul(x: np.ndarray, y: np.ndarray, width: int, t: int) -> np.ndarray:
    """Truncation multiplier: keep top t bits of each |operand| of ``width`` bits."""
    x = np.asarray(x, dtype=np.int64)
    y = np.asarray(y, dtype=np.int64)
    sign = np.sign(x) * np.sign(y)
    drop = max(width - 1 - t, 0)  # width-1 magnitude bits
    mask = ~((np.int64(1) << drop) - np.int64(1))
    return sign * ((np.abs(x) & mask) * (np.abs(y) & mask))


def mared(approx: np.ndarray, exact: np.ndarray) -> float:
    nz = exact != 0
    return float(np.mean(np.abs((approx[nz] - exact[nz]) / exact[nz])))

"""int8 product LUT + low-rank error factorization (TPU adaptation layer).

``build_int8_lut`` evaluates the bit-accurate 2-digit AMR-MUL over all
2^8 x 2^8 signed int8 pairs once; the resulting 256x256 int32 table *is*
the paper's arithmetic for 8-bit operands (the 2-digit MRSD dynamic range
[-272, 255] strictly contains int8).

``lowrank_factor`` SVD-factors the error table E(a,b) = AMR(a,b) - a*b into
rank-r terms  E ~= sum_r u_r(a) * v_r(b), which turns an approximate matmul
into ``A @ B + U(A) @ V(B)`` — (1+r)/1 MXU matmuls instead of per-element
gather emulation (DESIGN.md §2 L2). Rank 256 is exact by construction.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from .amrmul import AMRMultiplier

INT8_OFFSET = 128  # index = value + 128


def build_int8_lut(border: int | None, engine: str = "jax") -> np.ndarray:
    """(256, 256) int32: LUT[a+128, b+128] = AMR-MUL_2digit(a, b).

    All 2^16 products are evaluated in one batched call; ``engine="jax"``
    (default) replays the schedule through the compiled engine, bit-exact
    against the ``"numpy"`` host path (tests/test_engine.py asserts parity).
    """
    # normalize to positional args so default/keyword calls share a cache key
    return _build_int8_lut(border, engine)


@lru_cache(maxsize=32)
def _build_int8_lut(border: int | None, engine: str) -> np.ndarray:
    m = AMRMultiplier(2, border=border, engine=engine)
    vals = np.arange(-128, 128, dtype=np.int64)
    a = np.repeat(vals, 256)
    b = np.tile(vals, 256)
    prod = m.multiply_values(a, b)  # float64, exact (products < 2**16)
    lut = prod.astype(np.int32).reshape(256, 256)
    return lut


def exact_int8_table() -> np.ndarray:
    vals = np.arange(-128, 128, dtype=np.int64)
    return (vals[:, None] * vals[None, :]).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class LowRankFactors:
    """E(a, b) ~= U[a+128] @ V[b+128].T, shapes (256, r)."""

    border: int | None
    rank: int
    u: np.ndarray  # (256, r) float32
    v: np.ndarray  # (256, r) float32
    residual_fro: float  # ||E - UV'||_F / ||E||_F (0 when rank covers spectrum)
    engine: str = "jax"  # provenance: backend that produced the source table

    def reconstruct(self) -> np.ndarray:
        return self.u @ self.v.T


def lowrank_factor(border: int | None, rank: int, engine: str = "jax") -> LowRankFactors:
    return _lowrank_factor(border, rank, engine)


@lru_cache(maxsize=64)
def _lowrank_factor(border: int | None, rank: int, engine: str) -> LowRankFactors:
    lut = build_int8_lut(border, engine=engine).astype(np.float64)
    err = lut - exact_int8_table().astype(np.float64)
    U, s, Vt = np.linalg.svd(err, full_matrices=False)
    r = min(rank, 256)
    sr = np.sqrt(s[:r])
    u = (U[:, :r] * sr).astype(np.float32)
    v = (Vt[:r, :].T * sr).astype(np.float32)
    denom = float(np.linalg.norm(err)) or 1.0
    resid = float(np.linalg.norm(err - (u.astype(np.float64) @ v.T.astype(np.float64)))) / denom
    return LowRankFactors(border, r, u, v, resid, engine)


def error_stats(border: int | None, engine: str = "jax") -> dict[str, float]:
    """Summary statistics of the int8 error table (feeds amr_noise mode)."""
    lut = build_int8_lut(border, engine=engine).astype(np.float64)
    err = lut - exact_int8_table().astype(np.float64)
    return {
        "mean": float(err.mean()),
        "std": float(err.std()),
        "max_abs": float(np.abs(err).max()),
        "rel_std": float((err / np.maximum(np.abs(exact_int8_table()), 1)).std()),
    }

"""int8 product LUT + low-rank error factorization (TPU adaptation layer).

``build_int8_lut`` evaluates the bit-accurate 2-digit AMR-MUL over all
2^8 x 2^8 signed int8 pairs once; the resulting 256x256 int32 table *is*
the paper's arithmetic for 8-bit operands (the 2-digit MRSD dynamic range
[-272, 255] strictly contains int8).  ``build_int8_luts`` is the batched
multi-border entry point: the 2^16 operand pairs are MRSD-encoded and
bit-packed once, then every requested border's compiled schedule replays
inside ONE fused engine dispatch (``engine.evaluate_split_many``).  Tables
are cached per ``(n_digits, border, engine)`` with provenance (``Int8LUT``
records which backend produced each table).

``lowrank_factor`` SVD-factors the error table E(a,b) = AMR(a,b) - a*b into
rank-r terms  E ~= sum_r u_r(a) * v_r(b), which turns an approximate matmul
into ``A @ B + U(A) @ V(B)`` — (1+r)/1 MXU matmuls instead of per-element
gather emulation (DESIGN.md §2 L2).  Error bound vs the full table: the
rank-r residual is the tail of the SVD, so every entry obeys
``|E(a,b) - (U V^T)(a,b)| <= sigma_{r+1}`` (max entry <= spectral norm of
the residual, which equals the first dropped singular value), and a K-term
dot product accumulates at most ``K * sigma_{r+1}`` of extra error.  Rank
256 is exact by construction (``residual_fro ~ 0``); the full-table Pallas
kernel (``kernels/amr_matmul``, ``method="lut"``) skips the factorization
entirely and gathers from the int32 table for bit-exact products.

The jnp-constant accessors ``table_array`` / ``factor_arrays`` are the
single cached conversion point shared by the kernels and the numerics
policy — call sites must not rebuild factors themselves.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from . import mrsd, ppgen, reduction
from .amrmul import ENGINES, AMRMultiplier

INT8_OFFSET = 128  # index = value + 128
_N_DIGITS = 2      # int8 operands need exactly 2 radix-16 MRSD digits


@dataclasses.dataclass(frozen=True)
class Int8LUT:
    """A cached product table plus the provenance of the backend that built it."""

    n_digits: int
    border: int | None
    engine: str          # "jax" (fused engine replay) | "numpy" (host reference)
    table: np.ndarray    # (256, 256) int32, LUT[a+128, b+128] = AMR(a, b)


_LUT_CACHE: dict[tuple[int, int | None, str], Int8LUT] = {}


def _int8_value_grid() -> tuple[np.ndarray, np.ndarray]:
    """All 2^16 int8 pairs in row-major table order: (a repeated, b tiled)."""
    vals = np.arange(-128, 128, dtype=np.int64)
    return np.repeat(vals, 256), np.tile(vals, 256)


@lru_cache(maxsize=1)
def _int8_operand_bits() -> tuple[np.ndarray, np.ndarray]:
    """Stored operand bits for all 2^16 int8 pairs — encoded/flattened once.

    MRSD encoding is border-independent, so the same packed operands feed
    every border's replay in the multi-border build.
    """
    a, b = _int8_value_grid()
    xb = ppgen.flatten_operand_bits(mrsd.encode(a, _N_DIGITS))
    yb = ppgen.flatten_operand_bits(mrsd.encode(b, _N_DIGITS))
    return xb, yb


def build_int8_luts(
    borders: tuple[int | None, ...], engine: str = "jax"
) -> dict[int | None, np.ndarray]:
    """Batched multi-border build: ``{border: (256, 256) int32 table}``.

    All borders missing from the process-level cache are produced by ONE
    fused engine call (``engine="jax"``) over a shared bit-packed operand
    batch; ``engine="numpy"`` falls back to per-border host replay (the
    reference path the jax tables are asserted bit-exact against).
    """
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    borders = tuple(borders)
    missing = tuple(dict.fromkeys(
        b for b in borders if (_N_DIGITS, b, engine) not in _LUT_CACHE))
    if missing and engine == "jax":
        from . import engine as engine_mod  # lazy: numpy path stays jax-free

        xb, yb = _int8_operand_bits()
        splits = engine_mod.evaluate_split_many(_N_DIGITS, missing, xb, yb)
        for b, (lo, hi) in splits.items():
            prod = reduction.split_to_float(lo, hi)  # exact: products < 2**16
            _LUT_CACHE[(_N_DIGITS, b, engine)] = Int8LUT(
                _N_DIGITS, b, engine, prod.astype(np.int32).reshape(256, 256))
    elif missing:
        a, b2 = _int8_value_grid()
        for b in missing:
            m = AMRMultiplier(_N_DIGITS, border=b, engine=engine)
            prod = m.multiply_values(a, b2)
            _LUT_CACHE[(_N_DIGITS, b, engine)] = Int8LUT(
                _N_DIGITS, b, engine, prod.astype(np.int32).reshape(256, 256))
    return {b: _LUT_CACHE[(_N_DIGITS, b, engine)].table for b in borders}


def build_int8_lut(border: int | None, engine: str = "jax") -> np.ndarray:
    """(256, 256) int32: LUT[a+128, b+128] = AMR-MUL_2digit(a, b).

    Single-border convenience over ``build_int8_luts`` — same cache, same
    fused engine build, bit-exact against the ``"numpy"`` host path
    (tests/test_engine.py + tests/test_lut_numerics.py assert parity).
    """
    return build_int8_luts((border,), engine)[border]


def lut_record(border: int | None, engine: str = "jax") -> Int8LUT:
    """The cached table WITH provenance (which backend produced it)."""
    build_int8_luts((border,), engine)
    return _LUT_CACHE[(_N_DIGITS, border, engine)]


def exact_int8_table() -> np.ndarray:
    a, b = _int8_value_grid()
    return (a * b).astype(np.int32).reshape(256, 256)


@dataclasses.dataclass(frozen=True)
class LowRankFactors:
    """E(a, b) ~= U[a+128] @ V[b+128].T, shapes (256, r)."""

    border: int | None
    rank: int
    u: np.ndarray  # (256, r) float32
    v: np.ndarray  # (256, r) float32
    residual_fro: float  # ||E - UV'||_F / ||E||_F (0 when rank covers spectrum)
    engine: str = "jax"  # provenance: backend that produced the source table

    def reconstruct(self) -> np.ndarray:
        return self.u @ self.v.T


def lowrank_factor(border: int | None, rank: int, engine: str = "jax") -> LowRankFactors:
    return _lowrank_factor(border, rank, engine)


@lru_cache(maxsize=64)
def _lowrank_factor(border: int | None, rank: int, engine: str) -> LowRankFactors:
    lut = build_int8_lut(border, engine=engine).astype(np.float64)
    err = lut - exact_int8_table().astype(np.float64)
    U, s, Vt = np.linalg.svd(err, full_matrices=False)
    r = min(rank, 256)
    sr = np.sqrt(s[:r])
    u = (U[:, :r] * sr).astype(np.float32)
    v = (Vt[:r, :].T * sr).astype(np.float32)
    denom = float(np.linalg.norm(err)) or 1.0
    resid = float(np.linalg.norm(err - (u.astype(np.float64) @ v.T.astype(np.float64)))) / denom
    return LowRankFactors(border, r, u, v, resid, engine)


def table_array(border: int | None, engine: str = "jax"):
    """Cached jnp int32 view of the product table (single conversion point)."""
    return _table_array(border, engine)


@lru_cache(maxsize=64)
def _table_array(border: int | None, engine: str):
    import jax  # lazy: numpy-only users never pull in jax
    import jax.numpy as jnp

    # Concrete even when first materialized inside an ambient jit trace —
    # a tracer must never be cached.
    with jax.ensure_compile_time_eval():
        return jnp.asarray(build_int8_lut(border, engine=engine), dtype=jnp.int32)


@lru_cache(maxsize=64)
def table_max_abs(border: int | None, engine: str = "jax") -> int:
    """Exact max |product| of the design point (int32-saturation guards)."""
    return int(np.abs(build_int8_lut(border, engine=engine)).max())


def factor_arrays(border: int | None, rank: int, engine: str = "jax"):
    """Cached jnp (u, v) factors — ALL kernel/numerics call sites route here
    instead of re-converting ``lowrank_factor`` output per call."""
    return _factor_arrays(border, rank, engine)


@lru_cache(maxsize=64)
def _factor_arrays(border: int | None, rank: int, engine: str):
    import jax
    import jax.numpy as jnp

    f = lowrank_factor(border, rank, engine=engine)
    with jax.ensure_compile_time_eval():  # see _table_array
        return jnp.asarray(f.u), jnp.asarray(f.v)


def error_stats(border: int | None, engine: str = "jax") -> dict[str, float]:
    """Summary statistics of the int8 error table (feeds amr_noise mode)."""
    lut = build_int8_lut(border, engine=engine).astype(np.float64)
    err = lut - exact_int8_table().astype(np.float64)
    return {
        "mean": float(err.mean()),
        "std": float(err.std()),
        "max_abs": float(np.abs(err).max()),
        "rel_std": float((err / np.maximum(np.abs(exact_int8_table()), 1)).std()),
    }

"""Error metrics used in the paper's Table I.

  MRED  = mean( (approx - exact) / exact )          (signed; Table I shows
                                                     negative entries)
  MARED = mean( |approx - exact| / |exact| )
  NMED  = mean( approx - exact ) / max|product|      (signed, ditto)

plus auxiliary: nmed_abs (mean|ED|/max), error std/mean (Fig. 6 context).
Zero exact products are excluded from relative metrics (standard practice).
Streaming accumulator so 10^6-sample sweeps run in bounded memory.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import mrsd


@dataclasses.dataclass
class ErrorAccumulator:
    max_abs: float
    n: int = 0
    n_rel: int = 0
    sum_red: float = 0.0
    sum_ared: float = 0.0
    sum_ed: float = 0.0
    sum_aed: float = 0.0
    sum_ed2: float = 0.0

    def update_split(self, approx_lo, approx_hi, exact_lo, exact_hi) -> None:
        """Exact error distance from split-integer values (see reduction._SPLIT)."""
        ed = ((approx_hi - exact_hi) * (1 << 32) + (approx_lo - exact_lo)).astype(np.float64)
        exact = exact_hi.astype(np.float64) * float(1 << 32) + exact_lo.astype(np.float64)
        self._accumulate(ed, exact)

    def update(self, approx: np.ndarray, exact: np.ndarray) -> None:
        approx = np.asarray(approx, dtype=np.float64)
        exact = np.asarray(exact, dtype=np.float64)
        self._accumulate(approx - exact, exact)

    def _accumulate(self, ed: np.ndarray, exact: np.ndarray) -> None:
        nz = exact != 0
        re = ed[nz] / exact[nz]
        self.n += ed.size
        self.n_rel += int(nz.sum())
        self.sum_red += float(re.sum())
        self.sum_ared += float(np.abs(re).sum())
        self.sum_ed += float(ed.sum())
        self.sum_aed += float(np.abs(ed).sum())
        self.sum_ed2 += float((ed * ed).sum())

    def result(self) -> dict[str, float]:
        n = max(self.n, 1)
        nr = max(self.n_rel, 1)
        mean_ed = self.sum_ed / n
        return {
            "mred": self.sum_red / nr,
            "mared": self.sum_ared / nr,
            "nmed": mean_ed / self.max_abs,
            "nmed_abs": (self.sum_aed / n) / self.max_abs,
            "mean_ed": mean_ed,
            "std_ed": float(np.sqrt(max(self.sum_ed2 / n - mean_ed**2, 0.0))),
            "n_samples": float(self.n),
        }


def monte_carlo_metrics(
    approx_mul,
    exact_mul,
    n_samples: int,
    *,
    seed: int = 0,
    chunk: int = 32768,
    engine: str = "numpy",
) -> dict[str, float]:
    """Streaming Monte-Carlo error metrics for one design point.

    ``approx_mul``/``exact_mul`` are AMRMultiplier-likes; ``engine`` selects
    the replay backend ("numpy" host replay or the jitted "jax" engine) —
    both are bit-exact, so the metrics are backend-independent.
    """
    rng = np.random.default_rng(seed)
    n = approx_mul.cfg.n_digits
    max_abs = (16.0 ** n * (16.0 / 15.0)) ** 2  # |min value|^2 bound
    acc = ErrorAccumulator(max_abs=max_abs)
    remaining = n_samples
    while remaining > 0:
        b = min(chunk, remaining)
        xd = mrsd.random_digits(rng, n, b)
        yd = mrsd.random_digits(rng, n, b)
        alo, ahi = approx_mul.multiply_digits_split(xd, yd, engine=engine)
        elo, ehi = exact_mul.multiply_digits_split(xd, yd, engine=engine)
        acc.update_split(alo, ahi, elo, ehi)
        remaining -= b
    return acc.result()


def relative_errors(approx: np.ndarray, exact: np.ndarray) -> np.ndarray:
    """Per-sample relative error (Fig. 6 distribution), zeros excluded."""
    approx = np.asarray(approx, dtype=np.float64)
    exact = np.asarray(exact, dtype=np.float64)
    nz = exact != 0
    return (approx[nz] - exact[nz]) / exact[nz]

"""JAX schedule-compilation engine: batched bit-accurate AMR-MUL replay.

``reduction.evaluate_split`` replays the Wallace schedule group-by-group in
numpy on the host — fine for unit tests, but the bottleneck for the paper's
Monte-Carlo accuracy protocol (Table I / Fig. 6) and for the 256x256 int8
error table that feeds the Pallas low-rank kernel.  This module *compiles*
a ``reduction.Schedule`` once per ``(n_digits, border)`` design point into
dense per-stage tensors and replays it under ``jax.jit`` in **bit-sliced**
form: every wire holds a uint32 word whose 32 bits are 32 independent batch
samples, so

  * a reduction cell is evaluated as pure bitwise logic — the 8-entry
    sum/carry truth table of each cell type becomes 8 full-word minterm
    masks, and the whole stage is AND/OR/NOT on ``(n_cells, words)`` lanes
    (no per-sample LUT gathers); HA (2-input) tables are tiled twice so the
    padded third input is a don't-care,
  * wire routing is gather + concat over a wire-major ``(n_wires, words)``
    value array: new wires are emitted in allocation order through a static
    permutation, so the replay never scatters,
  * exactness is preserved without ``jax_enable_x64``: final bits unpack
    into 16-bit position limbs accumulated in int32 inside the jitted
    function and combined into the canonical ``(lo, hi)`` int64 split
    (value = lo + hi * 2**32) on the host.

``get_engine(n_digits, border)`` is the process-level cache: schedules
(``reduction.get_schedule``) and compiled artifacts are built at most once
per design point per process, shared across benchmarks, the LUT builder
and the DSE scripts.  Parity with the numpy path is asserted bit-for-bit
in tests/test_engine.py.

``compile_injector`` re-targets the same replay at *traced* operands: a
``CompiledInjector`` evaluates exact AMR products for int8 operand indices
inside an ambient jit trace (value->bits constant gather, in-trace lane
packing, int32 limb combine) — the substrate of the ``amr_inject`` numerics
mode (on-device error injection in training steps, any schedule including
DSE candidates; see docs/numerics.md).
"""
from __future__ import annotations

import dataclasses
import sys
from functools import lru_cache

import numpy as np

from . import ppgen, reduction
from .cells import CELLS

# Stable cell-type order; per-type truth tables are padded/tiled to 8 entries.
CELL_ORDER: tuple[str, ...] = tuple(sorted(CELLS))
_CELL_INDEX = {name: i for i, name in enumerate(CELL_ORDER)}

_LIMB_BITS = 16   # int32-safe: max limb weight 2**15, few hundred bits per limb
_LANE_BITS = 32   # batch samples per uint32 word


def _type_tables() -> tuple[np.ndarray, np.ndarray]:
    """(n_cell_types, 8) sum/carry truth tables over stored input bits."""
    sums = np.zeros((len(CELL_ORDER), 8), dtype=np.uint32)
    carries = np.zeros_like(sums)
    for name, t in _CELL_INDEX.items():
        cell = CELLS[name]
        s, c = np.asarray(cell.sum_table), np.asarray(cell.carry_table)
        if cell.n_in == 2:  # tile: the padded high input bit is a don't-care
            s, c = np.tile(s, 2), np.tile(c, 2)
        sums[t] = s
        carries[t] = c
    return sums, carries


# PP gate truth tables over (x, y), index x*2 + y (ppgen gate-type order).
_GATE_TABLES = np.array(
    [[0, 0, 0, 1],   # G_AND    x & y
     [1, 1, 0, 1],   # G_ORN_X  !x | y
     [1, 0, 1, 1],   # G_ORN_Y  !y | x
     [1, 0, 0, 0]],  # G_NOR
    dtype=np.uint32,
)

_FULL = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class StageTensors:
    """One reduction stage, densely packed (all cell groups concatenated)."""

    in3: np.ndarray        # (n_cells, 3) int32 wire ids; 2-in cells padded with 0
    sum_masks: np.ndarray  # (n_cells, 8) uint32 minterm masks (0 or all-ones)
    carry_masks: np.ndarray
    perm: np.ndarray       # (2 * n_cells,) int32: id-order slot -> concat slot


def _compile_stage(stage, stage_start: int) -> StageTensors:
    type_sum, type_carry = _type_tables()
    in3_rows: list[list[int]] = []
    cell_type: list[int] = []
    sum_ids: list[int] = []
    carry_ids: list[int] = []
    for g in stage:
        t = _CELL_INDEX[g.name]
        for row, sid, cid in zip(g.in_ids, g.sum_ids, g.carry_ids):
            ins = [int(b) for b in row]
            if len(ins) == 2:  # pad slot reads wire 0; tiled table ignores it
                ins = [0] + ins
            in3_rows.append(ins)
            cell_type.append(t)
            sum_ids.append(int(sid))
            carry_ids.append(int(cid))
    n_cells = len(in3_rows)
    # New wires of a stage are allocated contiguously during scheduling; the
    # permutation rebuilds allocation order from [all sums | all carries].
    if sorted(sum_ids + carry_ids) != list(range(stage_start, stage_start + 2 * n_cells)):
        raise AssertionError("stage outputs are not a contiguous wire-id block")
    perm = np.empty(2 * n_cells, dtype=np.int32)
    for k, (sid, cid) in enumerate(zip(sum_ids, carry_ids)):
        perm[sid - stage_start] = k
        perm[cid - stage_start] = n_cells + k
    t_idx = np.asarray(cell_type, dtype=np.int64)
    return StageTensors(
        in3=np.asarray(in3_rows, dtype=np.int32),
        sum_masks=(type_sum[t_idx] * _FULL).astype(np.uint32),
        carry_masks=(type_carry[t_idx] * _FULL).astype(np.uint32),
        perm=perm,
    )


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash/eq: ndarray
# fields aren't hashable, and a lowering is process-cached per design point —
# identity is exactly the right jit static-argument key (kernels/inject_replay).
class LoweredReplay:
    """A schedule's dense replay constants — ONE stage loop, many callers.

    Every replay form in this module (the jitted host evaluator, the
    traceable injector, the outer-product matmul path and the Pallas
    injection-replay kernel in ``kernels/inject_replay``) shares this
    lowering: numpy constants only, so the stage loop can be traced inside
    any ambient context (jit, scan, vmap, a Pallas kernel body) without
    ever caching tracers — numpy constants promote to on-device constants
    at trace time, per trace, which is exactly the safe direction.

    ``replay_stored`` is written for ARBITRARY trailing batch dims: the
    wire axis is first, everything after broadcasts.  The classic host
    path uses ``(n_wires, words)``; the outer-product injection path uses
    ``(n_wires, rows, kc, words)`` with x/y broadcasting against each
    other along disjoint dims.
    """

    schedule: reduction.Schedule
    gate_masks: np.ndarray      # (n_pp, 4) uint32 full-word gate minterm masks
    x_idx: np.ndarray           # (n_pp,) int32 into flattened X operand bits
    y_idx: np.ndarray           # (n_pp,) int32 into flattened Y operand bits
    stages: tuple[StageTensors, ...]
    final_ids: np.ndarray       # (n_final,) int32 surviving wire ids
    weights: np.ndarray         # (n_final, n_limbs) int32 per-limb bit weights
    offsets: np.ndarray         # (n_limbs,) int32 polarity offsets per limb
    n_limbs: int
    bit_weights: np.ndarray     # (n_final,) int64: 2**pos, limb-combined
    offset_total: int           # limb-combined polarity offset

    def replay_stored(self, xw, yw):
        """Bit-sliced stage replay over broadcastable uint32 wire arrays.

        ``xw``: (n_xbits, \\*dx) and ``yw``: (n_ybits, \\*dy) uint32 words with
        broadcast-compatible trailing dims; returns the stored final wire
        words ``(n_final, \\*broadcast(dx, dy))``.
        """
        import jax.numpy as jnp

        extra = max(xw.ndim, yw.ndim) - 1

        def bc(m):  # lift a (n_rows,) constant over the trailing batch dims
            return m.reshape(m.shape[0], *(1,) * extra)

        x = xw[self.x_idx]
        y = yw[self.y_idx]
        nx, ny = ~x, ~y
        gm = self.gate_masks
        vals = ((bc(gm[:, 0]) & (nx & ny)) | (bc(gm[:, 1]) & (nx & y))
                | (bc(gm[:, 2]) & (x & ny)) | (bc(gm[:, 3]) & (x & y)))
        for st in self.stages:
            ins = vals[st.in3]  # (n_cells, 3, *batch)
            a, b, c = ins[:, 0], ins[:, 1], ins[:, 2]
            na, nb, nc = ~a, ~b, ~c
            minterms = (na & nb & nc, na & nb & c, na & b & nc, na & b & c,
                        a & nb & nc, a & nb & c, a & b & nc, a & b & c)
            s_out = bc(st.sum_masks[:, 0]) & minterms[0]
            c_out = bc(st.carry_masks[:, 0]) & minterms[0]
            for k in range(1, 8):
                s_out |= bc(st.sum_masks[:, k]) & minterms[k]
                c_out |= bc(st.carry_masks[:, k]) & minterms[k]
            vals = jnp.concatenate(
                [vals, jnp.concatenate([s_out, c_out], 0)[st.perm]], 0)
        return vals[self.final_ids]


def lower_schedule(schedule: reduction.Schedule) -> LoweredReplay:
    """Lower a schedule to the dense numpy replay constants."""
    layout = schedule.layout
    stages = []
    n_wires = layout.n_pp
    for stage in schedule.stages:
        st = _compile_stage(stage, n_wires)
        stages.append(st)
        n_wires += st.perm.shape[0]
    if n_wires != schedule.n_bits:
        raise AssertionError("compiled wire count disagrees with schedule")

    pos = schedule.final_positions.astype(np.int64)
    pol = schedule.bit_polarity[schedule.final_ids].astype(np.int64)
    n_limbs = int(pos.max()) // _LIMB_BITS + 1
    # weights[i, l] = 2**(pos_i mod 16) when bit i lands in limb l, else 0
    weights_np = np.zeros((pos.shape[0], n_limbs), dtype=np.int32)
    weights_np[np.arange(pos.shape[0]), pos // _LIMB_BITS] = 1 << (pos % _LIMB_BITS)
    offsets_np = (pol[:, None] * weights_np).sum(0).astype(np.int32)
    bit_weights = np.int64(1) << pos
    return LoweredReplay(
        schedule=schedule,
        gate_masks=(_GATE_TABLES[layout.gate] * _FULL).astype(np.uint32),
        x_idx=layout.x_idx.astype(np.int32),
        y_idx=layout.y_idx.astype(np.int32),
        stages=tuple(stages),
        final_ids=schedule.final_ids.astype(np.int32),
        weights=weights_np,
        offsets=offsets_np,
        n_limbs=n_limbs,
        bit_weights=bit_weights,
        offset_total=int((pol * bit_weights).sum()),
    )


def _pack_lanes(bits: np.ndarray) -> np.ndarray:
    """(batch, n_bits) {0,1} -> bit-sliced (n_bits, words) uint32.

    Sample ``w * 32 + k`` lives in bit ``k`` of word ``w`` of each wire row.
    The batch is zero-padded up to a whole number of 32-sample words.
    """
    bits = np.ascontiguousarray(bits.T, dtype=np.uint8)
    pad = (-bits.shape[1]) % _LANE_BITS
    if pad:
        bits = np.pad(bits, ((0, 0), (0, pad)))
    if sys.byteorder == "little":
        packed = np.packbits(bits, axis=1, bitorder="little")
        return np.ascontiguousarray(packed).view(np.uint32)
    words = np.zeros((bits.shape[0], bits.shape[1] // _LANE_BITS), dtype=np.uint32)
    for k in range(_LANE_BITS):  # big-endian fallback: explicit lane packing
        words |= bits[:, k::_LANE_BITS].astype(np.uint32) << np.uint32(k)
    return words


@dataclasses.dataclass(frozen=True)
class CompiledSchedule:
    """A design point lowered to dense tensors + a jitted batched evaluator.

    ``evaluate_split`` is bit-exact against ``reduction.evaluate_split``
    (asserted by tests/test_engine.py across design points).
    """

    schedule: reduction.Schedule
    n_limbs: int
    _replay: object  # jit'd: (n_opbits, words) x2 uint32 -> (n_limbs, batch) i32

    def evaluate_split(
        self, xbits: np.ndarray, ybits: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """(batch, 5N) stored operand bits -> exact (lo, hi) int64 split."""
        import jax
        import jax.numpy as jnp

        # Host-facing: escape any ambient jit trace (e.g. a LUT being built
        # lazily while a consumer kernel traces) so the replay runs concretely.
        with jax.ensure_compile_time_eval():
            limbs = np.asarray(
                self._replay(jnp.asarray(_pack_lanes(xbits)), jnp.asarray(_pack_lanes(ybits)))
            )
        return _combine_limbs(limbs, self.n_limbs, xbits.shape[0])

    def evaluate(self, xbits: np.ndarray, ybits: np.ndarray) -> np.ndarray:
        """Float64 result value (exact only below ~2**53, as the numpy path)."""
        return reduction.split_to_float(*self.evaluate_split(xbits, ybits))


def _limb_replay(lowered: LoweredReplay):
    """Word-batched limb evaluator over a lowered schedule.

    A *traceable* (un-jitted) function ``(xw, yw) -> (n_limbs, batch) int32
    limbs`` over bit-sliced uint32 operand words.  Constants are numpy (see
    ``LoweredReplay``), so it can be ``jax.jit``-ed directly
    (``compile_schedule``) or inlined into a larger traced computation
    (``compile_injector`` — the on-device error-injection path calls it on
    operand words packed *inside* a jit trace).
    """
    import jax.numpy as jnp

    n_limbs = lowered.n_limbs
    weights = lowered.weights
    offsets = lowered.offsets
    lane_shifts = np.arange(_LANE_BITS, dtype=np.uint32)

    def replay(xw, yw):
        """Bit-sliced replay: rows are wires, uint32 words hold 32 samples."""
        stored = lowered.replay_stored(xw, yw)  # (n_final, words)
        bits = ((stored[:, None, :] >> lane_shifts[None, :, None]) & 1).astype(jnp.int32)
        limbs = jnp.einsum("fl,fsw->lws", weights, bits)  # (n_limbs, words, 32)
        return limbs.reshape(n_limbs, -1) - offsets[:, None]

    return replay


def _build_replay(schedule: reduction.Schedule):
    """Lower a schedule and build its limb evaluator: ``(replay_fn, n_limbs)``."""
    lowered = lower_schedule(schedule)
    return _limb_replay(lowered), lowered.n_limbs


def compile_schedule(schedule: reduction.Schedule) -> CompiledSchedule:
    """Lower a schedule to dense tensors and build its jitted evaluator."""
    import jax

    replay, n_limbs = _build_replay(schedule)
    return CompiledSchedule(
        schedule=schedule,
        n_limbs=n_limbs,
        _replay=jax.jit(replay),
    )


def _combine_limbs(limbs: np.ndarray, n_limbs: int, batch: int) -> tuple[np.ndarray, np.ndarray]:
    """(n_limbs, padded_batch) int32 limbs -> exact (lo, hi) int64 split."""
    limbs = limbs.astype(np.int64)[:, :batch]
    lo = limbs[0].copy()
    if n_limbs > 1:
        lo += limbs[1] * (1 << _LIMB_BITS)
    hi = np.zeros_like(lo)
    for limb in range(2, n_limbs):
        hi += limbs[limb] * (1 << (_LIMB_BITS * (limb - 2)))
    return lo, hi


@lru_cache(maxsize=64)
def get_engine(n_digits: int, border: int | None) -> CompiledSchedule:
    """Process-level compiled-artifact cache, keyed on the design point."""
    return compile_schedule(reduction.get_schedule(n_digits, border))


@lru_cache(maxsize=16)
def _multi_replay(n_digits: int, borders: tuple):
    """Fuse several design points' replays into ONE jitted dispatch."""
    import jax

    engines = tuple(get_engine(n_digits, b) for b in borders)
    replays = tuple(e._replay for e in engines)
    return engines, jax.jit(lambda xw, yw: tuple(r(xw, yw) for r in replays))


def evaluate_split_many(
    n_digits: int, borders: tuple, xbits: np.ndarray, ybits: np.ndarray
) -> dict:
    """One fused engine call across approximate borders on a shared batch.

    The host-side costs that dominate multi-design sweeps — bit-slicing the
    operand batch into uint32 lanes and the host->device transfer — are paid
    ONCE; every border's compiled replay then runs inside a single jitted
    dispatch (the per-border replays are composed into one XLA program).
    Returns ``{border: (lo, hi)}`` with the same exact int64 split as
    ``CompiledSchedule.evaluate_split``.
    """
    import jax
    import jax.numpy as jnp

    borders = tuple(borders)
    engines, fused = _multi_replay(n_digits, borders)
    batch = xbits.shape[0]
    # Host-facing (see evaluate_split): run concretely under ambient traces.
    with jax.ensure_compile_time_eval():
        xw = jnp.asarray(_pack_lanes(xbits))
        yw = jnp.asarray(_pack_lanes(ybits))
        outs = [np.asarray(limbs) for limbs in fused(xw, yw)]
    return {
        b: _combine_limbs(limbs, eng.n_limbs, batch)
        for b, eng, limbs in zip(borders, engines, outs)
    }


@dataclasses.dataclass(frozen=True)
class CandidateBatch:
    """Several candidate schedules fused into ONE jitted batched evaluator.

    The cached ``evaluate_split_many`` path is keyed on ``(n_digits,
    border)`` design points; DSE exploration instead produces *ad-hoc*
    schedules (alternative cell assignments for the same design point) that
    have no cache key.  ``compile_candidates`` lowers each one and composes
    the per-candidate replays into a single XLA program, so a Monte-Carlo
    sweep over many frontier candidates pays the operand bit-slicing and
    dispatch cost once per batch — the same fusion ``lut.build_int8_luts``
    gets from ``evaluate_split_many``.  Reuse one ``CandidateBatch`` across
    chunks of the same batch shape to avoid re-tracing.
    """

    engines: tuple[CompiledSchedule, ...]
    _fused: object  # jit'd: (xw, yw) -> tuple of per-candidate limb tensors

    def evaluate_split(
        self, xbits: np.ndarray, ybits: np.ndarray
    ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Shared operand batch -> per-candidate exact (lo, hi) splits."""
        import jax
        import jax.numpy as jnp

        batch = xbits.shape[0]
        # Host-facing (see CompiledSchedule.evaluate_split): run concretely.
        with jax.ensure_compile_time_eval():
            xw = jnp.asarray(_pack_lanes(xbits))
            yw = jnp.asarray(_pack_lanes(ybits))
            outs = [np.asarray(limbs) for limbs in self._fused(xw, yw)]
        return [
            _combine_limbs(limbs, eng.n_limbs, batch)
            for eng, limbs in zip(self.engines, outs)
        ]


def compile_candidates(schedules) -> CandidateBatch:
    """Fuse candidate schedules (or pre-compiled engines) into one dispatch.

    Accepts any mix of ``reduction.Schedule`` and ``CompiledSchedule``; all
    candidates must share the operand width (same ``n_digits``) so a single
    bit-packed batch feeds every replay.
    """
    import jax

    engines = tuple(
        s if isinstance(s, CompiledSchedule) else compile_schedule(s)
        for s in schedules
    )
    if len({e.schedule.n_digits for e in engines}) > 1:
        raise ValueError("candidates must share n_digits (one operand batch)")
    replays = tuple(e._replay for e in engines)
    return CandidateBatch(
        engines, jax.jit(lambda xw, yw: tuple(r(xw, yw) for r in replays)))


def evaluate_candidates_split(
    candidates, xbits: np.ndarray, ybits: np.ndarray
) -> list[tuple[np.ndarray, np.ndarray]]:
    """One fused engine call over candidate schedules on a shared batch.

    ``candidates`` is a ``CandidateBatch`` or a sequence of schedules (which
    is compiled on the spot — prefer building the batch once via
    ``compile_candidates`` when evaluating several operand chunks).
    """
    if not isinstance(candidates, CandidateBatch):
        candidates = compile_candidates(candidates)
    return candidates.evaluate_split(xbits, ybits)


def evaluate_digits_split(
    n_digits: int, border: int | None, x_digits: np.ndarray, y_digits: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Convenience: digit arrays -> exact (lo, hi) via the cached engine."""
    xb = ppgen.flatten_operand_bits(x_digits)
    yb = ppgen.flatten_operand_bits(y_digits)
    return get_engine(n_digits, border).evaluate_split(xb, yb)


# --------------------------------------------------------------------------
# On-device error injection: the replay as a traceable product evaluator
# --------------------------------------------------------------------------

def _int8_value_bit_table(n_digits: int) -> np.ndarray:
    """(256, 5N) stored operand bits of every int8 value (index = v + 128).

    MRSD encoding is data-independent, so the 256 possible int8 operand
    values enumerate the whole bit-pattern domain of the injection path —
    a gather from this table turns *traced* quantized operands into replay
    inputs without ever leaving the device.
    """
    from . import mrsd

    vals = np.arange(-128, 128, dtype=np.int64)
    return ppgen.flatten_operand_bits(mrsd.encode(vals, n_digits)).astype(np.uint32)


@dataclasses.dataclass(frozen=True)
class CompiledInjector:
    """A schedule lowered to a *traceable* per-sample product evaluator.

    Unlike ``CompiledSchedule`` (host-facing: numpy operands in, exact int64
    split out), the injector is built to run INSIDE an ambient jit trace —
    ``train_step``/``serve_step`` call it on traced int8 operands, so a
    matmul under ``amr_inject`` numerics sees the exact per-product error of
    the actual quantized activations/weights on-device, for ANY
    ``reduction.Schedule`` (including DSE candidate assignments that have no
    materialized 256x256 LUT).  Operand bits are gathered from a constant
    value->bits table, lane-packed with jnp ops, replayed bit-sliced, and
    limb-combined entirely in int32; ``compile_injector`` rejects schedules
    whose dynamic range does not fit int32 (n_digits <= 3 in practice).
    """

    schedule: reduction.Schedule
    n_limbs: int
    _replay: object       # traceable: (n_opbits, words) uint32 x2 -> int32 limbs
    _value_bits: object   # (256, n_opbits) uint32 jnp constant
    lowered: LoweredReplay = None
    _value_masks: object = None  # (256, n_opbits) uint32 jnp constant (0 / ~0)
    max_abs_product: int = 0     # bound on |product| (int32 saturation checks)

    def products(self, ia, ib):
        """Exact AMR products of int8 operand *indices* (value + 128).

        ``ia``/``ib``: equal-shape traced int arrays in [0, 256).  Returns
        int32 products of the same shape — bit-identical to gathering from
        the schedule's 256x256 LUT, but computed by replaying the reduction
        circuit on-device for exactly the requested operand pairs.
        """
        import jax.numpy as jnp

        ia = jnp.asarray(ia)
        ib = jnp.asarray(ib)
        if ia.shape != ib.shape:
            raise ValueError(f"operand index shapes differ: {ia.shape} vs {ib.shape}")
        shape = ia.shape
        xb = self._value_bits[ia.reshape(-1)]
        yb = self._value_bits[ib.reshape(-1)]
        flat = self.products_from_bits(xb, yb)
        return flat.reshape(shape)

    def products_from_bits(self, xbits, ybits):
        """(batch, 5N) traced stored-bit arrays -> (batch,) int32 products."""
        import jax.numpy as jnp

        batch = xbits.shape[0]
        limbs = self._replay(_pack_lanes_traced(xbits), _pack_lanes_traced(ybits))
        out = limbs[0]
        if self.n_limbs > 1:
            out = out + limbs[1] * (1 << _LIMB_BITS)
        return out[:batch].astype(jnp.int32)

    def operand_masks(self, ia):
        """Value->full-word-mask gather: operand indices (...) in [0, 256)
        -> (..., n_opbits) uint32 where each stored bit becomes 0 or ~0."""
        import jax.numpy as jnp

        ia = jnp.asarray(ia)
        return self._value_masks[ia.reshape(-1)].reshape(*ia.shape, -1)

    def pack_weights(self, ib):
        """(K, N) operand indices -> (K, n_opbits, n_words) packed lane words.

        The weight-side bit-pack of the outer-product replay: column ``n``
        lives in bit ``n % 32`` of word ``n // 32`` (the ``_pack_lanes``
        layout), shared across every activation row of a matmul — and, for
        concrete weights, cacheable across calls (``numerics.injection``
        keeps that cache).  Traceable; ``N`` is zero-padded up to whole
        words, so callers slice the first N output columns.
        """
        import jax.numpy as jnp

        ib = jnp.asarray(ib)
        pad = (-ib.shape[1]) % _LANE_BITS
        if pad:  # pad with index 128 (value 0): padded products stay bounded
            # by max_abs_product, so K-accumulation never wraps before the
            # caller slices the real columns out.
            ib = jnp.pad(ib, ((0, 0), (0, pad)), constant_values=128)
        k, n = ib.shape
        bits = self._value_bits[ib.reshape(-1)].reshape(k, n, -1)  # {0,1}
        nb = bits.shape[-1]
        lanes = bits.reshape(k, -1, _LANE_BITS, nb)
        shifts = np.arange(_LANE_BITS, dtype=np.uint32)
        words = jnp.sum(lanes << shifts[None, None, :, None], axis=2,
                        dtype=jnp.uint32)
        return words.transpose(0, 2, 1)  # (K, n_opbits, n_words)

    def products_outer(self, xm, yw):
        """Outer-product replay: exact products of every (row, column) pair.

        ``xm``: (R, C, n_opbits) uint32 x-operand masks (``operand_masks``),
        ``yw``: (C, n_opbits, W) packed y words (``pack_weights`` rows) —
        returns (R, C, W*32) int32 where entry (r, c, w*32+l) is the exact
        AMR product of x operand (r, c) and the y operand in lane ``l`` of
        word ``w``.  The x side broadcasts as full-word masks against the
        lane-packed y side, so the replay cost is one word per 32 columns
        and the x-side gather/pack cost is shared by ALL columns — the
        structural win over pairwise packing (see docs/numerics.md).
        """
        import jax.numpy as jnp

        r, c, _ = xm.shape
        w = yw.shape[-1]
        x = xm.transpose(2, 0, 1)[:, :, :, None]      # (n_opbits, R, C, 1)
        y = yw.transpose(1, 0, 2)[:, None, :, :]      # (n_opbits, 1, C, W)
        stored = self.lowered.replay_stored(x, y)     # (n_final, R, C, W)
        shifts = np.arange(_LANE_BITS, dtype=np.uint32)
        bw = self.lowered.bit_weights.astype(np.int32)
        acc = jnp.zeros((r, c, w, _LANE_BITS), jnp.int32)
        for f in range(stored.shape[0]):  # accumulate per final bit: keeps the
            # unpacked (R, C, W, 32) intermediates at 2 live tensors, not n_final
            bits = ((stored[f][..., None] >> shifts) & np.uint32(1)).astype(jnp.int32)
            acc = acc + np.int32(bw[f]) * bits
        return (acc - np.int32(self.lowered.offset_total)).reshape(r, c, w * _LANE_BITS)


def _pack_lanes_traced(bits):
    """Traceable ``_pack_lanes``: (batch, n_bits) {0,1} -> (n_bits, words).

    Same lane layout as the host packer (sample ``w * 32 + k`` in bit ``k``
    of word ``w``), built from shifts + a disjoint-bit sum so it lowers to a
    handful of vector ops inside the surrounding trace.
    """
    import jax.numpy as jnp

    batch, n_bits = bits.shape
    pad = (-batch) % _LANE_BITS
    if pad:
        bits = jnp.pad(bits, ((0, pad), (0, 0)))
    lanes = bits.T.reshape(n_bits, -1, _LANE_BITS).astype(jnp.uint32)
    shifts = jnp.arange(_LANE_BITS, dtype=jnp.uint32)
    return jnp.sum(lanes << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def compile_injector(schedule: reduction.Schedule) -> CompiledInjector:
    """Lower a schedule to the on-device injection evaluator.

    Raises ``ValueError`` when the schedule's output dynamic range exceeds
    int32 (the injector combines limbs in int32 so it can run under jit
    without ``jax_enable_x64``); every 2-digit (int8-operand) schedule —
    cached design points and DSE exports alike — is comfortably inside.
    """
    import jax
    import jax.numpy as jnp

    lowered = lower_schedule(schedule)
    bound = int(lowered.bit_weights.sum())  # >= max |value| + |offset|
    if 2 * bound >= 2**31:
        raise ValueError(
            f"schedule dynamic range (sum 2**pos = {bound}) exceeds int32; "
            f"on-device injection supports n_digits <= 3 "
            f"(got n_digits={schedule.n_digits})")
    replay = _limb_replay(lowered)
    vb_np = _int8_value_bit_table(schedule.n_digits)
    with jax.ensure_compile_time_eval():  # concrete even under an ambient trace
        value_bits = jnp.asarray(vb_np)
        value_masks = value_bits * jnp.uint32(_FULL)
        # Exact max |product| over the whole int8 x int8 domain (ONE 64K-pair
        # replay, once per design point): the analytic range bound above is
        # orders of magnitude looser, which would make the K-accumulation
        # saturation guard reject legitimately safe matmul shapes.
        ia, ib = np.divmod(np.arange(256 * 256), 256)
        limbs = np.asarray(replay(jnp.asarray(_pack_lanes(vb_np[ia])),
                                  jnp.asarray(_pack_lanes(vb_np[ib]))))
    prods = limbs[0].astype(np.int64)
    if lowered.n_limbs > 1:
        prods = prods + limbs[1].astype(np.int64) * (1 << _LIMB_BITS)
    return CompiledInjector(
        schedule=schedule, n_limbs=lowered.n_limbs, _replay=replay,
        _value_bits=value_bits, lowered=lowered, _value_masks=value_masks,
        max_abs_product=int(np.abs(prods).max()))


@lru_cache(maxsize=64)
def get_injector(n_digits: int, border: int | None) -> CompiledInjector:
    """Process-level injector cache for the default design points."""
    return compile_injector(reduction.get_schedule(n_digits, border))


def inject_products(schedule, ia, ib):
    """Exact AMR products for traced int8 operand indices (value + 128).

    ``schedule`` is a ``CompiledInjector`` or a raw ``reduction.Schedule``
    (compiled on the spot — hold a ``CompiledInjector`` when calling from a
    hot loop; ``numerics.injection`` keeps the policy-level cache).
    """
    inj = schedule if isinstance(schedule, CompiledInjector) else compile_injector(schedule)
    return inj.products(ia, ib)

"""AMR-MUL core: the paper's contribution as a composable library.

Layers (DESIGN.md §2):
  L0 bit-accurate MRSD multiplier model — mrsd / cells / ppgen / reduction /
     dse / amrmul / metrics / energy / baselines
  L0' compiled batched replay (jit + vmap, bit-exact vs L0) — engine
  L1 int8 LUT semantics + low-rank MXU factorization — lut
(L2, the matmul numerics policy, lives in repro.numerics; TPU kernels in
repro.kernels.)
"""
from .amrmul import AMRMulConfig, AMRMultiplier, exact_multiplier
from .cells import CELLS, PAPER_AVG_ERR
from .dse import (MultiplierAssignment, assign_column, materialize,
                  pareto_sweep, search_assignments, select_border)
from .lut import (Int8LUT, build_int8_lut, build_int8_luts, error_stats,
                  exact_int8_table, lowrank_factor, lut_record)
from .metrics import ErrorAccumulator, monte_carlo_metrics, relative_errors

__all__ = [
    "AMRMulConfig", "AMRMultiplier", "exact_multiplier",
    "CELLS", "PAPER_AVG_ERR", "assign_column",
    "MultiplierAssignment", "search_assignments", "materialize",
    "pareto_sweep", "select_border",
    "Int8LUT", "build_int8_lut", "build_int8_luts", "lut_record",
    "exact_int8_table", "lowrank_factor", "error_stats",
    "ErrorAccumulator", "monte_carlo_metrics", "relative_errors",
]

"""Measured (error, energy) Pareto exploration over DSE candidates.

Analytic expected error ranks candidates inside the search, but the number
that matters for deployment is the *measured* Monte-Carlo error of the
exported schedule — the two can diverge because the analytic bound tracks
only the mean.  ``measure_candidates`` therefore replays seeded MC batches
through ``engine.compile_candidates``: every candidate of a digit width
(plus the exact reference) is evaluated by ONE jitted dispatch per operand
chunk over a shared bit-packed batch, and the per-candidate error metrics
are accumulated exactly (split-integer error distances, the Table I
protocol).

``pareto_sweep`` composes the whole pipeline — search k candidates per
border, materialize, measure, cost — and flags the non-dominated
(error, energy) frontier per digit width.  ``select_border`` is the
application-facing wrapper: cheapest frontier design meeting an error
budget (used by ``scripts/hillclimb.py`` to pick numerics borders).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .. import metrics, mrsd, ppgen, reduction
from .export import materialize
from .multiplier import MultiplierAssignment, search_assignments


@dataclasses.dataclass
class CandidatePoint:
    """One explored design: assignment + exported schedule + measured scores."""

    n_digits: int
    border: int | None
    candidate: int                    # rank within its (n_digits, border)
    assignment: MultiplierAssignment
    schedule: reduction.Schedule
    measured: dict[str, float]        # Table I metrics from the fused replay
    energy: float                     # cost_fn(schedule)
    frontier: bool = False

    @property
    def err_abs_mred(self) -> float:
        return abs(self.measured["mred"])


def measure_candidates(
    schedules: Sequence[reduction.Schedule],
    *,
    n_samples: int,
    seed: int = 0,
    chunk: int = 16384,
) -> list[dict[str, float]]:
    """Table I metrics for each candidate, one fused dispatch per chunk.

    All schedules must share ``n_digits``.  The exact reference schedule is
    appended to the same fused batch, so reference products come from the
    identical operand stream at no extra host cost.
    """
    from .. import engine as engine_mod  # lazy: numpy-only paths stay jax-free

    n = schedules[0].n_digits
    exact = reduction.get_schedule(n, None)
    batch = engine_mod.compile_candidates([*schedules, exact])
    max_abs = (16.0 ** n * (16.0 / 15.0)) ** 2
    accs = [metrics.ErrorAccumulator(max_abs=max_abs) for _ in schedules]
    rng = np.random.default_rng(seed)
    remaining = n_samples
    while remaining > 0:
        b = min(chunk, remaining)
        xd = mrsd.random_digits(rng, n, b)
        yd = mrsd.random_digits(rng, n, b)
        xb = ppgen.flatten_operand_bits(xd)
        yb = ppgen.flatten_operand_bits(yd)
        outs = batch.evaluate_split(xb, yb)
        elo, ehi = outs[-1]
        for acc, (lo, hi) in zip(accs, outs[:-1]):
            acc.update_split(lo, hi, elo, ehi)
        remaining -= b
    return [acc.result() for acc in accs]


def measured_score_hook(
    *,
    key: str = "std_ed",
    n_samples: int = 20000,
    seed: int = 0,
    chunk: int = 16384,
) -> Callable[[Sequence[MultiplierAssignment]], list[float]]:
    """A ``search_assignments(score_hook=...)`` factory scoring candidates by
    a MEASURED Monte-Carlo metric (default ``std_ed``, the error-distance
    standard deviation) instead of the analytic |expected error| alone.

    The analytic bound tracks only the error MEAN; the engine loop already
    measures the full distribution, so re-ranking a wider analytic pool by
    measured variance costs one fused candidate dispatch and picks designs
    whose error is both small and tight (the ROADMAP's variance-aware
    scoring carry-over; the per-layer policy search consumes these).
    """

    def hook(assignments: Sequence[MultiplierAssignment]) -> list[float]:
        measured = measure_candidates(
            [materialize(a) for a in assignments],
            n_samples=n_samples, seed=seed, chunk=chunk)
        return [abs(float(m[key])) for m in measured]

    return hook


def pareto_front(errs: Sequence[float], costs: Sequence[float]) -> list[bool]:
    """Non-dominated flags under joint minimization of (error, cost).

    A point is dominated when another is <= on both axes and < on at least
    one; duplicate points are both kept on the frontier.
    """
    flags = []
    pts = list(zip(errs, costs))
    for i, (e, c) in enumerate(pts):
        dominated = any(
            (e2 <= e and c2 <= c and (e2 < e or c2 < c))
            for j, (e2, c2) in enumerate(pts) if j != i
        )
        flags.append(not dominated)
    return flags


def pareto_sweep(
    n_digits: int,
    borders: Sequence[int],
    *,
    k: int = 2,
    n_samples: int = 20000,
    seed: int = 0,
    chunk: int = 16384,
    cost_fn: Callable[[reduction.Schedule], float] | None = None,
    err_key: str = "mred",
    **search_kwargs,
) -> list[CandidatePoint]:
    """Full engine-in-the-loop sweep for one digit width.

    For every border: ``k`` best whole-multiplier assignments, materialized
    and measured together (one fused candidate dispatch per chunk covers
    every border's candidates), costed by ``cost_fn`` (default: the
    model-free ``energy.literal_energy_proxy``), and flagged with the
    per-digit-width (|measured err_key|, energy) Pareto frontier.
    """
    from .. import energy as energy_mod  # deferred: energy -> amrmul -> ... -> dse

    cost_fn = cost_fn or energy_mod.literal_energy_proxy
    points: list[CandidatePoint] = []
    for border in borders:
        assignments = search_assignments(n_digits, border, k=k, **search_kwargs)
        for rank, a in enumerate(assignments):
            sched = materialize(a)
            points.append(CandidatePoint(
                n_digits, border, rank, a, sched,
                measured={}, energy=float(cost_fn(sched))))
    measured = measure_candidates(
        [pt.schedule for pt in points],
        n_samples=n_samples, seed=seed, chunk=chunk)
    for pt, m in zip(points, measured):
        pt.measured = m
    flags = pareto_front(
        [abs(pt.measured[err_key]) for pt in points],
        [pt.energy for pt in points])
    for pt, f in zip(points, flags):
        pt.frontier = f
    return points


def select_border(
    n_digits: int,
    borders: Sequence[int],
    *,
    max_err: float,
    err_key: str = "mared",
    n_samples: int = 20000,
    seed: int = 0,
    cost_fn: Callable[[reduction.Schedule], float] | None = None,
    **sweep_kwargs,
) -> int:
    """Cheapest explored border whose measured error meets the budget.

    Runs ``pareto_sweep`` with ``k=1`` and returns the border of the
    lowest-energy point with ``|measured[err_key]| <= max_err`` (signed
    metrics like ``mred`` are compared by magnitude, matching the frontier
    axis); raises ``ValueError`` when no explored design meets the budget
    (widen the border sweep or relax ``max_err``).
    """
    points = pareto_sweep(
        n_digits, borders, k=1, n_samples=n_samples, seed=seed,
        cost_fn=cost_fn, err_key=err_key, **sweep_kwargs)
    ok = [pt for pt in points if abs(pt.measured[err_key]) <= max_err]
    if not ok:
        raise ValueError(
            f"no border in {list(borders)} meets |{err_key}| <= {max_err} "
            f"(best: {min(abs(pt.measured[err_key]) for pt in points):.3g})")
    return min(ok, key=lambda pt: (pt.energy, pt.border)).border

"""Whole-multiplier branch-and-bound / beam search over cell assignments.

The greedy composition in ``reduction.build_schedule`` threads the running
expected error through per-column Fig. 3 solves, committing to each column's
local optimum before the next column is seen.  This module searches the
*joint* space instead: at every DSE column the branch set is that column's
exact achievable-error profile (``column.column_profile``) and the objective
is the |expected error| of the whole multiplier.

Two structural facts make the joint search tractable:

  * **Shape invariance** — column heights per stage are choice-independent:
    every full adder consumes three same-weight bits and emits one sum (at
    ``p``) plus one carry (at ``p+1``) whatever its type, and the HA /
    pass-through remainder rule depends only on ``height mod 3``.  The
    reduction *shape* (which columns reduce at which stage, with how many
    FAs, in which region) is therefore compiled once per design point
    (``compile_shape``); only the posibit/negabit splits — and hence each
    column's achievable error profile — depend on earlier choices.
  * **Admissible suffix bounds** — one FA changes the expected multiplier
    error by at most ``1/2 * 2^p``, so suffix sums of ``n_fa * 2^p / 2``
    over the remaining shape events lower-bound the best achievable |final
    error| from any node (Fig. 3's bound 1 lifted to the whole multiplier).

``search_assignments`` always runs a width-bounded beam pass (exact
``Fraction`` bookkeeping, deduplicated states) and then an exact DFS pass
capped by ``max_nodes`` whose pruning is seeded with the beam incumbents;
when the DFS exhausts the tree the returned optimum is provably optimal
and ``complete=True``.  ``greedy_assignment`` reproduces the per-column
Fig. 3 composition of ``reduction.build_schedule`` decision for decision —
the parity anchor for the export round-trip.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from functools import lru_cache

from .. import ppgen
from ..cells import output_polarity
from . import column as column_mod

MAX_STEP = column_mod.MAX_ABS_STEP  # max |avg err| one FA can contribute


@dataclasses.dataclass(frozen=True)
class ShapeEvent:
    """One column reduction of one stage (choice-independent skeleton)."""

    stage: int
    p: int            # column weight 2**p
    height: int       # bits entering the column this stage
    n_fa: int         # height // 3 full adders consumed here
    region: str       # "exact" | "approx" | "border"
    first_of_stage: bool

    @property
    def decision(self) -> bool:
        """True when the DSE actually chooses cells here."""
        return self.region != "exact" and self.n_fa > 0


@dataclasses.dataclass(frozen=True)
class ColumnChoice:
    """Recorded decision: the cells assigned to one column of one stage."""

    stage: int
    p: int
    pos_cnt: int
    neg_cnt: int
    cells: tuple[tuple[str, int, int], ...]


@dataclasses.dataclass(frozen=True)
class MultiplierAssignment:
    """A full-multiplier cell assignment for one ``(n_digits, border)``.

    ``choices`` covers exactly the decision events (approx/border columns
    with at least one FA) in processing order; exact-region and remainder
    cells are reproduced deterministically by the schedule builder.
    ``expected_error`` is the exact accumulated expected multiplier error,
    bit-identical to ``materialize(a).expected_error`` (asserted on export).
    """

    n_digits: int
    border: int | None
    choices: tuple[ColumnChoice, ...]
    expected_error: Fraction
    nodes: int
    complete: bool  # True when the exact DFS exhausted the search tree

    def tag(self) -> str:
        b = "exact" if self.border is None else f"b{self.border}"
        return f"dse_{self.n_digits}d_{b}_e{float(self.expected_error):+.3g}"


def initial_columns(n_digits: int) -> dict[int, tuple[int, int]]:
    """Partial-product column splits: ``{position: (pos_cnt, neg_cnt)}``."""
    layout = ppgen.build_pp_layout(n_digits)
    cols: dict[int, tuple[int, int]] = {}
    for p, pol in zip(layout.position.tolist(), layout.polarity.tolist()):
        pc, nc = cols.get(p, (0, 0))
        cols[p] = (pc + (pol == 0), nc + (pol == 1))
    return cols


@lru_cache(maxsize=None)
def compile_shape(n_digits: int, border: int | None) -> tuple[ShapeEvent, ...]:
    """The choice-independent reduction skeleton of a design point."""
    cols = {p: pc + nc for p, (pc, nc) in initial_columns(n_digits).items()}
    events: list[ShapeEvent] = []
    stage = 0
    while any(h > 2 for h in cols.values()):
        nxt: dict[int, int] = {}
        first = True
        for p in sorted(cols):
            h = cols[p]
            if h == 0:
                continue
            if h == 1:
                nxt[p] = nxt.get(p, 0) + 1
                continue
            if border is None or p > border:
                region = "exact"
            elif p == border:
                region = "border"
            else:
                region = "approx"
            n_fa = h // 3
            rem = h - 3 * n_fa
            events.append(ShapeEvent(stage, p, h, n_fa, region, first))
            first = False
            nxt[p] = nxt.get(p, 0) + n_fa + (1 if rem >= 1 else 0)
            nxt[p + 1] = nxt.get(p + 1, 0) + n_fa + (1 if rem == 2 else 0)
        cols = nxt
        stage += 1
    return tuple(events)


def _suffix_bounds(events: tuple[ShapeEvent, ...]) -> list[Fraction]:
    """``suffix[i]`` = max |expected-error change| events ``i..`` can apply."""
    suffix = [Fraction(0)] * (len(events) + 1)
    for i in range(len(events) - 1, -1, -1):
        step = Fraction(0)
        if events[i].region != "exact":
            step = MAX_STEP * events[i].n_fa * (1 << events[i].p)
        suffix[i] = suffix[i + 1] + step
    return suffix


def _exact_cells(pos: int, neg: int) -> tuple[tuple[str, int, int], ...]:
    """Exact-region policy of ``reduction.build_schedule``: triples, posibits first."""
    out = []
    while pos + neg >= 3:
        dp = min(3, pos)
        dn = 3 - dp
        out.append(("FA", dp, dn))
        pos -= dp
        neg -= dn
    return tuple(out)


def _add(nxt: dict[int, tuple[int, int]], p: int, pol: int) -> None:
    pc, nc = nxt.get(p, (0, 0))
    nxt[p] = (pc + (pol == 0), nc + (pol == 1))


def _apply_column(
    nxt: dict[int, tuple[int, int]], p: int, pos: int, neg: int,
    cells: tuple[tuple[str, int, int], ...],
) -> None:
    """Mutate ``nxt`` with the outputs of ``cells`` + HA/pass remainder.

    Mirrors the count-level effect of one column body of
    ``reduction.build_schedule`` (cell outputs, then exact HA on a 2-bit
    remainder, then pass-through of a single leftover bit).
    """
    for _name, dp, dn in cells:
        spol, cpol = output_polarity(3, dn)
        _add(nxt, p, int(spol))
        _add(nxt, p + 1, int(cpol))
        pos -= dp
        neg -= dn
    if pos < 0 or neg < 0:
        raise AssertionError("cell assignment over-consumes a polarity")
    rem = pos + neg
    if rem == 2:
        spol, cpol = output_polarity(2, neg)
        _add(nxt, p, int(spol))
        _add(nxt, p + 1, int(cpol))
    elif rem == 1:
        _add(nxt, p, 0 if pos else 1)
    elif rem != 0:
        raise AssertionError("column remainder exceeds 2 bits")


def _boundary(
    cols: dict[int, tuple[int, int]], nxt: dict[int, tuple[int, int]]
) -> tuple[dict[int, tuple[int, int]], dict[int, tuple[int, int]]]:
    """Stage boundary: untouched (height <= 1) columns pass through."""
    merged = dict(nxt)
    for p, (pc, nc) in cols.items():
        mc, mn = merged.get(p, (0, 0))
        merged[p] = (mc + pc, mn + nc)
    return merged, {}


def _pop(cols: dict, p: int) -> tuple[dict, int, int]:
    new_cols = dict(cols)
    pos, neg = new_cols.pop(p)
    return new_cols, pos, neg


class _KBest:
    """Bounded set of the k best distinct leaves by (|err|, err, choices)."""

    def __init__(self, k: int):
        self.k = k
        self.items: list[tuple[Fraction, Fraction, tuple[ColumnChoice, ...]]] = []

    def offer(self, e_abs: Fraction, choices: tuple[ColumnChoice, ...]) -> None:
        key = (abs(e_abs), e_abs, choices)
        if any(c == choices for _, _, c in self.items):
            return
        self.items.append(key)
        # ties beyond (|err|, err) keep insertion order (deterministic: beam
        # ranking, then greedy, then DFS exploration order)
        self.items.sort(key=lambda t: (t[0], t[1]))
        del self.items[self.k:]

    @property
    def worst(self) -> Fraction | None:
        return self.items[-1][0] if len(self.items) == self.k else None


def _beam(
    events: tuple[ShapeEvent, ...],
    init_cols: dict[int, tuple[int, int]],
    k: int,
    beam_width: int,
    branch_cap: int,
) -> tuple[_KBest, int]:
    """Width-bounded forward pass; returns k best leaves + states expanded."""
    # state: (e_abs, cols, nxt, choices)
    states = [(Fraction(0), dict(init_cols), {}, ())]
    nodes = 0
    for i, ev in enumerate(events):
        if ev.first_of_stage and i > 0:
            states = [(e, *_boundary(c, x), ch) for e, c, x, ch in states]
        new_states = []
        for e_abs, cols, nxt, choices in states:
            cols2, pos, neg = _pop(cols, ev.p)
            if pos + neg != ev.height:
                raise AssertionError("shape/state height mismatch")
            if not ev.decision:
                cells = _exact_cells(pos, neg) if ev.region == "exact" else ()
                nxt2 = dict(nxt)
                _apply_column(nxt2, ev.p, pos, neg, cells)
                new_states.append((e_abs, cols2, nxt2, choices))
                nodes += 1
                continue
            profile = column_mod.column_profile(pos, neg, ev.region == "border")
            w = 1 << ev.p
            ranked = sorted(profile.items(), key=lambda kv: (abs(e_abs + kv[0] * w), kv[0]))
            for s, cells in ranked[:branch_cap]:
                nxt2 = dict(nxt)
                _apply_column(nxt2, ev.p, pos, neg, cells)
                choice = ColumnChoice(ev.stage, ev.p, pos, neg, cells)
                new_states.append((e_abs + s * w, cols2, nxt2, choices + (choice,)))
                nodes += 1
        # Dedup identical futures (same error + same splits): choices differ
        # only in the past, so keeping the best-ranked one loses nothing.
        seen = set()
        deduped = []
        for st in sorted(new_states, key=lambda t: (abs(t[0]), t[0])):
            sig = (st[0], tuple(sorted(st[1].items())), tuple(sorted(st[2].items())))
            if sig in seen:
                continue
            seen.add(sig)
            deduped.append(st)
        states = deduped[:beam_width]
    best = _KBest(k)
    for e_abs, _cols, _nxt, choices in states:
        best.offer(e_abs, choices)
    return best, nodes


def _dfs(
    events: tuple[ShapeEvent, ...],
    init_cols: dict[int, tuple[int, int]],
    suffix: list[Fraction],
    best: _KBest,
    max_nodes: int,
) -> tuple[int, bool]:
    """Exact DFS with admissible k-best pruning; returns (nodes, complete)."""
    nodes = 0
    aborted = False

    def rec(i, cols, nxt, e_abs, choices):
        nonlocal nodes, aborted
        if aborted:
            return
        nodes += 1
        if nodes > max_nodes:
            aborted = True
            return
        if i == len(events):
            best.offer(e_abs, choices)
            return
        worst = best.worst
        if worst is not None and abs(e_abs) - suffix[i] > worst:
            return  # admissible: remaining events cannot recover the deficit
        ev = events[i]
        if ev.first_of_stage and i > 0:
            cols, nxt = _boundary(cols, nxt)
        cols2, pos, neg = _pop(cols, ev.p)
        if not ev.decision:
            cells = _exact_cells(pos, neg) if ev.region == "exact" else ()
            nxt2 = dict(nxt)
            _apply_column(nxt2, ev.p, pos, neg, cells)
            rec(i + 1, cols2, nxt2, e_abs, choices)
            return
        profile = column_mod.column_profile(pos, neg, ev.region == "border")
        w = 1 << ev.p
        ranked = sorted(profile.items(), key=lambda kv: (abs(e_abs + kv[0] * w), kv[0]))
        for s, cells in ranked:
            nxt2 = dict(nxt)
            _apply_column(nxt2, ev.p, pos, neg, cells)
            choice = ColumnChoice(ev.stage, ev.p, pos, neg, cells)
            rec(i + 1, cols2, nxt2, e_abs + s * w, choices + (choice,))

    rec(0, dict(init_cols), {}, Fraction(0), ())
    return nodes, not aborted


def greedy_assignment(n_digits: int, border: int | None) -> MultiplierAssignment:
    """The per-column Fig. 3 composition, decision-for-decision identical to
    ``reduction.build_schedule``'s built-in policy (parity anchor)."""
    events = compile_shape(n_digits, border)
    cols = dict(initial_columns(n_digits))
    nxt: dict[int, tuple[int, int]] = {}
    e_abs = Fraction(0)
    nodes = 0
    choices: list[ColumnChoice] = []
    for i, ev in enumerate(events):
        if ev.first_of_stage and i > 0:
            cols, nxt = _boundary(cols, nxt)
        cols, pos, neg = _pop(cols, ev.p)
        if not ev.decision:
            cells = _exact_cells(pos, neg) if ev.region == "exact" else ()
        else:
            res = column_mod.assign_column(
                pos, neg, e_abs / Fraction(1 << ev.p),
                allow_exact_fa=ev.region == "border",
            )
            nodes += res.nodes
            cells = tuple(res.cells)
            choices.append(ColumnChoice(ev.stage, ev.p, pos, neg, cells))
            e_abs = res.err * (1 << ev.p)
        _apply_column(nxt, ev.p, pos, neg, cells)
    return MultiplierAssignment(
        n_digits, border, tuple(choices), e_abs, nodes, complete=False)


def search_assignments(
    n_digits: int,
    border: int | None,
    *,
    k: int = 3,
    beam_width: int = 64,
    branch_cap: int = 6,
    max_nodes: int = 100_000,
    score_hook=None,
    pool: int | None = None,
) -> list[MultiplierAssignment]:
    """The ``k`` best whole-multiplier assignments by |expected error|.

    Beam pass first (always terminates; exact bookkeeping), then an exact
    DFS seeded with the beam incumbents and capped at ``max_nodes``; if the
    DFS exhausts the tree, ``[0]`` is the provable optimum and every result
    carries ``complete=True``.  Results are sorted by (|error|, error) and
    are pairwise-distinct assignments.

    ``score_hook`` re-ranks by a MEASURED criterion: the analytic |expected
    error| only tracks the error mean, so two assignments with equal means
    can have very different variance.  When given, the search keeps a wider
    analytic pool (``pool``, default ``3 * k``), calls
    ``score_hook(assignments) -> sequence of floats`` (lower is better —
    e.g. Monte-Carlo ``std_ed`` via :func:`repro.core.dse.pareto.
    measured_score_hook`), and returns the ``k`` best by (score, |error|).
    """
    events = compile_shape(n_digits, border)
    init_cols = initial_columns(n_digits)
    keep = k if score_hook is None else max(pool or 3 * k, k)
    if not any(ev.decision for ev in events):
        return [MultiplierAssignment(n_digits, border, (), Fraction(0), 0, True)]
    suffix = _suffix_bounds(events)
    best, beam_nodes = _beam(events, init_cols, keep, beam_width, branch_cap)
    # The greedy incumbent is free and often optimal — seed it too.
    greedy = greedy_assignment(n_digits, border)
    best.offer(greedy.expected_error, greedy.choices)
    dfs_nodes, complete = _dfs(events, init_cols, suffix, best, max_nodes)
    nodes = beam_nodes + greedy.nodes + dfs_nodes
    results = [
        MultiplierAssignment(n_digits, border, choices, e_abs, nodes, complete)
        for _abs_e, e_abs, choices in best.items
    ]
    if score_hook is not None:
        scores = list(score_hook(results))
        if len(scores) != len(results):
            raise ValueError(
                f"score_hook returned {len(scores)} scores for "
                f"{len(results)} assignments")
        order = sorted(
            range(len(results)),
            key=lambda j: (scores[j], abs(results[j].expected_error),
                           results[j].expected_error))
        results = [results[j] for j in order[:k]]
    return results

"""Materialize DSE assignments into artifacts the rest of the system consumes.

``materialize`` turns a ``MultiplierAssignment`` (a decision record from the
whole-multiplier search) into a real ``reduction.Schedule`` by replaying the
recorded choices through ``reduction.build_schedule``'s pluggable assigner —
so the exported schedule has genuine wiring, feeds ``core.engine`` compiled
replay, metrics, and the energy model unchanged, and its bookkeeping is
asserted bit-identical to the search's (``expected_error`` must round-trip
exactly or the export raises).

``lut_from_schedule`` closes the loop to the kernel path: for a 2-digit
schedule it produces the 256x256 int32 product table in the exact layout of
``lut.build_int8_lut`` (LUT[a+128, b+128] = AMR(a, b)), directly consumable
by ``kernels.amr_matmul.amr_matmul_int8_lut`` and the low-rank factorization.
"""
from __future__ import annotations

import numpy as np

from .. import reduction
from .multiplier import MultiplierAssignment


class _ReplayAssigner:
    """Replays recorded choices in schedule-builder order, with validation."""

    def __init__(self, assignment: MultiplierAssignment):
        self._queue = list(assignment.choices)
        self._idx = 0

    def __call__(self, p, pos_cnt, neg_cnt, _err_scaled, _allow_exact_fa):
        if (pos_cnt + neg_cnt) // 3 == 0:
            return []  # no FA consumed: HA/pass remainder, never recorded
        if self._idx >= len(self._queue):
            raise AssertionError("assignment has fewer decisions than the schedule")
        ch = self._queue[self._idx]
        self._idx += 1
        if (ch.p, ch.pos_cnt, ch.neg_cnt) != (p, pos_cnt, neg_cnt):
            raise AssertionError(
                f"assignment desync at decision {self._idx - 1}: recorded "
                f"(p={ch.p}, {ch.pos_cnt}+{ch.neg_cnt}) vs builder "
                f"(p={p}, {pos_cnt}+{neg_cnt})")
        return list(ch.cells)

    def finish(self) -> None:
        if self._idx != len(self._queue):
            raise AssertionError(
                f"{len(self._queue) - self._idx} recorded decisions unconsumed")


def materialize(assignment: MultiplierAssignment) -> reduction.Schedule:
    """Recorded assignment -> fully wired ``reduction.Schedule``.

    The returned schedule is NOT entered in the ``get_schedule`` cache (that
    cache is reserved for the default greedy policy); compile it with
    ``engine.compile_schedule`` / ``engine.compile_candidates`` for batched
    evaluation.  Raises ``AssertionError`` if the builder's exact expected
    error disagrees with the search's — the count-level simulation and the
    wired schedule must agree bit for bit.
    """
    replayer = _ReplayAssigner(assignment)
    sched = reduction.build_schedule(
        assignment.n_digits, assignment.border, assigner=replayer)
    replayer.finish()
    if sched.expected_error != assignment.expected_error:
        raise AssertionError(
            f"expected-error mismatch after export: search "
            f"{assignment.expected_error} vs schedule {sched.expected_error}")
    return sched


def lut_from_schedule(schedule: reduction.Schedule) -> np.ndarray:
    """(256, 256) int32 product table of a custom 2-digit schedule.

    Same layout/contract as ``lut.build_int8_lut`` (index = value + 128) so
    the result drops into ``amr_matmul_int8_lut`` and ``lowrank_factor``'s
    SVD unchanged.  Evaluated through the compiled engine in one batched
    replay over the shared 2^16-pair operand grid.
    """
    if schedule.n_digits != 2:
        raise ValueError("int8 LUT export requires a 2-digit schedule")
    from .. import engine as engine_mod  # lazy: keep numpy-only paths jax-free
    from ..lut import _int8_operand_bits

    xb, yb = _int8_operand_bits()
    lo, hi = engine_mod.compile_schedule(schedule).evaluate_split(xb, yb)
    prod = reduction.split_to_float(lo, hi)  # exact: 2-digit products < 2**19
    return prod.astype(np.int32).reshape(256, 256)

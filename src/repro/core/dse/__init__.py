"""Design-space exploration subsystem (paper §III.B / Fig. 3, grown up).

Layered package:

  * ``column``     — the paper's per-column Fig. 3 branch-and-bound
    (``assign_column``), the exponential oracle (``brute_force_column``),
    and the exact achievable-error dynamic program (``column_profile`` /
    ``assign_column_topk``) that scales the oracle to tall columns.
  * ``multiplier`` — whole-multiplier search: the choice-independent
    reduction shape (``compile_shape``), greedy Fig. 3 composition
    (``greedy_assignment``, parity-anchored to ``reduction.build_schedule``)
    and the joint beam + branch-and-bound (``search_assignments``).
  * ``export``     — ``materialize`` an assignment into a fully wired
    ``reduction.Schedule`` (round-trip asserted) and ``lut_from_schedule``
    into the kernel path's 256x256 int8 product table.
  * ``pareto``     — measured Monte-Carlo scoring through ONE fused engine
    dispatch per chunk (``measure_candidates``), (error, energy) frontier
    (``pareto_front`` / ``pareto_sweep``) and border selection under an
    error budget (``select_border``).
  * ``model_policy`` — MODEL-level search over the frontier: per-layer
    (mode, border, schedule) assignment under an energy budget, driven by
    a measured sensitivity pass (lazy attribute: it pulls in jax + the
    model stack, while the rest of the package stays numpy-only).

``from repro.core.dse import assign_column`` keeps working — the historical
module is now this package.
"""
from .column import (DSEResult, assign_column, assign_column_topk,
                     brute_force_column, column_profile)
from .export import lut_from_schedule, materialize
from .multiplier import (ColumnChoice, MultiplierAssignment, ShapeEvent,
                         compile_shape, greedy_assignment, initial_columns,
                         search_assignments)
from .pareto import (CandidatePoint, measure_candidates, measured_score_hook,
                     pareto_front, pareto_sweep, select_border)

_MODEL_POLICY = (
    "PolicyChoice", "SensitivityReport", "PolicySearchResult",
    "site_mac_counts", "layer_mac_counts", "frontier_choices",
    "measure_sensitivity",
    "assignment_policy", "policy_energy", "search_model_policy",
)

__all__ = [
    "DSEResult", "assign_column", "assign_column_topk", "brute_force_column",
    "column_profile",
    "ShapeEvent", "ColumnChoice", "MultiplierAssignment", "compile_shape",
    "initial_columns", "greedy_assignment", "search_assignments",
    "materialize", "lut_from_schedule",
    "CandidatePoint", "measure_candidates", "measured_score_hook",
    "pareto_front", "pareto_sweep", "select_border",
    *_MODEL_POLICY,
]


def __getattr__(name: str):
    # model_policy imports jax + the model stack; keep the numpy-only core
    # importable without it (PEP 562 lazy attribute)
    if name in _MODEL_POLICY:
        from . import model_policy

        return getattr(model_policy, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

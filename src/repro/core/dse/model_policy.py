"""Model-level numerics DSE: per-(layer, site) assignment under a budget.

The per-multiplier Pareto sweep (``pareto.pareto_sweep``) ends with a
frontier of (measured error, modeled energy) design points; this module
lifts that frontier to a MODEL decision: which design point runs in which
matmul of which decoder layer.  Two measured phases
(docs/dse.md#model-level-search):

  * Phase 1 — sensitivity (:func:`measure_sensitivity`): ONE instrumented
    forward/backward pass of the real loss on a real batch under a probe
    ``amr_inject`` policy, with ``AuditTrace(compare="exact")`` recording
    the exact |approx - exact| error mass per ``(site, layer)`` coordinate.
    Coordinates whose activations push more error through the approximate
    multiplier are the ones to keep accurate.
  * Phase 2 — assignment search (:func:`search_model_policy`): hill-climb
    over per-(layer, site) frontier choices under a total modeled-energy
    budget (per-site MAC counts x per-multiply energy from ``core.energy``).
    Starts from the best uniform policy that fits the budget, then applies
    sensitivity-ordered upgrade and swap moves, accepting only strict
    fidelity improvements — so the searched heterogeneous policy never does
    worse than the best uniform point at the same budget.

Site granularity is what makes the search pay: measured per-site fidelity
sensitivity spans >10x at equal MACs (attention q/k errors are attenuated
through the softmax; ``mlp.w_down`` errors land on the residual stream
directly), while adjacent frontier tiers differ ~2-3x in standalone error.
A swap (upgrade a hot site, downgrade a cold one) beats the uniform point
exactly when the sensitivity ratio exceeds the squared tier-error ratio —
whole layers rarely clear that bar, individual sites do.

Fidelity is the float32 logit MSE against the exact-numerics reference on
the probe batch (argmax-token agreement is too coarse to rank candidate
assignments at smoke scale).  The result's ``policy`` is a
``numerics.PerLayerPolicy`` — a committable JSON artifact
(``numerics.save_policy``) consumed by ``launch/cli.py --policy-file``.

Energy here is the *multiplier* energy model (switched-literal proxy or a
calibrated ``CostModel.energy``), scaled by per-token MAC counts; it ranks
hardware design points, it is not a chip power estimate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from .. import reduction
from .pareto import CandidatePoint, pareto_front

__all__ = [
    "PolicyChoice", "SensitivityReport", "PolicySearchResult",
    "site_mac_counts", "layer_mac_counts", "frontier_choices",
    "measure_sensitivity", "assignment_policy", "policy_energy",
    "search_model_policy",
]


# --------------------------------------------------------------- MAC model
def _attn_sites(cfg) -> list[tuple[str, int]]:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return [("attn.wq", d * nh * hd), ("attn.wk", d * nkv * hd),
            ("attn.wv", d * nkv * hd), ("attn.wo", nh * hd * d)]


def _xattn_sites(cfg) -> list[tuple[str, int]]:
    # cross-attention q/k/v/o all project full heads (k/v read the encoder
    # stream; counted per token like the self-attn projections)
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return [("xattn.wq", d * nh * hd), ("xattn.wk", d * nh * hd),
            ("xattn.wv", d * nh * hd), ("xattn.wo", nh * hd * d)]


def _mlp_sites(cfg, *, shared: bool = False) -> list[tuple[str, int]]:
    if cfg.moe is not None and not shared:
        # per token: the top_k routed experts each run the full expert mlp
        m = cfg.moe.top_k * cfg.d_model * cfg.moe.d_ff_expert
        return [("moe.w_gate", m), ("moe.w_up", m), ("moe.w_down", m)]
    m = cfg.d_model * cfg.d_ff
    return [("mlp.w_gate", m), ("mlp.w_up", m), ("mlp.w_down", m)]


def _ssm_sites(cfg) -> list[tuple[str, int]]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    return [("ssm.wz", d * d_inner), ("ssm.wx", d * d_inner),
            ("ssm.wb", d * s.n_groups * s.d_state),
            ("ssm.wc", d * s.n_groups * s.d_state),
            ("ssm.wdt", d * n_heads), ("ssm.out_proj", d_inner * d)]


def site_mac_counts(cfg) -> tuple[tuple[tuple[str, int], ...], ...]:
    """Per-token MACs through each policy-covered matmul, per flat decoder
    layer: ``out[layer] = ((site, macs), ...)``.

    Mirrors the dense call sites the numerics policy reaches (attn.*,
    xattn.*, mlp.*, moe.*, ssm.*); attention score/value products and the
    exact unembed are excluded."""
    out = []
    for kind in cfg.layer_kinds():
        if kind == "ssm":
            sites = _ssm_sites(cfg)
        elif kind == "shared_attn":
            sites = _attn_sites(cfg) + _mlp_sites(cfg, shared=True)
        elif kind == "cross":
            sites = _attn_sites(cfg) + _xattn_sites(cfg) + _mlp_sites(cfg)
        else:  # full / swa
            sites = _attn_sites(cfg) + _mlp_sites(cfg)
        out.append(tuple(sites))
    return tuple(out)


def layer_mac_counts(cfg) -> tuple[int, ...]:
    """Per-token MACs per flat decoder layer (site counts summed)."""
    return tuple(sum(m for _, m in sites) for sites in site_mac_counts(cfg))


# ----------------------------------------------------------------- choices
@dataclasses.dataclass(frozen=True)
class PolicyChoice:
    """One assignable design point: a numerics policy + its per-MAC energy
    and measured standalone error (frontier coordinates)."""

    label: str
    numerics: Any                 # AMRNumerics
    energy_per_mac: float
    err: float                    # |measured err_key| of the schedule (0 = exact)


def frontier_choices(
    points: Sequence[CandidatePoint],
    *,
    err_key: str = "mared",
    include_exact: bool = True,
    cost_fn: Callable | None = None,
    prefix: str = "dse",
) -> list[PolicyChoice]:
    """Sweep ``CandidatePoint``s -> assignable per-site design choices.

    The (|err_key|, energy) frontier is recomputed here over ALL explored
    points rather than reusing the sweep's ``frontier`` flags: the sweep may
    have ranked on a different metric (default ``mred``, whose signed
    cancellation can drop designs that are non-dominated on ``mared``), and
    the search wants the DENSEST monotone error ladder available — swap
    moves only pay when adjacent tiers are close.

    Each frontier schedule is registered as a NAMED injection handle
    (``"<prefix>:b<border>.<rank>"``) so the resulting policy's
    ``schedule_ref`` strings survive JSON round-trips: re-running
    ``frontier_choices`` on the same sweep in a fresh process re-registers
    the same handles (the ``on_restore`` idiom, docs/numerics.md#policy-files).
    Returned sorted by ascending energy (most approximate first), with the
    exact reference design appended when ``include_exact``.
    """
    from repro import numerics as num
    from repro.numerics import injection
    from .. import energy as energy_mod

    cost_fn = cost_fn or energy_mod.literal_energy_proxy
    points = list(points)
    if not points:
        raise ValueError("empty sweep result")
    n_digits = points[0].n_digits
    if n_digits != 2:
        raise ValueError(
            f"model policies run on the int8 (2-digit) matmul path; the "
            f"sweep explored n_digits={n_digits}")
    flags = pareto_front([abs(float(p.measured[err_key])) for p in points],
                         [p.energy for p in points])
    front = sorted((p for p, f in zip(points, flags) if f),
                   key=lambda p: p.energy)
    choices = []
    for p in front:
        handle = injection.register_schedule(
            p.schedule, name=f"{prefix}:b{p.border}.{p.candidate}")
        nm = num.AMRNumerics("amr_inject", border=p.border, schedule_ref=handle)
        choices.append(PolicyChoice(
            handle, nm, float(p.energy), abs(float(p.measured[err_key]))))
    if include_exact:
        exact_energy = float(cost_fn(reduction.get_schedule(n_digits, None)))
        choices.append(PolicyChoice(
            "exact", num.AMRNumerics("exact"), exact_energy, 0.0))
    return sorted(choices, key=lambda c: (c.energy_per_mac, c.err))


# ------------------------------------------------------------- sensitivity
@dataclasses.dataclass
class SensitivityReport:
    """Exact-error mass injected by the probe design, per coordinate."""

    coords: dict[tuple[str, int], float]  # (site, flat layer) -> sum |err|
    per_layer: tuple[float, ...]          # aggregated over sites
    loss: float                           # probe-batch loss under the probe

    def mass(self, site: str, layer: int) -> float:
        return self.coords.get((site, layer), 0.0)

    def ranked_layers(self) -> list[int]:
        """Flat layer indices, most error-sensitive first."""
        return sorted(range(len(self.per_layer)),
                      key=lambda i: -self.per_layer[i])


def measure_sensitivity(cfg, params, batch, *, probe=None,
                        aux_weight: float = 0.01) -> SensitivityReport:
    """Phase 1: per-(site, layer) exact-error mass in ONE forward/backward.

    Runs the real ``train.steps.loss_fn`` (value_and_grad, so the measured
    activations are the training-time ones) under a uniform probe policy
    with ``AuditTrace(compare="exact")``: every approximate matmul replays
    its exact counterpart and the audit accumulates ``sum |approx - exact|``
    per call-site coordinate.  The probe rides ``PerLayerPolicy`` with
    ``static_unroll=True`` and ``remat="none"`` — audit callbacks are
    dropped inside grad-of-scan and double-counted under remat, so the
    probe forces the plain unrolled layer loop.
    """
    from repro import numerics as num
    from repro.train.steps import loss_fn

    probe = probe or num.AMRNumerics("amr_inject", border=8)
    probe_cfg = dataclasses.replace(
        cfg,
        numerics=num.PerLayerPolicy(default=probe, static_unroll=True),
        remat="none")
    trace = num.AuditTrace(compare="exact")

    def lf(p):
        loss, _ = loss_fn(probe_cfg, p, batch["tokens"], batch["targets"],
                          batch.get("extra"), aux_weight=aux_weight,
                          step=jnp.zeros((), jnp.int32))
        return loss

    with num.numerics_scope(audit=trace):
        loss, _ = jax.value_and_grad(lf)(params)
        loss.block_until_ready()
    jax.effects_barrier()

    n_layers = len(cfg.layer_kinds())
    per_layer = [0.0] * n_layers
    coords: dict[tuple[str, int], float] = {}
    for (site, layer), ent in trace.coords.items():
        mass = float(ent["sum_abs_diff"])
        coords[(site, layer)] = mass
        if 0 <= layer < n_layers:
            per_layer[layer] += mass
    return SensitivityReport(coords, tuple(per_layer), float(loss))


# ------------------------------------------------------------------ search
def assignment_policy(units: Sequence[tuple[int, str]],
                      assignment: Sequence[int],
                      choices: Sequence[PolicyChoice]):
    """Per-unit choice indices -> a ``PerLayerPolicy`` artifact.

    ``units`` are ``(flat layer, site)`` coordinates.  Coordinates outside
    the unit list (encoder layers, unembed) resolve the exact default."""
    from repro import numerics as num

    return num.PerLayerPolicy(
        default=num.AMRNumerics("exact"),
        layer_sites=tuple((layer, site, choices[a].numerics)
                          for (layer, site), a in zip(units, assignment)))


def policy_energy(unit_macs: Sequence[int], assignment: Sequence[int],
                  choices: Sequence[PolicyChoice]) -> float:
    """Modeled per-token multiplier energy of one assignment."""
    return float(sum(m * choices[a].energy_per_mac
                     for m, a in zip(unit_macs, assignment)))


@dataclasses.dataclass
class PolicySearchResult:
    policy: Any                    # PerLayerPolicy
    units: list[tuple[int, str]]   # (flat layer, site) coordinates searched
    assignment: tuple[int, ...]    # per unit, index into choices
    choices: list[PolicyChoice]
    energy: float                  # modeled per-token multiplier energy
    fidelity: float                # float32 logit MSE vs exact reference
    loss: float                    # probe-batch LM loss under the policy
    budget: float
    exact_energy: float            # all-exact assignment energy (scale ref)
    uniform: dict[str, dict]       # per choice label: energy/fidelity/loss/feasible
    sensitivity: SensitivityReport
    history: list[dict]            # accepted moves

    @property
    def best_uniform(self) -> dict:
        """The budget-feasible uniform point the search had to beat."""
        feas = {k: v for k, v in self.uniform.items() if v["feasible"]}
        return min(feas.values(), key=lambda v: v["fidelity"])


def _eval_policy(cfg, params, batch, policy, aux_weight):
    """(loss, float32 logits) of the probe batch under one policy."""
    from repro.train.steps import loss_fn

    ecfg = dataclasses.replace(cfg, numerics=policy, remat="none")
    loss, (_, logits) = loss_fn(
        ecfg, params, batch["tokens"], batch["targets"], batch.get("extra"),
        aux_weight=aux_weight, step=jnp.zeros((), jnp.int32),
        with_logits=True)
    return float(loss), logits.astype(jnp.float32)


def search_model_policy(
    cfg, params, batch, choices: Sequence[PolicyChoice],
    *,
    budget: float | None = None,
    budget_frac: float = 0.7,
    sensitivity: SensitivityReport | None = None,
    probe=None,
    max_moves: int = 12,
    beam: int = 4,
    aux_weight: float = 0.01,
) -> PolicySearchResult:
    """Phase 2: hill-climb per-(layer, site) assignments under a budget.

    ``budget`` caps the modeled per-token multiplier energy (default:
    ``budget_frac`` of the all-exact energy).  Start = the budget-feasible
    uniform assignment with the best measured fidelity; each round proposes
    up to ``beam`` sensitivity-ordered moves — *site-class* moves first
    (upgrade every layer's instance of a hot site, or swap a hot class up
    while a cold class goes down a tier; often a net energy SAVING), then
    single-unit swaps for fine-tuning — and accepts the best strict
    fidelity improvement.  Terminates when no proposal improves or after
    ``max_moves`` accepted moves.

    Move ordering is CALIBRATED, not just audited: the phase-1 audit mass
    measures the error a site injects locally, but propagation differs
    wildly per site (softmax attenuates q/k error; ``mlp.w_down`` lands on
    the residual stream), so the search first measures each site class's
    isolated fidelity impact (one forward per class) and ranks by measured
    fidelity per MAC, distributing within a class by audit mass.  Every
    candidate evaluation is one forward of the probe batch (a fresh trace
    per distinct policy — run this on ``reduced()``-scale configs).
    """
    choices = sorted(choices, key=lambda c: (c.energy_per_mac, c.err))
    per_layer_sites = site_mac_counts(cfg)
    units: list[tuple[int, str]] = []
    unit_macs: list[int] = []
    for layer, sites in enumerate(per_layer_sites):
        for site, m in sites:
            units.append((layer, site))
            unit_macs.append(m)
    n_units = len(units)
    n_choice = len(choices)
    exact_energy = policy_energy(unit_macs, [n_choice - 1] * n_units, choices)
    budget = float(budget) if budget is not None else budget_frac * exact_energy

    if sensitivity is None:
        sensitivity = measure_sensitivity(cfg, params, batch, probe=probe,
                                          aux_weight=aux_weight)

    from repro import numerics as num
    exact_nm = num.AMRNumerics("exact")
    _, ref_logits = _eval_policy(
        cfg, params, batch, num.UniformPolicy(exact_nm), aux_weight)

    def eval_policy(policy):
        loss, logits = _eval_policy(cfg, params, batch, policy, aux_weight)
        return loss, float(jnp.mean((logits - ref_logits) ** 2))

    def fidelity_of(assignment):
        return eval_policy(assignment_policy(units, assignment, choices))

    # uniform reference points (the frontier the search must dominate)
    uniform: dict[str, dict] = {}
    for ci, c in enumerate(choices):
        e = policy_energy(unit_macs, [ci] * n_units, choices)
        loss, fid = fidelity_of([ci] * n_units)
        uniform[c.label] = {"label": c.label, "energy": e, "loss": loss,
                            "fidelity": fid, "feasible": e <= budget}
    feasible = [ci for ci, c in enumerate(choices)
                if uniform[c.label]["feasible"]]
    if not feasible:
        raise ValueError(
            f"no uniform choice fits budget={budget:.4g} (cheapest uniform "
            f"needs {min(u['energy'] for u in uniform.values()):.4g}); "
            f"raise the budget or add cheaper frontier points")
    start = min(feasible, key=lambda ci: uniform[choices[ci].label]["fidelity"])

    # phase 1b — calibrate: isolated fidelity of each site class at the
    # start tier (exact everywhere else) measures PROPAGATED impact
    class_macs: dict[str, int] = {}
    class_mass: dict[str, float] = {}
    for (layer, site), m in zip(units, unit_macs):
        class_macs[site] = class_macs.get(site, 0) + m
        class_mass[site] = class_mass.get(site, 0.0) + sensitivity.mass(site, layer)
    probe_tier = min(start, n_choice - 2)  # exact probes nothing
    class_fid: dict[str, float] = {}
    for site in class_macs:
        _, f = eval_policy(num.PerLayerPolicy(
            default=exact_nm, sites={site: choices[probe_tier].numerics}))
        class_fid[site] = f
    class_density = {s: class_fid[s] / max(class_macs[s], 1)
                     for s in class_macs}
    classes_hot = sorted(class_macs, key=lambda s: -class_density[s])

    def unit_density(u):
        layer, site = units[u]
        share = (sensitivity.mass(site, layer) / class_mass[site]
                 if class_mass.get(site) else 1.0)
        return class_fid[site] * share / max(unit_macs[u], 1)

    by_sens = sorted(range(n_units), key=lambda u: -unit_density(u))

    assignment = [start] * n_units
    cur_energy = uniform[choices[start].label]["energy"]
    cur_loss = uniform[choices[start].label]["loss"]
    cur_fid = uniform[choices[start].label]["fidelity"]
    history: list[dict] = []

    def unit_name(u):
        layer, site = units[u]
        return f"L{layer}.{site}"

    def class_shift(base, site, delta):
        """Shift every unit of one site class a tier (None when any unit
        cannot move)."""
        a = list(base)
        for i, (_, s) in enumerate(units):
            if s == site:
                a[i] += delta
                if not 0 <= a[i] < n_choice:
                    return None
        return a

    def propose():
        seen: set[tuple] = set()
        props: list[tuple[str, list[int]]] = []

        def add(label, a):
            if a is not None and tuple(a) not in seen \
                    and policy_energy(unit_macs, a, choices) <= budget:
                seen.add(tuple(a))
                props.append((label, a))

        # class-level moves: biggest measured-fidelity leverage first
        for hot in classes_hot:
            if len(props) >= beam:
                return props
            up = class_shift(assignment, hot, +1)
            add(f"class {hot}+", up)
            if up is not None:
                for cold in reversed(classes_hot):  # coldest class first
                    if cold == hot:
                        continue
                    add(f"class {hot}+ {cold}-", class_shift(up, cold, -1))
                    break
        # unit-level swaps: fine-tuning within the remaining beam
        for hot in by_sens:
            if len(props) >= beam:
                return props
            if assignment[hot] >= n_choice - 1:
                continue
            up = list(assignment)
            up[hot] += 1
            add(f"unit {unit_name(hot)}+", up)
            for cold in reversed(by_sens):
                if cold == hot or assignment[cold] <= 0:
                    continue
                sw = list(up)
                sw[cold] -= 1
                add(f"unit {unit_name(hot)}+ {unit_name(cold)}-", sw)
                break
        return props

    for _ in range(max_moves):
        best = None
        for label, cand in propose():
            loss, fid = fidelity_of(cand)
            if fid < cur_fid and (best is None or fid < best[2]):
                best = (label, cand, fid, loss)
        if best is None:
            break
        label, assignment, cur_fid, cur_loss = best
        cur_energy = policy_energy(unit_macs, assignment, choices)
        history.append({"move": label, "energy": cur_energy,
                        "fidelity": cur_fid, "loss": cur_loss})

    return PolicySearchResult(
        policy=assignment_policy(units, assignment, choices),
        units=units, assignment=tuple(assignment), choices=list(choices),
        energy=cur_energy, fidelity=cur_fid, loss=cur_loss, budget=budget,
        exact_energy=exact_energy, uniform=uniform,
        sensitivity=sensitivity, history=history)

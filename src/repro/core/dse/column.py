"""Column-local DSE solvers: Fig. 3 branch-and-bound + an exact DP profile.

``assign_column`` is the faithful implementation of the paper's Fig. 3
``DSE_FA_Assign`` with two documented fixes (see DESIGN.md):

  * Fig. 3 line 1 reads ``FA_cnt = (pos_cnt + neg_cnt) % 3`` — a modulus
    cannot count full adders; we use ``(pos_cnt + neg_cnt) // 3`` (triples
    consumed), the remainder being handled by an exact HA (2 bits) or a
    pass-through (1 bit) exactly as in the multiplier structure (Fig. 1.b).
  * The paper's bounds 2/3 prune on the *sign* of the running error when a
    single polarity remains; when only one polarity remains the assignment
    is *forced*, so we evaluate the forced tail directly — equivalent
    effect, but guaranteed admissible (never prunes the optimum; property-
    tested against brute force).

Bound 1 is the standard admissible bound: each remaining FA changes the
expected error by at most ``max |avg_err| = 0.5``, so a branch whose best
achievable |final error| already exceeds the incumbent is cut.

Branches per node (Fig. 3 lines 13-24): FA_PP (3 pos), FA_PN1/FA_PN2
(2 pos + 1 neg), FA_NP1/FA_NP2 (1 pos + 2 neg), FA_NN (3 neg), plus the
exact FA (any feasible polarity mix, zero error) when assigning the border
column.

``column_profile`` is the complementary *exact dynamic program*: for a given
``(pos_cnt, neg_cnt)`` it enumerates every achievable total column error
(errors are quarter-multiples, so the state space is tiny) with one
canonical representative cell list per value.  It serves three roles:

  * a brute-force-equivalent oracle that stays cheap on tall columns, so
    optimality of ``assign_column`` is property-testable far beyond the
    exponential ``brute_force_column``'s reach,
  * the branch generator of the whole-multiplier search (multiplier.py):
    a column's decision space IS its achievable-error profile,
  * ``assign_column_topk``, the ranked k-best used to seed diverse
    full-multiplier candidates for the measured Pareto sweep.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from functools import lru_cache

from ..cells import CELLS

# (cell name, pos consumed, neg consumed, avg err as Fraction)
_Q = Fraction(1, 4)
_APPROX_BRANCHES = [
    ("FA_PP", 3, 0, Fraction(CELLS["FA_PP"].avg_err).limit_denominator(4)),
    ("FA_PN1", 2, 1, Fraction(CELLS["FA_PN1"].avg_err).limit_denominator(4)),
    ("FA_PN2", 2, 1, Fraction(CELLS["FA_PN2"].avg_err).limit_denominator(4)),
    ("FA_NP1", 1, 2, Fraction(CELLS["FA_NP1"].avg_err).limit_denominator(4)),
    ("FA_NP2", 1, 2, Fraction(CELLS["FA_NP2"].avg_err).limit_denominator(4)),
    ("FA_NN", 0, 3, Fraction(CELLS["FA_NN"].avg_err).limit_denominator(4)),
]
_EXACT_BRANCHES = [  # exact FA on any feasible polarity mix (border column only)
    ("FA", 3, 0, Fraction(0)),
    ("FA", 2, 1, Fraction(0)),
    ("FA", 1, 2, Fraction(0)),
    ("FA", 0, 3, Fraction(0)),
]
MAX_ABS_STEP = Fraction(1, 2)  # max |avg err| any single FA can contribute


@dataclasses.dataclass
class DSEResult:
    cells: list[tuple[str, int, int]]  # (cell name, pos consumed, neg consumed)
    err: Fraction                       # err_in + sum of assigned cell errors
    nodes: int                          # search-tree nodes visited (reporting)


def assign_column(
    pos_cnt: int,
    neg_cnt: int,
    err_in: float | Fraction = 0,
    *,
    allow_exact_fa: bool = False,
) -> DSEResult:
    """Optimal FA assignment for one column of one PPR stage.

    Consumes ``(pos_cnt + neg_cnt) // 3`` triples; minimises
    ``|err_in + sum(avg_err of chosen cells)|``. Leftover bits (< 3) are the
    caller's to pass through / HA. Returns the chosen cells in consumption
    order.
    """
    err_in = Fraction(err_in).limit_denominator(1 << 20)
    n_fa = (pos_cnt + neg_cnt) // 3
    branches = _APPROX_BRANCHES + (_EXACT_BRANCHES if allow_exact_fa else [])

    best_abs: list[Fraction] = [abs(err_in) + MAX_ABS_STEP * n_fa + 1]
    best_cells: list[list] = [[]]
    nodes = [0]
    memo: dict[tuple, Fraction] = {}

    def rec(p: int, n: int, err: Fraction, chosen: list) -> None:
        nodes[0] += 1
        remaining = (p + n) // 3
        if remaining == 0:
            if abs(err) < best_abs[0]:
                best_abs[0] = abs(err)
                best_cells[0] = list(chosen)
            return
        # Bound 1: best achievable |final error| from here.
        floor = abs(err) - MAX_ABS_STEP * remaining
        if floor > 0 and floor >= best_abs[0]:
            return
        # Dominance memo: if we reached (p, n) before with the same error,
        # the subtree is identical — skip re-expansion unless it could win.
        key = (p, n, err)
        if key in memo:
            return
        memo[key] = err
        # Forced tails (paper bounds 2/3, made exact): single polarity left.
        # Only valid when the exact FA is not a branch option (non-border
        # columns) — with exact FAs allowed nothing is forced.
        if allow_exact_fa:
            pass
        elif n == 0 and p >= 3:
            # all remaining must be FA_PP
            e = err
            tail = []
            k = p
            while k >= 3:
                e += _APPROX_BRANCHES[0][3]
                tail.append(("FA_PP", 3, 0))
                k -= 3
            if abs(e) < best_abs[0]:
                best_abs[0] = abs(e)
                best_cells[0] = list(chosen) + tail
            return
        elif p == 0 and n >= 3:
            e = err
            tail = []
            k = n
            while k >= 3:
                e += _APPROX_BRANCHES[5][3]
                tail.append(("FA_NN", 0, 3))
                k -= 3
            if abs(e) < best_abs[0]:
                best_abs[0] = abs(e)
                best_cells[0] = list(chosen) + tail
            return
        for name, dp, dn, de in branches:
            if p >= dp and n >= dn and (p - dp + n - dn) >= 0:
                chosen.append((name, dp, dn))
                rec(p - dp, n - dn, err + de, chosen)
                chosen.pop()

    rec(pos_cnt, neg_cnt, err_in, [])
    total = err_in + sum(
        Fraction(CELLS[c].avg_err).limit_denominator(4) for c, _, _ in best_cells[0]
    )
    return DSEResult(best_cells[0], total, nodes[0])


def brute_force_column(
    pos_cnt: int, neg_cnt: int, err_in: float | Fraction = 0, *, allow_exact_fa: bool = False
) -> Fraction:
    """Exhaustive minimum |final error| — oracle for property tests."""
    err_in = Fraction(err_in).limit_denominator(1 << 20)
    branches = _APPROX_BRANCHES + (_EXACT_BRANCHES if allow_exact_fa else [])
    best = [None]

    def rec(p, n, err):
        if (p + n) // 3 == 0:
            a = abs(err)
            if best[0] is None or a < best[0]:
                best[0] = a
            return
        for name, dp, dn, de in branches:
            if p >= dp and n >= dn:
                rec(p - dp, n - dn, err + de)

    rec(pos_cnt, neg_cnt, err_in)
    return best[0]


# ---------------------------------------------------------------------------
# exact achievable-error profile (dynamic program)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def column_profile(
    pos_cnt: int, neg_cnt: int, allow_exact_fa: bool = False
) -> dict[Fraction, tuple[tuple[str, int, int], ...]]:
    """Every achievable total column error -> one canonical cell assignment.

    Exhaustive-equivalent by construction: the DP unions branch outcomes over
    the same branch set as ``brute_force_column``, but keyed by error sum —
    cell errors are quarter-multiples in [-1/2, +1/2] and a column consumes
    ``(pos+neg)//3`` triples, so the profile has O(height) entries instead of
    O(6^height) leaves.  The representative per error value is the
    lexicographically smallest sorted cell tuple (deterministic across runs).
    Callers must not mutate the returned dict (it is cached).
    """
    if (pos_cnt + neg_cnt) // 3 == 0:
        return {Fraction(0): ()}
    branches = _APPROX_BRANCHES + (_EXACT_BRANCHES if allow_exact_fa else [])
    out: dict[Fraction, tuple] = {}
    for name, dp, dn, de in branches:
        if pos_cnt >= dp and neg_cnt >= dn:
            sub = column_profile(pos_cnt - dp, neg_cnt - dn, allow_exact_fa)
            for s, cells in sub.items():
                total = de + s
                cand = tuple(sorted(cells + ((name, dp, dn),)))
                if total not in out or cand < out[total]:
                    out[total] = cand
    return out


def assign_column_topk(
    pos_cnt: int,
    neg_cnt: int,
    err_in: float | Fraction = 0,
    *,
    k: int = 4,
    allow_exact_fa: bool = False,
) -> list[DSEResult]:
    """The ``k`` best column assignments ranked by |err_in + column error|.

    ``[0]`` achieves the same optimum as ``assign_column`` (both are exact);
    the tail seeds alternative whole-multiplier candidates for the measured
    Pareto sweep.  Ties rank the more negative error first, matching the
    paper's preference for designs whose mean error straddles zero.
    """
    err_in = Fraction(err_in).limit_denominator(1 << 20)
    profile = column_profile(pos_cnt, neg_cnt, allow_exact_fa)
    ranked = sorted(profile.items(), key=lambda kv: (abs(err_in + kv[0]), kv[0]))
    return [
        DSEResult(list(cells), err_in + s, len(profile))
        for s, cells in ranked[:k]
    ]

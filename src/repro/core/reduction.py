"""Wallace-tree partial-product reduction with exact/approximate cells.

Builds a *static schedule*: stages of (cell, input-bit-ids, output-bit-ids)
until every column holds at most two bits. Bit-accurate evaluation then
replays the schedule vectorised over a batch (numpy uint8).

Region policy per column ``p`` and border ``b`` (paper §III):
  * approximate part, ``p < b``  : approximate FAs chosen by the DSE + exact HA
  * border column,    ``p == b`` : DSE may additionally pick exact FAs
  * exact part,       ``p > b``  : exact FA/HA only
``b = None`` gives the exact MRSD multiplier.

Expected-error bookkeeping: the DSE receives the accumulated expected
multiplier error scaled into units of the current column weight
(``E / 2**p``), maintained exactly with ``Fraction`` — a unit of error at
column p-1 weighs half a unit at column p (see DESIGN.md on the Fig. 3
error-carry interpretation).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from fractions import Fraction
from functools import lru_cache

import numpy as np

from . import dse, ppgen
from .cells import CELLS, output_polarity


@dataclasses.dataclass
class CellGroup:
    """All same-type cells of one stage, vectorised."""

    name: str
    in_ids: np.ndarray      # (n_cells, n_in) int64 bit ids
    sum_ids: np.ndarray     # (n_cells,) output bit ids
    carry_ids: np.ndarray   # (n_cells,) output bit ids


@dataclasses.dataclass
class Schedule:
    n_digits: int
    border: int | None
    layout: ppgen.PPLayout
    stages: list[list[CellGroup]]
    n_bits: int                     # total wires incl. PP bits
    bit_polarity: np.ndarray        # (n_bits,) 0 pos / 1 neg
    final_ids: np.ndarray           # bit ids surviving reduction
    final_positions: np.ndarray
    expected_error: Fraction        # accumulated expected (mean) value error
    cell_counts: dict[str, int]
    dse_nodes: int

    @property
    def n_stages(self) -> int:
        return len(self.stages)


def build_schedule(n_digits: int, border: int | None, assigner=None) -> Schedule:
    """Build the static reduction schedule for one design point.

    ``assigner`` is the pluggable DSE policy for approx/border columns:
    ``assigner(p, pos_cnt, neg_cnt, err_scaled, allow_exact_fa)`` returns the
    ``(cell, dp, dn)`` list to instantiate (``err_scaled`` is the accumulated
    expected error in units of ``2**p``).  ``None`` (the default, and the
    only policy the ``get_schedule`` cache ever uses) runs the paper's
    per-column Fig. 3 branch-and-bound (``dse.assign_column``); the DSE
    export path (``dse.materialize``) passes a replay policy that re-emits a
    recorded whole-multiplier assignment instead.
    """
    layout = ppgen.build_pp_layout(n_digits)
    n_pp = layout.n_pp

    bit_pol: list[int] = list(layout.polarity.astype(int))
    # columns: position -> (list of pos bit ids, list of neg bit ids)
    cols: dict[int, tuple[list, list]] = defaultdict(lambda: ([], []))
    for bid in range(n_pp):
        p = int(layout.position[bid])
        cols[p][int(layout.polarity[bid])].append(bid)

    def new_bit(pol: int) -> int:
        bit_pol.append(pol)
        return len(bit_pol) - 1

    stages: list[list[CellGroup]] = []
    e_abs = Fraction(0)  # exact expected multiplier error so far
    cell_counts: dict[str, int] = defaultdict(int)
    dse_nodes = 0

    def col_height(c):
        return len(c[0]) + len(c[1])

    while any(col_height(c) > 2 for c in cols.values()):
        groups: dict[str, list] = defaultdict(list)  # name -> (in_ids, sum_id, carry_id, neg_in)
        next_cols: dict[int, tuple[list, list]] = defaultdict(lambda: ([], []))

        for p in sorted(cols.keys()):
            pos_bits, neg_bits = cols[p]
            h = len(pos_bits) + len(neg_bits)
            if h == 1:
                for bid in pos_bits + neg_bits:
                    next_cols[p][bit_pol[bid]].append(bid)
                continue
            # h == 2: Wallace groups every column each stage — an HA here
            # absorbs the neighbour's incoming carry and avoids a ripple tail
            # of height-3 columns (which would serialise the tree).

            region_approx = border is not None and p < border
            region_border = border is not None and p == border

            chosen: list[tuple[str, int, int]]
            if (region_approx or region_border) and assigner is not None:
                chosen = list(assigner(
                    p, len(pos_bits), len(neg_bits),
                    e_abs / Fraction(2**p), region_border,
                ))
            elif region_approx or region_border:
                res = dse.assign_column(
                    len(pos_bits), len(neg_bits),
                    e_abs / Fraction(2**p),
                    allow_exact_fa=region_border,
                )
                dse_nodes += res.nodes
                chosen = res.cells
            else:
                # exact region: FAs on triples, posibits first
                chosen = []
                np_, nn_ = len(pos_bits), len(neg_bits)
                while np_ + nn_ >= 3:
                    dp = min(3, np_)
                    dn = 3 - dp
                    chosen.append(("FA", dp, dn))
                    np_ -= dp
                    nn_ -= dn

            pq = list(pos_bits)
            nq = list(neg_bits)
            for name, dp, dn, in chosen:
                ins = [pq.pop() for _ in range(dp)] + [nq.pop() for _ in range(dn)]
                spol, cpol = output_polarity(3, dn)
                sid = new_bit(int(spol))
                cid = new_bit(int(cpol))
                groups[name].append((ins, sid, cid))
                cell_counts[name] += 1
                next_cols[p][int(spol)].append(sid)
                next_cols[p + 1][int(cpol)].append(cid)
                if CELLS[name].approx:
                    e_abs += Fraction(CELLS[name].avg_err).limit_denominator(4) * (2**p)

            # remainder: 2 bits -> exact HA, 1 bit -> pass-through
            rem = pq + nq
            if len(rem) == 2:
                dn = sum(1 for b in rem if bit_pol[b] == 1)
                spol, cpol = output_polarity(2, dn)
                # order inputs pos-first for a deterministic 2-bit index
                rem = sorted(rem, key=lambda b: bit_pol[b])
                sid = new_bit(int(spol))
                cid = new_bit(int(cpol))
                groups["HA"].append((rem, sid, cid))
                cell_counts["HA"] += 1
                next_cols[p][int(spol)].append(sid)
                next_cols[p + 1][int(cpol)].append(cid)
            elif len(rem) == 1:
                b = rem[0]
                next_cols[p][bit_pol[b]].append(b)

        stage_groups = []
        for name, items in sorted(groups.items()):
            in_ids = np.array([i[0] for i in items], dtype=np.int64)
            sum_ids = np.array([i[1] for i in items], dtype=np.int64)
            carry_ids = np.array([i[2] for i in items], dtype=np.int64)
            stage_groups.append(CellGroup(name, in_ids, sum_ids, carry_ids))
        stages.append(stage_groups)
        cols = next_cols

    final_ids = []
    final_positions = []
    for p in sorted(cols.keys()):
        for bid in cols[p][0] + cols[p][1]:
            final_ids.append(bid)
            final_positions.append(p)

    return Schedule(
        n_digits=n_digits,
        border=border,
        layout=layout,
        stages=stages,
        n_bits=len(bit_pol),
        bit_polarity=np.array(bit_pol, dtype=np.uint8),
        final_ids=np.array(final_ids, dtype=np.int64),
        final_positions=np.array(final_positions, dtype=np.int64),
        expected_error=e_abs,
        cell_counts=dict(cell_counts),
        dse_nodes=dse_nodes,
    )


@lru_cache(maxsize=None)
def get_schedule(n_digits: int, border: int | None) -> Schedule:
    """Process-level schedule cache: build_schedule + DSE run once per design
    point and are shared across multipliers, the jax engine and benchmarks."""
    return build_schedule(n_digits, border)


_SPLIT = 32  # result value = lo + hi * 2**_SPLIT, both exact int64


def evaluate_split(
    schedule: Schedule, xbits: np.ndarray, ybits: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Replay the schedule; returns the result as exact split integers.

    xbits/ybits: (batch, 5N) stored operand bits (ppgen.flatten_operand_bits).
    Returns (lo, hi) int64 with value = lo + hi * 2**32 — 8-digit products
    reach ~2**69, beyond both int64 and the float64 mantissa, so all exact
    arithmetic is done in this split form.
    """
    batch = xbits.shape[0]
    vals = np.zeros((batch, schedule.n_bits), dtype=np.uint8)
    vals[:, : schedule.layout.n_pp] = ppgen.eval_pp_bits(schedule.layout, xbits, ybits)

    for stage in schedule.stages:
        # all groups in a stage read the *pre-stage* wire values; outputs are
        # fresh wires, so in-place writes to new ids are race-free.
        for g in stage:
            cell = CELLS[g.name]
            ins = vals[:, g.in_ids]  # (batch, n_cells, n_in)
            if cell.n_in == 3:
                idx = (ins[..., 0] << 2) | (ins[..., 1] << 1) | ins[..., 2]
            else:
                idx = (ins[..., 0] << 1) | ins[..., 1]
            vals[:, g.sum_ids] = cell.sum_np[idx]
            vals[:, g.carry_ids] = cell.carry_np[idx]

    stored = vals[:, schedule.final_ids].astype(np.int64)
    pos = schedule.final_positions
    pol = schedule.bit_polarity[schedule.final_ids].astype(np.int64)
    lo_mask = pos < _SPLIT
    w_lo = np.where(lo_mask, 1 << np.minimum(pos, _SPLIT - 1), 0).astype(np.int64)
    w_hi = np.where(~lo_mask, 1 << np.maximum(pos - _SPLIT, 0), 0).astype(np.int64)
    lo = (stored * w_lo).sum(-1) - int((pol * w_lo).sum())
    hi = (stored * w_hi).sum(-1) - int((pol * w_hi).sum())
    return lo, hi


def split_to_float(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    return hi.astype(np.float64) * float(1 << _SPLIT) + lo.astype(np.float64)


def evaluate(schedule: Schedule, xbits: np.ndarray, ybits: np.ndarray) -> np.ndarray:
    """Float64 result value (exact only below ~2**53; metrics use the split form)."""
    return split_to_float(*evaluate_split(schedule, xbits, ybits))

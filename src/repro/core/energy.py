"""Analytical delay/power/energy/area model for (A)MR-MUL designs.

The paper synthesizes with Synopsys DC on NanGate 45nm (Table II); no
synthesis flow exists here, so we use a *linear component model*

    area   = a_pp * n_pp_gates + sum_cells a_cell(type) + a_dig * n_result_digits
    energy = e_pp * n_pp_gates + sum_cells e_cell(type) + e_dig * n_result_digits
    delay  = d0 + d_fa * n_stages_exact + d_fa_approx * n_stages_border_crossed

with per-cell coefficients proportional to each cell's minimal-SOP literal
count (cells.logic_complexity) times technology scale factors. The scale
factors are **calibrated by least squares against the paper's own Table II**
(18 design points: 3 widths x {exact + 5 borders}) — the calibration fit and
its residuals are a reported benchmark artifact (benchmarks/table2_energy.py),
not hidden constants.

Rationale for the structure (DESIGN.md §2): a synthesized design's
power/area track switched capacitance ~ literal count; approximate cells are
strictly simpler (enforced in cells.py); PP gates are single gates; the
final BSD->MRSD conversion is XORs + 4-bit adders, linear in result digits.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .amrmul import AMRMultiplier
from .cells import CELLS, logic_complexity


def _cell_literals(name: str) -> int:
    c = CELLS[name]
    sk = sum(int(b) << i for i, b in enumerate(c.sum_table))
    ck = sum(int(b) << i for i, b in enumerate(c.carry_table))
    base = logic_complexity(sk) + logic_complexity(ck)
    # constants/pass-throughs still cost wiring/buffering: floor of 1 literal
    return max(base, 1)


CELL_LITERALS = {name: _cell_literals(name) for name in CELLS}


@dataclasses.dataclass(frozen=True)
class DesignFeatures:
    """Structural features of one multiplier design (model inputs)."""

    n_digits: int
    border: int | None
    n_pp_gates: int
    exact_cell_literals: int
    approx_cell_literals: int
    n_result_digits: int
    n_stages: int
    approx_cell_frac: float  # fraction of FA-class cells that are approximate

    @classmethod
    def from_schedule(cls, schedule) -> "DesignFeatures":
        """Features straight from a ``reduction.Schedule`` — works for both
        cached design points and ad-hoc DSE-exported candidate schedules
        (which never pass through an ``AMRMultiplier``)."""
        counts = schedule.cell_counts
        exact_lit = sum(CELL_LITERALS[k] * v for k, v in counts.items()
                        if not CELLS[k].approx)
        approx_lit = sum(CELL_LITERALS[k] * v for k, v in counts.items()
                         if CELLS[k].approx)
        fa_total = sum(v for k, v in counts.items() if k != "HA")
        fa_approx = sum(v for k, v in counts.items() if CELLS[k].approx)
        return cls(
            n_digits=schedule.n_digits,
            border=schedule.border,
            n_pp_gates=schedule.layout.n_pp,
            exact_cell_literals=exact_lit,
            approx_cell_literals=approx_lit,
            n_result_digits=2 * schedule.n_digits + 1,
            n_stages=schedule.n_stages,
            approx_cell_frac=(fa_approx / fa_total) if fa_total else 0.0,
        )

    @classmethod
    def from_multiplier(cls, m: AMRMultiplier) -> "DesignFeatures":
        return cls.from_schedule(m.schedule)

    def basis(self) -> np.ndarray:
        """Feature vector for the linear area/energy model."""
        return np.array(
            [self.n_pp_gates, self.exact_cell_literals, self.approx_cell_literals,
             self.n_result_digits],
            dtype=np.float64,
        )


@dataclasses.dataclass
class CostModel:
    """Calibrated linear model; produced by ``fit`` (see table2 benchmark)."""

    area_coef: np.ndarray    # per basis()
    energy_coef: np.ndarray
    delay_d0: float
    delay_per_stage: float
    delay_approx_scale: float  # stage delay multiplier as approx_frac -> 1

    def area(self, f: DesignFeatures) -> float:
        return float(f.basis() @ self.area_coef)

    def energy(self, f: DesignFeatures) -> float:
        return float(f.basis() @ self.energy_coef)

    def delay(self, f: DesignFeatures) -> float:
        scale = 1.0 - self.delay_approx_scale * f.approx_cell_frac
        return self.delay_d0 + self.delay_per_stage * f.n_stages * scale

    def power(self, f: DesignFeatures) -> float:
        """mW from pJ/op at max frequency (1/delay), as the paper reports."""
        return self.energy(f) / self.delay(f)


def fit(features: list[DesignFeatures],
        area: np.ndarray, energy: np.ndarray, delay: np.ndarray) -> CostModel:
    """Non-negative least squares (projected) calibration to reference data."""
    X = np.stack([f.basis() for f in features])

    def nnls(X, y):
        # simple projected-gradient NNLS (small problems; avoids scipy dep)
        w = np.maximum(np.linalg.lstsq(X, y, rcond=None)[0], 0.0)
        lr = 1.0 / (np.linalg.norm(X, 2) ** 2 + 1e-9)
        for _ in range(5000):
            w = np.maximum(w - lr * (X.T @ (X @ w - y)), 0.0)
        return w

    area_coef = nnls(X, np.asarray(area, dtype=np.float64))
    energy_coef = nnls(X, np.asarray(energy, dtype=np.float64))

    # delay: d = d0 + d_s * stages * (1 - alpha * approx_frac); grid-search alpha
    stages = np.array([f.n_stages for f in features], dtype=np.float64)
    fr = np.array([f.approx_cell_frac for f in features], dtype=np.float64)
    dly = np.asarray(delay, dtype=np.float64)
    best = None
    for alpha in np.linspace(0.0, 0.6, 121):
        A = np.stack([np.ones_like(stages), stages * (1 - alpha * fr)], axis=1)
        coef, *_ = np.linalg.lstsq(A, dly, rcond=None)
        resid = float(((A @ coef - dly) ** 2).sum())
        if best is None or resid < best[0]:
            best = (resid, float(coef[0]), float(coef[1]), float(alpha))
    _, d0, ds, alpha = best
    return CostModel(area_coef, energy_coef, d0, ds, alpha)


def literal_energy_proxy(schedule) -> float:
    """Model-free energy surrogate: unit-weight switched-literal count.

    ``basis() @ 1`` — PP gates + cell SOP literals + result digits — tracks
    switched capacitance without any calibration data, so the DSE Pareto
    sweep has a deterministic default cost axis.  Pass a calibrated
    ``CostModel.energy`` instead (``benchmarks.dse_bench`` does) for pJ
    predictions comparable to the paper's Table II.
    """
    return float(DesignFeatures.from_schedule(schedule).basis().sum())


def predict(model: CostModel, m: AMRMultiplier) -> dict[str, float]:
    f = DesignFeatures.from_multiplier(m)
    return {
        "area_um2": model.area(f),
        "energy_pj": model.energy(f),
        "delay_ns": model.delay(f),
        "power_mw": model.power(f),
    }

"""Radix-16 maximally-redundant signed-digit (MRSD) number system.

Representation (paper §II.A, encoding of Jaberipur–Parhami [11]):

  * An N-digit operand has digits ``d_k`` in ``[-16, 15]`` and value
    ``sum_k d_k * 16**k``.
  * Each digit is 5 bits in 2's-complement: four *posibits* ``b0..b3``
    (values in {0,1}, weights ``2**(4k+i)``) and one *negabit* whose
    weight equals the LSB of the next digit, i.e. ``2**(4(k+1))``.
  * Negabits use the **inverted storage** convention of [11]: a negabit
    with stored bit ``s`` has arithmetic value ``s - 1`` (in {-1, 0}).
    Under this convention any three same-weight stored bits add with an
    ordinary full adder; only the *polarity interpretation* of the
    outputs changes with the number of negabit inputs (see cells.py).

Flat bit layout of an N-digit operand (used by ppgen/reduction):

  * posibits: index ``j`` in ``[0, 4N)``   -> position ``j``      (weight +2**j)
  * negabits: index ``k`` in ``[0, N)``    -> position ``4(k+1)`` (weight 2**{4(k+1)},
    value stored-1)

Value identity::

  X = sum_j  pos[j]  * 2**j  +  sum_k (neg[k] - 1) * 2**(4(k+1))

Dynamic range of N digits: ``[-16*(16**N - 1)//15, 16**N - 1]``
(N=2: [-272, 255] as quoted in the paper §IV.B).
"""
from __future__ import annotations

import numpy as np

RADIX = 16
BITS_PER_DIGIT = 4  # posibits per digit; +1 negabit
DIGIT_MIN = -16
DIGIT_MAX = 15


def n_pos_bits(n_digits: int) -> int:
    return BITS_PER_DIGIT * n_digits


def n_neg_bits(n_digits: int) -> int:
    return n_digits


def pos_positions(n_digits: int) -> np.ndarray:
    """Bit position (log2 weight) of each posibit."""
    return np.arange(4 * n_digits, dtype=np.int64)


def neg_positions(n_digits: int) -> np.ndarray:
    """Bit position of each negabit (same weight as next digit's LSB)."""
    return 4 * (np.arange(n_digits, dtype=np.int64) + 1)


def min_value(n_digits: int) -> int:
    return -16 * (16**n_digits - 1) // 15


def max_value(n_digits: int) -> int:
    return 16**n_digits - 1


def encode(x, n_digits: int) -> np.ndarray:
    """Canonical MRSD encoding of integer(s) ``x`` into ``n_digits`` digits.

    LSD-first greedy: each digit is chosen congruent to the remainder mod 16,
    preferring the non-negative residue and falling back to ``residue - 16``
    when needed to keep the remaining value representable by the remaining
    digits (the bottom of the MRSD range requires negative digits).
    Accepts scalars or integer arrays; returns shape ``x.shape + (n_digits,)``.
    """
    x = np.asarray(x, dtype=np.int64)
    lo, hi = min_value(n_digits), max_value(n_digits)
    if np.any(x < lo) or np.any(x > hi):
        raise ValueError(f"value out of range [{lo}, {hi}] for {n_digits} digits")
    digits = np.zeros(x.shape + (n_digits,), dtype=np.int64)
    r = x.copy()
    for k in range(n_digits - 1):
        m = n_digits - 1 - k  # digits remaining after this one
        rem_lo, rem_hi = min_value(m), max_value(m)
        d_pos = r % 16  # numpy: non-negative residue
        r_pos = (r - d_pos) // 16
        use_neg = (r_pos > rem_hi) | (r_pos < rem_lo)
        d = np.where(use_neg, d_pos - 16, d_pos)
        digits[..., k] = d
        r = (r - d) // 16
    digits[..., n_digits - 1] = r
    if np.any(r < DIGIT_MIN) or np.any(r > DIGIT_MAX):
        raise ValueError("top digit out of [-16, 15]; value not representable")
    return digits


def decode(digits: np.ndarray, dtype=np.float64) -> np.ndarray:
    """Value of digit array(s); float64 by default (8-digit products exceed int64)."""
    digits = np.asarray(digits)
    n = digits.shape[-1]
    w = (16.0 ** np.arange(n)).astype(np.float64)
    return (digits.astype(np.float64) * w).sum(-1).astype(dtype)


def decode_int(digits) -> int:
    """Exact Python-int value of a single digit vector (arbitrary precision)."""
    return sum(int(d) * 16**k for k, d in enumerate(np.asarray(digits).tolist()))


def digits_to_bits(digits: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Digit array -> (posibits, stored negabits).

    digits: (..., N) in [-16, 15].
    Returns pos (..., 4N) uint8 and neg (..., N) uint8 where the negabit is
    stored inverted (stored 1 == arithmetic 0, stored 0 == arithmetic -1).
    """
    digits = np.asarray(digits, dtype=np.int64)
    if np.any(digits < DIGIT_MIN) or np.any(digits > DIGIT_MAX):
        raise ValueError("digit out of range [-16, 15]")
    n = digits.shape[-1]
    is_neg = (digits < 0).astype(np.int64)  # arithmetic negabit value is -is_neg
    b = digits + 16 * is_neg  # low nibble in [0, 15]
    shifts = np.arange(BITS_PER_DIGIT, dtype=np.int64)
    pos = ((b[..., :, None] >> shifts) & 1).astype(np.uint8)  # (..., N, 4)
    pos = pos.reshape(digits.shape[:-1] + (4 * n,))
    neg = (1 - is_neg).astype(np.uint8)  # inverted storage
    return pos, neg


def bits_to_digits(pos: np.ndarray, neg: np.ndarray) -> np.ndarray:
    """(posibits, stored negabits) -> digit array (..., N)."""
    pos = np.asarray(pos, dtype=np.int64)
    neg = np.asarray(neg, dtype=np.int64)
    n = neg.shape[-1]
    p = pos.reshape(pos.shape[:-1] + (n, BITS_PER_DIGIT))
    weights = 1 << np.arange(BITS_PER_DIGIT, dtype=np.int64)
    nibble = (p * weights).sum(-1)
    return nibble - 16 * (1 - neg)


def bits_value(pos: np.ndarray, neg: np.ndarray, dtype=np.float64) -> np.ndarray:
    """Arithmetic value of a flat bit collection (float64 for wide operands)."""
    pos = np.asarray(pos, dtype=np.float64)
    neg = np.asarray(neg, dtype=np.float64)
    npb = pos.shape[-1]
    nn = neg.shape[-1]
    wp = 2.0 ** np.arange(npb)
    wn = 2.0 ** (4 * (np.arange(nn) + 1))
    return ((pos * wp).sum(-1) + ((neg - 1.0) * wn).sum(-1)).astype(dtype)


def random_digits(rng: np.random.Generator, n_digits: int, batch: int) -> np.ndarray:
    """Uniform random digit vectors over the full redundant digit set [-16, 15].

    This is how the paper's Monte-Carlo inputs exercise both polarities
    (§IV: 50K/500K/1M random inputs).
    """
    return rng.integers(DIGIT_MIN, DIGIT_MAX + 1, size=(batch, n_digits), dtype=np.int64)


def random_values(rng: np.random.Generator, n_digits: int, batch: int) -> np.ndarray:
    """Uniform random integer values over the representable range (int64-safe widths)."""
    lo, hi = min_value(n_digits), max_value(n_digits)
    return rng.integers(lo, hi + 1, size=(batch,), dtype=np.int64)

"""Partial-product generation for the radix-16 MRSD multiplier (paper §II.B).

Every bit of X multiplies every bit of Y; the product bit lands at position
``p1 + p2`` and its polarity is the "product" of the input polarities.
With inverted negabit storage (value = stored - 1) the single-gate forms are:

  pos(x) * pos(y): value x*y          -> posibit, stored = x AND y
  pos(x) * neg(y): value x*(y-1)      -> negabit, stored = NOT(x) OR y
  neg(x) * pos(y): value (x-1)*y      -> negabit, stored = NOT(y) OR x
  neg(x) * neg(y): value (x-1)*(y-1)  -> posibit, stored = NOR(x, y)

(the paper's §II.B identities are the same one-gate-per-PP structure under
its own storage convention; ours is property-tested for exactness).

Operand bits are flattened as: indices [0, 4N) = posibits (position j),
indices [4N, 5N) = negabits (negabit k at position 4(k+1)).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import mrsd

# gate types
G_AND = 0   # pos*pos
G_ORN_X = 1  # pos(x)*neg(y): !x | y
G_ORN_Y = 2  # neg(x)*pos(y): !y | x
G_NOR = 3   # neg*neg


@dataclasses.dataclass(frozen=True)
class PPLayout:
    """Static partial-product wiring for an N x N digit MRSD multiply."""

    n_digits: int
    position: np.ndarray  # (n_pp,) int64 column of each PP bit
    polarity: np.ndarray  # (n_pp,) uint8: 0 posibit, 1 negabit
    gate: np.ndarray      # (n_pp,) uint8 gate type
    x_idx: np.ndarray     # (n_pp,) index into flattened X bits
    y_idx: np.ndarray     # (n_pp,) index into flattened Y bits

    @property
    def n_pp(self) -> int:
        return int(self.position.shape[0])

    @property
    def n_columns(self) -> int:
        return int(self.position.max()) + 1


def flatten_operand_bits(digits: np.ndarray) -> np.ndarray:
    """(..., N) digits -> (..., 5N) flat stored bits (posibits then negabits)."""
    pos, neg = mrsd.digits_to_bits(digits)
    return np.concatenate([pos, neg], axis=-1)


def operand_bit_meta(n_digits: int) -> tuple[np.ndarray, np.ndarray]:
    """(positions, polarities) for the 5N flattened operand bits."""
    positions = np.concatenate([mrsd.pos_positions(n_digits), mrsd.neg_positions(n_digits)])
    polarities = np.concatenate([
        np.zeros(4 * n_digits, dtype=np.uint8),
        np.ones(n_digits, dtype=np.uint8),
    ])
    return positions, polarities


def build_pp_layout(n_digits: int) -> PPLayout:
    """All 25*N^2 partial-product bits of an N x N digit multiply."""
    positions, polarities = operand_bit_meta(n_digits)
    nb = positions.shape[0]  # 5N
    xi, yi = np.meshgrid(np.arange(nb), np.arange(nb), indexing="ij")
    xi = xi.ravel()
    yi = yi.ravel()
    px = positions[xi]
    py = positions[yi]
    gx = polarities[xi].astype(np.int64)
    gy = polarities[yi].astype(np.int64)
    pp_pos = px + py
    pp_pol = (gx ^ gy).astype(np.uint8)  # neg*neg and pos*pos are posibits
    gate = np.where(
        (gx == 0) & (gy == 0), G_AND,
        np.where((gx == 0) & (gy == 1), G_ORN_X,
                 np.where((gx == 1) & (gy == 0), G_ORN_Y, G_NOR)),
    ).astype(np.uint8)
    return PPLayout(n_digits, pp_pos.astype(np.int64), pp_pol, gate, xi, yi)


def eval_pp_bits(layout: PPLayout, xbits: np.ndarray, ybits: np.ndarray) -> np.ndarray:
    """Stored values of all PP bits. xbits/ybits: (..., 5N) uint8 -> (..., n_pp)."""
    x = xbits[..., layout.x_idx].astype(np.uint8)
    y = ybits[..., layout.y_idx].astype(np.uint8)
    g = layout.gate
    out = np.empty_like(x)
    m = g == G_AND
    out[..., m] = x[..., m] & y[..., m]
    m = g == G_ORN_X
    out[..., m] = (1 - x[..., m]) | y[..., m]
    m = g == G_ORN_Y
    out[..., m] = (1 - y[..., m]) | x[..., m]
    m = g == G_NOR
    out[..., m] = (1 - x[..., m]) & (1 - y[..., m])
    return out


def pp_value(layout: PPLayout, pp_bits: np.ndarray) -> np.ndarray:
    """Arithmetic value of a PP bit collection (float64; oracle/testing)."""
    w = 2.0 ** layout.position.astype(np.float64)
    stored = pp_bits.astype(np.float64)
    # posibit value = stored; negabit value = stored - 1
    offs = (layout.polarity.astype(np.float64) * w).sum()
    return (stored * w).sum(-1) - offs

"""Reduction cells: exact FA/HA and the paper's six approximate FAs.

Under the inverted-negabit storage convention (mrsd.py), any three
same-weight stored bits add with an ordinary full adder on the *stored*
values; the number of negabit inputs ``k`` only fixes the polarity class
of the outputs (paper §III.A):

    k = 0 -> sum posibit, carry posibit   (FA_PP)
    k = 1 -> sum negabit, carry posibit   (FA_PN)   [consumes 2 pos + 1 neg]
    k = 2 -> sum posibit, carry negabit   (FA_NP)   [consumes 1 pos + 2 neg]
    k = 3 -> sum negabit, carry negabit   (FA_NN)

and identically for HAs (k in {0,1,2}). The *arithmetic* error of an
approximate cell equals its stored-bit error ``(2c'+s') - (x+y+z)``
because the polarity offsets are fixed by the output class.

Paper Fig. 2 defines the six approximate truth tables as an image; only
the signed average errors survive in the text.  We deterministically
*reconstruct* each table by exhaustive search over all 2^16 (sum, carry)
truth-table pairs selecting, among tables that match the published mean
error exactly, the one with minimal two-level logic complexity (SOP
literal count via prime implicants), then fewest errored input combos,
smallest max |error|, and lexicographic order as the final tie-break.
Published mean errors (error totals over the 8 input combos in
parentheses):

    FA_PP  +0.25 (+2)   FA1_PN +0.25 (+2)   FA2_PN -0.50 (-4)
    FA1_NP -0.25 (-2)   FA2_NP +0.50 (+4)   FA_NN  -0.25 (-2)

Tests assert the reconstructed tables reproduce these means exactly.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import lru_cache

import numpy as np

# ---------------------------------------------------------------------------
# exact cells (on stored bits)
# ---------------------------------------------------------------------------

_IN3 = [(x, y, z) for x in (0, 1) for y in (0, 1) for z in (0, 1)]
_IN2 = [(x, y) for x in (0, 1) for y in (0, 1)]

FA_SUM_EXACT = np.array([x ^ y ^ z for x, y, z in _IN3], dtype=np.uint8)
FA_CARRY_EXACT = np.array([(x + y + z) >> 1 for x, y, z in _IN3], dtype=np.uint8)
HA_SUM = np.array([x ^ y for x, y in _IN2], dtype=np.uint8)
HA_CARRY = np.array([x & y for x, y in _IN2], dtype=np.uint8)


# ---------------------------------------------------------------------------
# two-level logic complexity of a 3-input boolean function
# ---------------------------------------------------------------------------

def _prime_implicants(onset: frozenset[int]) -> list[tuple[int, int]]:
    """Prime implicants of a 3-var function as (mask, value) cube pairs.

    A cube (mask, value) covers minterm m iff (m & mask) == value; mask has a
    1 where the variable is fixed.
    """
    if not onset:
        return []
    cubes = set()
    for mask_bits in range(8):  # which of the 3 vars are fixed (bit i -> var i)
        for value in range(8):
            if value & ~mask_bits:
                continue
            covered = [m for m in range(8) if (m & mask_bits) == value]
            if covered and all(m in onset for m in covered):
                cubes.add((mask_bits, value))
    # prime = not strictly contained in another valid cube. Cube A=(mask,val)
    # is contained in B=(mask2,val2) iff mask2 is a subset of mask (B fixes
    # fewer vars, so is larger) and val agrees with val2 on mask2's vars.
    primes = []
    for mask, val in cubes:
        contained = any(
            (mask2, val2) != (mask, val)
            and (mask2 & ~mask) == 0
            and (val & mask2) == val2
            for mask2, val2 in cubes
        )
        if not contained:
            primes.append((mask, val))
    return primes


@lru_cache(maxsize=512)
def logic_complexity(table_key: int) -> int:
    """Minimal SOP literal count of a 3-input function (8-bit truth table key).

    Constants cost 0; exact minimum cover over prime implicants (<= ~14
    primes for 3 vars, so exhaustive subset search is fine).
    """
    onset = frozenset(m for m in range(8) if (table_key >> m) & 1)
    if len(onset) in (0, 8):
        return 0
    primes = _prime_implicants(onset)
    best = 99
    # Exhaustive over prime subsets (3-var functions have few primes).
    for r in range(1, len(primes) + 1):
        for combo in itertools.combinations(primes, r):
            covered = set()
            for mask, val in combo:
                covered.update(m for m in range(8) if (m & mask) == val)
            if covered == set(onset):
                cost = sum(bin(mask).count("1") for mask, _ in combo)
                cost += max(0, len(combo) - 1)  # OR-gate inputs
                best = min(best, cost)
    return best


def _table_key(table: np.ndarray) -> int:
    return int(sum(int(b) << i for i, b in enumerate(table)))


# ---------------------------------------------------------------------------
# approximate-FA reconstruction search
# ---------------------------------------------------------------------------

_IN_SUM = np.array([x + y + z for x, y, z in _IN3], dtype=np.int64)


@lru_cache(maxsize=None)
def _search_tables_vectorized() -> dict[int, tuple[np.ndarray, np.ndarray]]:
    """Best (sum, carry) table pair per total-error target, fully vectorized."""
    cplx = np.array([logic_complexity(k) for k in range(256)], dtype=np.int64)
    exact_cost = cplx[_table_key(FA_SUM_EXACT)] + cplx[_table_key(FA_CARRY_EXACT)]

    keys = np.arange(256, dtype=np.int64)
    tabs = ((keys[:, None] >> np.arange(8)) & 1).astype(np.int64)  # (256, 8)
    # err[ck, sk, m] = 2*c + s - (x+y+z)
    err = 2 * tabs[:, None, :] + tabs[None, :, :] - _IN_SUM[None, None, :]
    total = err.sum(-1)  # (256, 256)
    complexity = cplx[:, None] + cplx[None, :]  # (256, 256)
    n_wrong = (err != 0).sum(-1)
    max_abs = np.abs(err).max(-1)
    sum_abs = np.abs(err).sum(-1)

    out = {}
    for target in (+2, -2, +4, -4):
        ok = (total == target) & (complexity < exact_cost)
        assert ok.any(), f"no approximate FA with total error {target}"
        # lexicographic argmin over (sum_abs, max_abs, complexity, n_wrong, ck, sk):
        # smallest/most-balanced per-combo errors first (the paper's cells err by
        # at most 1 ulp per combo where achievable), then simplest logic.
        ck_grid = keys[:, None] * np.ones((1, 256), dtype=np.int64)
        sk_grid = np.ones((256, 1), dtype=np.int64) * keys[None, :]
        score = sum_abs
        for term, width in ((max_abs, 4), (complexity, 64), (n_wrong, 16),
                            (ck_grid, 256), (sk_grid, 256)):
            score = score * width + term
        score = np.where(ok, score, np.iinfo(np.int64).max)
        flat = int(np.argmin(score))
        ck, sk = flat // 256, flat % 256
        out[target] = (tabs[sk].astype(np.uint8), tabs[ck].astype(np.uint8))
    return out


def _search_table(total_err: int) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic reconstruction of an approximate-FA truth table pair."""
    return _search_tables_vectorized()[total_err]


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """A reduction cell: truth tables over stored bits + metadata."""

    name: str
    n_in: int
    sum_table: tuple  # length 2**n_in
    carry_table: tuple
    avg_err: float  # mean of (2c+s) - sum(inputs) over input combos
    approx: bool
    neg_in: int | None  # required negabit-input count (None = any mix)

    @property
    def sum_np(self) -> np.ndarray:
        return np.array(self.sum_table, dtype=np.uint8)

    @property
    def carry_np(self) -> np.ndarray:
        return np.array(self.carry_table, dtype=np.uint8)


def _mk(name, s_tab, c_tab, approx, neg_in, n_in=3) -> CellSpec:
    s = np.asarray(s_tab, dtype=np.int64)
    c = np.asarray(c_tab, dtype=np.int64)
    ins = _IN_SUM if n_in == 3 else np.array([x + y for x, y in _IN2])
    avg = float((2 * c + s - ins).mean())
    return CellSpec(name, n_in, tuple(int(v) for v in s), tuple(int(v) for v in c),
                    avg, approx, neg_in)


def _build_cells() -> dict[str, CellSpec]:
    s_pp, c_pp = _search_table(+2)
    s_pn1, c_pn1 = _search_table(+2)
    s_pn2, c_pn2 = _search_table(-4)
    s_np1, c_np1 = _search_table(-2)
    s_np2, c_np2 = _search_table(+4)
    s_nn, c_nn = _search_table(-2)
    cells = {
        "FA": _mk("FA", FA_SUM_EXACT, FA_CARRY_EXACT, False, None),
        "HA": _mk("HA", HA_SUM, HA_CARRY, False, None, n_in=2),
        "FA_PP": _mk("FA_PP", s_pp, c_pp, True, 0),
        "FA_PN1": _mk("FA_PN1", s_pn1, c_pn1, True, 1),
        "FA_PN2": _mk("FA_PN2", s_pn2, c_pn2, True, 1),
        "FA_NP1": _mk("FA_NP1", s_np1, c_np1, True, 2),
        "FA_NP2": _mk("FA_NP2", s_np2, c_np2, True, 2),
        "FA_NN": _mk("FA_NN", s_nn, c_nn, True, 3),
    }
    return cells


CELLS: dict[str, CellSpec] = _build_cells()

# Published mean errors, asserted in tests.
PAPER_AVG_ERR = {
    "FA_PP": +0.25,
    "FA_PN1": +0.25,
    "FA_PN2": -0.50,
    "FA_NP1": -0.25,
    "FA_NP2": +0.50,
    "FA_NN": -0.25,
}

# Approximate-FA names by negabit-input count (branch order follows Fig. 3).
APPROX_BY_NEG = {
    0: ["FA_PP"],
    1: ["FA_PN1", "FA_PN2"],
    2: ["FA_NP1", "FA_NP2"],
    3: ["FA_NN"],
}


def output_polarity(n_in: int, neg_in: int) -> tuple[bool, bool]:
    """(sum_is_negabit, carry_is_negabit) for a cell with ``neg_in`` negabit inputs.

    From sum(values) = sum(stored) - neg_in = 2c + s - neg_in:
      neg_in 0 -> (P, P); 1 -> (N, P); 2 -> (P, N); 3 -> (N, N).
    """
    if n_in == 2 and neg_in > 2:
        raise ValueError("HA has at most 2 negabit inputs")
    return {0: (False, False), 1: (True, False), 2: (False, True), 3: (True, True)}[neg_in]

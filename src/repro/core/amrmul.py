"""AMR-MUL: the approximate maximally-redundant signed-digit multiplier.

Facade over ppgen/reduction/dse: builds the static schedule once, then
evaluates bit-accurately (vectorised numpy) and reports the paper's
metrics, cell-usage breakdown (Fig. 5) and cost-model hooks (Table II).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from . import metrics, mrsd, ppgen, reduction


@dataclasses.dataclass(frozen=True)
class AMRMulConfig:
    n_digits: int
    border: int | None = None  # None = exact MRSD multiplier

    def tag(self) -> str:
        b = "exact" if self.border is None else f"b{self.border}"
        return f"amrmul_{self.n_digits}d_{b}"


class AMRMultiplier:
    """N x N-digit radix-16 MRSD multiplier with approximate border ``b``."""

    def __init__(self, n_digits: int, border: int | None = None):
        self.cfg = AMRMulConfig(n_digits, border)
        self.schedule = reduction.build_schedule(n_digits, border)

    # ------------------------------------------------------------------ eval
    def multiply_digits(self, x_digits: np.ndarray, y_digits: np.ndarray) -> np.ndarray:
        """(batch, N) digit arrays -> (batch,) float64 product values."""
        xb = ppgen.flatten_operand_bits(x_digits)
        yb = ppgen.flatten_operand_bits(y_digits)
        return reduction.evaluate(self.schedule, xb, yb)

    def multiply_digits_split(
        self, x_digits: np.ndarray, y_digits: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact split-integer products (lo, hi): value = lo + hi * 2**32."""
        xb = ppgen.flatten_operand_bits(x_digits)
        yb = ppgen.flatten_operand_bits(y_digits)
        return reduction.evaluate_split(self.schedule, xb, yb)

    def multiply_values(self, x, y) -> np.ndarray:
        """Integer values -> product values (canonical MRSD encoding)."""
        xd = mrsd.encode(np.asarray(x), self.cfg.n_digits)
        yd = mrsd.encode(np.asarray(y), self.cfg.n_digits)
        return self.multiply_digits(xd, yd)

    # ----------------------------------------------------------------- stats
    @property
    def n_stages(self) -> int:
        return self.schedule.n_stages

    @property
    def cell_counts(self) -> dict[str, int]:
        return dict(self.schedule.cell_counts)

    def cell_usage_percent(self) -> dict[str, float]:
        """Fig. 5-style breakdown over FA-class cells (HA excluded)."""
        fa = {k: v for k, v in self.schedule.cell_counts.items() if k != "HA"}
        total = sum(fa.values())
        return {k: 100.0 * v / total for k, v in sorted(fa.items())} if total else {}

    @property
    def expected_error(self) -> float:
        return float(self.schedule.expected_error)

    # ----------------------------------------------------------- monte carlo
    def monte_carlo(
        self,
        n_samples: int,
        seed: int = 0,
        chunk: int = 32768,
        exact_ref: "AMRMultiplier | None" = None,
    ) -> dict[str, float]:
        """Paper §IV accuracy protocol: uniform random digit-vector inputs.

        Returns MRED/MARED/NMED (signed means as in Table I) plus aux stats.
        """
        rng = np.random.default_rng(seed)
        n = self.cfg.n_digits
        if exact_ref is None:
            exact_ref = _exact_cached(n)
        max_abs = (16.0 ** n * (16.0 / 15.0)) ** 2  # |min value|^2 bound
        acc = metrics.ErrorAccumulator(max_abs=max_abs)
        remaining = n_samples
        while remaining > 0:
            b = min(chunk, remaining)
            xd = mrsd.random_digits(rng, n, b)
            yd = mrsd.random_digits(rng, n, b)
            alo, ahi = self.multiply_digits_split(xd, yd)
            elo, ehi = exact_ref.multiply_digits_split(xd, yd)
            acc.update_split(alo, ahi, elo, ehi)
            remaining -= b
        return acc.result()


@lru_cache(maxsize=8)
def _exact_cached(n_digits: int) -> AMRMultiplier:
    return AMRMultiplier(n_digits, border=None)


def exact_multiplier(n_digits: int) -> AMRMultiplier:
    return _exact_cached(n_digits)

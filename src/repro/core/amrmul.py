"""AMR-MUL: the approximate maximally-redundant signed-digit multiplier.

Facade over ppgen/reduction/dse: pulls the static schedule from the
process-level cache, then evaluates bit-accurately on one of two backends
and reports the paper's metrics, cell-usage breakdown (Fig. 5) and
cost-model hooks (Table II).

Backends (``engine=`` at construction or per call):
  * ``"numpy"`` — host replay via ``reduction.evaluate_split``,
  * ``"jax"``   — compiled batched replay via ``core.engine`` (jit + vmap),
    bit-exact against the numpy path and much faster at large batch.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from . import metrics, mrsd, ppgen, reduction

ENGINES = ("numpy", "jax")


@dataclasses.dataclass(frozen=True)
class AMRMulConfig:
    n_digits: int
    border: int | None = None  # None = exact MRSD multiplier

    def tag(self) -> str:
        b = "exact" if self.border is None else f"b{self.border}"
        return f"amrmul_{self.n_digits}d_{b}"


class AMRMultiplier:
    """N x N-digit radix-16 MRSD multiplier with approximate border ``b``."""

    def __init__(self, n_digits: int, border: int | None = None, engine: str = "numpy"):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        self.cfg = AMRMulConfig(n_digits, border)
        self.engine = engine
        self.schedule = reduction.get_schedule(n_digits, border)

    # ------------------------------------------------------------------ eval
    def multiply_digits(
        self, x_digits: np.ndarray, y_digits: np.ndarray, engine: str | None = None
    ) -> np.ndarray:
        """(batch, N) digit arrays -> (batch,) float64 product values."""
        return reduction.split_to_float(
            *self.multiply_digits_split(x_digits, y_digits, engine=engine)
        )

    def multiply_digits_split(
        self, x_digits: np.ndarray, y_digits: np.ndarray, engine: str | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact split-integer products (lo, hi): value = lo + hi * 2**32."""
        backend = engine or self.engine
        xb = ppgen.flatten_operand_bits(x_digits)
        yb = ppgen.flatten_operand_bits(y_digits)
        if backend == "jax":
            from . import engine as engine_mod  # lazy: numpy path stays jax-free

            eng = engine_mod.get_engine(self.cfg.n_digits, self.cfg.border)
            return eng.evaluate_split(xb, yb)
        if backend != "numpy":
            raise ValueError(f"engine must be one of {ENGINES}, got {backend!r}")
        return reduction.evaluate_split(self.schedule, xb, yb)

    def multiply_values(self, x, y, engine: str | None = None) -> np.ndarray:
        """Integer values -> product values (canonical MRSD encoding)."""
        xd = mrsd.encode(np.asarray(x), self.cfg.n_digits)
        yd = mrsd.encode(np.asarray(y), self.cfg.n_digits)
        return self.multiply_digits(xd, yd, engine=engine)

    # ----------------------------------------------------------------- stats
    @property
    def n_stages(self) -> int:
        return self.schedule.n_stages

    @property
    def cell_counts(self) -> dict[str, int]:
        return dict(self.schedule.cell_counts)

    def cell_usage_percent(self) -> dict[str, float]:
        """Fig. 5-style breakdown over FA-class cells (HA excluded)."""
        fa = {k: v for k, v in self.schedule.cell_counts.items() if k != "HA"}
        total = sum(fa.values())
        return {k: 100.0 * v / total for k, v in sorted(fa.items())} if total else {}

    @property
    def expected_error(self) -> float:
        return float(self.schedule.expected_error)

    # ----------------------------------------------------------- monte carlo
    def monte_carlo(
        self,
        n_samples: int,
        seed: int = 0,
        chunk: int = 32768,
        exact_ref: "AMRMultiplier | None" = None,
        engine: str | None = None,
    ) -> dict[str, float]:
        """Paper §IV accuracy protocol: uniform random digit-vector inputs.

        Returns MRED/MARED/NMED (signed means as in Table I) plus aux stats.
        """
        if exact_ref is None:
            exact_ref = _exact_cached(self.cfg.n_digits)
        return metrics.monte_carlo_metrics(
            self, exact_ref, n_samples,
            seed=seed, chunk=chunk, engine=engine or self.engine,
        )


@lru_cache(maxsize=8)
def _exact_cached(n_digits: int) -> AMRMultiplier:
    return AMRMultiplier(n_digits, border=None)


def exact_multiplier(n_digits: int) -> AMRMultiplier:
    return _exact_cached(n_digits)

"""Runtime: fault tolerance, straggler mitigation, elastic restart logic."""
from .fault import FaultTolerantLoop, Heartbeat, StragglerMonitor

__all__ = ["FaultTolerantLoop", "Heartbeat", "StragglerMonitor"]

"""Fault tolerance & straggler mitigation (the launcher's control plane).

At 1000+ nodes the failure model is: (a) hard node loss — the job restarts
from the last checkpoint, possibly on fewer/more nodes (elastic); (b) soft
hangs/stragglers — detected by step-time outliers and surfaced to the
scheduler; (c) preemption — SIGTERM arrives, we checkpoint and exit with a
resumable code. This module implements that control plane host-side:

  * ``Heartbeat``       — periodic progress file (external watchdogs/k8s
                          liveness probes key off its mtime).
  * ``StragglerMonitor``— robust step-time tracking; flags steps slower
                          than ``threshold`` x the running median.
  * ``FaultTolerantLoop``— runs step_fn with retry-from-checkpoint on
                          exception, preemption-safe checkpointing, and an
                          elastic ``remesh`` hook invoked when the device
                          count changes between restarts.

Checkpoints are mesh-agnostic (ckpt/checkpoint.py), which is what makes
the elastic path work: restore under whatever mesh exists now.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Callable

from repro.ckpt import CheckpointManager


class Heartbeat:
    def __init__(self, path: str | Path, interval_s: float = 10.0):
        self.path = Path(path)
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.payload: dict = {}

    def start(self) -> None:
        def run():
            while not self._stop.wait(self.interval_s):
                self.beat()

        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def beat(self) -> None:
        # tmp + rename: watchdogs poll this file concurrently — a reader
        # must never see a half-written JSON payload (lint rule RPL006)
        tmp = self.path.with_name(self.path.name + ".tmp")
        tmp.write_text(json.dumps({"t": time.time(), **self.payload}))
        os.replace(tmp, self.path)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1)


class StragglerMonitor:
    """Flags step times above ``threshold`` x running median (window-robust)."""

    def __init__(self, window: int = 50, threshold: float = 2.5):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.flagged: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        med = self.median()
        is_straggler = med is not None and dt > self.threshold * med
        if is_straggler:
            self.flagged.append((step, dt, med))
        self.times.append(dt)
        return is_straggler

    def median(self) -> float | None:
        if len(self.times) < 5:
            return None
        s = sorted(self.times)
        return s[len(s) // 2]


@dataclasses.dataclass
class LoopResult:
    steps_done: int
    restarts: int
    preempted: bool
    final_state: Any


class FaultTolerantLoop:
    """Checkpoint/restart training loop with preemption + retry + elasticity.

    step_fn(state, batch) -> (state, metrics); state must be a pytree.
    make_state() builds a fresh state; remesh(state_host) re-shards a
    restored host-side state for the *current* device topology.
    """

    def __init__(
        self,
        *,
        ckpt_dir: str | Path,
        make_state: Callable[[], Any],
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        batch_at: Callable[[int], Any],
        ckpt_every: int = 50,
        keep: int = 3,
        max_retries: int = 3,
        remesh: Callable[[Any], Any] | None = None,
        heartbeat: Heartbeat | None = None,
        on_restore: Callable[[Any, int], None] | None = None,
    ):
        self.manager = CheckpointManager(ckpt_dir, keep=keep)
        self.make_state = make_state
        self.step_fn = step_fn
        self.batch_at = batch_at
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.remesh = remesh
        self.heartbeat = heartbeat
        self.on_restore = on_restore
        self.straggler = StragglerMonitor()
        self._preempted = threading.Event()

    def install_preemption_handler(self) -> None:
        def handler(signum, frame):  # noqa: ARG001
            self._preempted.set()

        signal.signal(signal.SIGTERM, handler)

    def run(self, total_steps: int, log_every: int = 10,
            log=print) -> LoopResult:
        restarts = 0
        state, start = self._restore_or_init()
        step = start
        while step < total_steps:
            try:
                if self._preempted.is_set():
                    self.manager.save(state, step)
                    return LoopResult(step, restarts, True, state)
                t0 = time.time()
                batch = self.batch_at(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.time() - t0
                if self.straggler.observe(step, dt):
                    log(f"[fault] step {step}: straggler ({dt:.2f}s vs median "
                        f"{self.straggler.median():.2f}s)")
                step += 1
                if self.heartbeat:
                    self.heartbeat.payload = {"step": step}
                if step % self.ckpt_every == 0:
                    self.manager.save_async(state, step)
                if step % log_every == 0:
                    loss = metrics.get("loss")
                    log(f"[train] step {step} loss {float(loss):.4f} ({dt:.2f}s)"
                        if loss is not None else f"[train] step {step} ({dt:.2f}s)")
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # noqa: BLE001 — node-failure surrogate
                restarts += 1
                log(f"[fault] step {step} failed ({type(e).__name__}: {e}); "
                    f"restart {restarts}/{self.max_retries} from checkpoint")
                if restarts > self.max_retries:
                    raise
                state, step = self._restore_or_init()
        self.manager.wait()
        self.manager.save(state, step)
        return LoopResult(step, restarts, False, state)

    def _restore_or_init(self) -> tuple[Any, int]:
        import jax

        fresh = self.make_state()
        abstract = jax.tree.map(lambda l: l, fresh)
        restored, step = self.manager.restore_latest(abstract)
        if restored is None:
            return fresh, 0
        if self.remesh is not None:
            restored = self.remesh(restored)
        if self.on_restore is not None:
            # process-level side effects a restart must re-establish before
            # stepping — e.g. re-registering the amr_inject schedule handle
            # the restored state's numerics policy refers to (the schedule
            # registry does not survive the process)
            self.on_restore(restored, int(step))
        return restored, int(step)

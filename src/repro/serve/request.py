"""Request / completion records and the FIFO admission queue.

Pure host-side bookkeeping: nothing here touches jax. Timestamps are
filled in by the engine (monotonic clock) so completions carry queue
latency, time-to-first-token and end-to-end latency for serve_bench.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a token-id sequence; generation is greedy and stops at
    ``eos_id`` (if given) or after ``max_new_tokens``. ``uid`` is assigned
    by the queue at submit time.
    """

    prompt: tuple[int, ...]
    max_new_tokens: int
    eos_id: int | None = None
    uid: int = -1
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0

    def __post_init__(self) -> None:
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {self.max_new_tokens}")


@dataclasses.dataclass
class Completion:
    """A finished request: generated tokens + latency breakdown."""

    uid: int
    prompt: tuple[int, ...]
    tokens: tuple[int, ...]
    finish_reason: str  # "eos" | "length"
    t_submit: float
    t_admit: float
    t_first_token: float
    t_done: float
    logits: list | None = None  # per-token final logits (record_logits=True)

    @property
    def queue_s(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> float:
        """Submit -> first token (queue wait + prefill)."""
        return self.t_first_token - self.t_submit

    @property
    def total_s(self) -> float:
        return self.t_done - self.t_submit


class RequestQueue:
    """FIFO admission queue. Admission order == submit order (fairness is
    property-tested: the engine never reorders waiting requests)."""

    def __init__(self) -> None:
        self._q: deque[Request] = deque()
        self._uids = itertools.count()

    def submit(self, req: Request) -> int:
        req.uid = next(self._uids)
        self._q.append(req)
        return req.uid

    def pop(self) -> Request:
        return self._q.popleft()

    def peek(self) -> Request | None:
        return self._q[0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

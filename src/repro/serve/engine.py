"""ServeEngine: continuous batching over one shared slot-decode cache.

Lifecycle of a request:

  submit -> queue (FIFO) -> admit: allocate slot, jitted prefill
  (``prefill_with_cache``), insert the request cache into the slot row,
  first token from the prefill logits -> decode: ONE jitted step advances
  every live slot under an active mask -> finish (EOS / max tokens):
  free the slot; the next queued request reuses it.

Compile behaviour (the whole point of the design):

  * the decode step is traced ONCE per engine shape — the active mask and
    per-slot positions are traced operands, so slots finishing, joining,
    or wrapping never retrace; heterogeneous ``NumericsPolicy`` configs
    (per-layer searched policies, docs/numerics.md#policy-files) resolve
    per call site AT TRACE TIME inside that single step, so they add no
    compiles either (gated: tests/test_policy.py asserts
    ``_cache_size() == 1`` under a per-layer policy);
  * prefill compiles once per distinct prompt *length* (documented cost;
    callers pad/bucket prompts if they care);
  * the slot insert is one trace total (the slot index is a traced scalar).

Correctness invariant (gated by benchmarks/serve_bench.py in CI): for the
integer AMR modes — and exact, and even ``amr_noise`` thanks to per-slot
position PRNG folding — the token AND logit streams of a request decoded
in a busy engine are bit-identical to the same request served alone.

Fault wiring: an optional ``Heartbeat`` (runtime.fault) publishes
queue/slot/step progress for external watchdogs, and a
``StragglerMonitor`` flags decode steps slower than the running median —
a host-side stall (e.g. a paging device or a preempting neighbour) shows
up as flagged steps rather than silent p99 inflation.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_cache, prefill_with_cache
from repro.runtime.fault import Heartbeat, StragglerMonitor
from repro.train.steps import make_serve_step

from .request import Completion, Request, RequestQueue
from .slots import SlotAllocator


def _insert_request(engine_cache, request_cache, slot):
    """Write a batch-1 prefill cache into slot row ``slot`` of the engine
    cache. Leaves are stacked (n_repeat, B, ...); scalar-position length
    leaves arrive as (n_repeat,) and gain the batch axis here."""

    def one(e, r):
        if r.ndim == e.ndim - 1:
            r = jnp.expand_dims(r, 1)
        return jax.lax.dynamic_update_slice_in_dim(e, r.astype(e.dtype), slot, axis=1)

    return jax.tree.map(one, engine_cache, request_cache)


class ServeEngine:
    """Continuous-batching greedy decoder with ``n_slots`` fixed slots."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_slots: int,
        capacity: int,
        record_logits: bool = False,
        heartbeat: Heartbeat | None = None,
        straggler: StragglerMonitor | None = None,
        log: Callable[[str], None] | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.capacity = capacity
        self.record_logits = record_logits
        self.queue = RequestQueue()
        self.slots = SlotAllocator(n_slots)
        self.heartbeat = heartbeat
        self.straggler = straggler if straggler is not None else StragglerMonitor()
        self._log = log or (lambda msg: None)

        self.cache = init_cache(cfg, n_slots, capacity, per_slot=True)
        self._active = np.zeros(n_slots, bool)
        self._next_tok = np.zeros(n_slots, np.int32)
        self._slot_req: list[Request | None] = [None] * n_slots
        self._slot_toks: list[list[int]] = [[] for _ in range(n_slots)]
        self._slot_logits: list[list] = [[] for _ in range(n_slots)]
        self.completions: list[Completion] = []
        self.steps_done = 0
        self.decode_seconds = 0.0  # cumulative masked-decode-step wall time
        self.decode_tokens = 0     # tokens produced by decode steps (not prefill)

        self._prefill = jax.jit(
            partial(prefill_with_cache, cfg, capacity=capacity))
        self._decode = jax.jit(make_serve_step(cfg, with_logits=record_logits),
                               donate_argnums=(1,))
        self._insert = jax.jit(_insert_request, donate_argnums=(0,))

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> int:
        """Queue a request; returns its uid. Rejects requests that cannot
        fit the slot cache (prompt + generation exceeds capacity)."""
        need = len(req.prompt) + req.max_new_tokens
        if need > self.capacity:
            raise ValueError(
                f"request needs {need} cache positions "
                f"(prompt {len(req.prompt)} + max_new_tokens "
                f"{req.max_new_tokens}) but slot capacity is {self.capacity}")
        req.t_submit = time.monotonic()
        return self.queue.submit(req)

    # ---------------------------------------------------------- scheduler
    def run(self, max_steps: int | None = None) -> list[Completion]:
        """Drive admit/decode until the queue and all slots drain (or
        ``max_steps`` decode steps ran). Returns completions in uid order."""
        if self.heartbeat is not None:
            self.heartbeat.start()
        try:
            steps = 0
            while self.queue or self._active.any():
                self._admit()
                if self._active.any():
                    self._decode_once()
                    steps += 1
                    if max_steps is not None and steps >= max_steps:
                        break
        finally:
            if self.heartbeat is not None:
                self._beat()
                self.heartbeat.stop()
        return sorted(self.completions, key=lambda c: c.uid)

    def _beat(self) -> None:
        if self.heartbeat is None:
            return
        self.heartbeat.payload = {
            "step": self.steps_done,
            "active_slots": int(self._active.sum()),
            "queued": len(self.queue),
            "completed": len(self.completions),
        }
        # Flush immediately: the timer thread only re-writes the last
        # payload, so liveness on disk tracks scheduler progress, not the
        # heartbeat interval.
        self.heartbeat.beat()

    def _admit(self) -> None:
        """Admit queued requests into free slots, FIFO order."""
        while self.queue and self.slots.n_free:
            req = self.queue.pop()
            slot = self.slots.allocate()
            assert slot is not None
            req.t_admit = time.monotonic()
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, rcache = self._prefill(self.params, toks)
            self.cache = self._insert(self.cache, rcache, jnp.int32(slot))
            last = jax.device_get(logits[:, -1].astype(jnp.float32))[0]
            first = int(np.argmax(last))
            req.t_first_token = time.monotonic()
            self._slot_req[slot] = req
            self._slot_toks[slot] = [first]
            self._slot_logits[slot] = [last] if self.record_logits else []
            self._active[slot] = True
            self._next_tok[slot] = first
            self._maybe_finish(slot)
            self._beat()

    def _decode_once(self) -> None:
        """One masked decode step for every live slot."""
        batch = {
            "token": jnp.asarray(self._next_tok)[:, None],
            "active": jnp.asarray(self._active),
        }
        t0 = time.monotonic()
        out = self._decode(self.params, self.cache, batch)
        if self.record_logits:
            next_tok, last_logits, self.cache = out
            logits_host = jax.device_get(last_logits)
        else:
            next_tok, self.cache = out
            logits_host = None
        tok_host = jax.device_get(next_tok)  # blocks: true step time
        dt = time.monotonic() - t0
        self.steps_done += 1
        self.decode_seconds += dt
        self.decode_tokens += int(self._active.sum())
        if self.straggler.observe(self.steps_done, dt):
            self._log(f"[serve] step {self.steps_done}: straggler "
                      f"({dt * 1e3:.1f}ms vs median "
                      f"{self.straggler.median() * 1e3:.1f}ms)")
        for slot in np.flatnonzero(self._active):
            self._slot_toks[slot].append(int(tok_host[slot]))
            if logits_host is not None:
                self._slot_logits[slot].append(np.asarray(logits_host[slot]))
            self._next_tok[slot] = int(tok_host[slot])
            self._maybe_finish(slot)
        self._beat()

    # ------------------------------------------------------------ finish
    def _maybe_finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        toks = self._slot_toks[slot]
        reason = None
        if req.eos_id is not None and toks and toks[-1] == req.eos_id:
            reason = "eos"
        elif len(toks) >= req.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        self.completions.append(Completion(
            uid=req.uid, prompt=req.prompt, tokens=tuple(toks),
            finish_reason=reason, t_submit=req.t_submit, t_admit=req.t_admit,
            t_first_token=req.t_first_token, t_done=time.monotonic(),
            logits=self._slot_logits[slot] if self.record_logits else None))
        self._active[slot] = False
        self._next_tok[slot] = 0
        self._slot_req[slot] = None
        self._slot_toks[slot] = []
        self._slot_logits[slot] = []
        self.slots.free(slot)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "steps": self.steps_done,
            "completed": len(self.completions),
            "active_slots": int(self._active.sum()),
            "queued": len(self.queue),
            "stragglers": len(self.straggler.flagged),
        }

"""Continuous-batching serving engine over the slot-decode model path.

Requests enter a FIFO ``RequestQueue``; a ``SlotAllocator`` maps each
admitted request onto a fixed decode slot of one shared, capacity-bounded
KV cache; ``ServeEngine`` prefills into the slot, then advances ALL live
slots with a single jitted decode step (active-slot mask — no recompiles
as requests finish and new ones are admitted mid-flight).

The engine is numerics-policy agnostic: the same loop serves exact and
every approximate AMR mode, and batched slot-decode is bit-identical to
decoding each request alone (benchmarks/serve_bench.py gates this in CI).
"""
from .engine import ServeEngine
from .request import Completion, Request, RequestQueue
from .slots import SlotAllocator

__all__ = ["Request", "Completion", "RequestQueue", "SlotAllocator",
           "ServeEngine"]

"""Fixed-slot allocator for the shared decode cache.

The engine's cache has ``n_slots`` batch rows; each admitted request owns
exactly one row until it finishes. The allocator is deliberately dumb —
lowest free index first — because slot *identity* must not matter: the
decode step is row-independent (bit-exactness gate), so any free row is as
good as any other.
"""
from __future__ import annotations


class SlotAllocator:
    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._free: list[int] = sorted(range(n_slots), reverse=True)
        self._in_use: set[int] = set()

    def allocate(self) -> int | None:
        """Lowest free slot index, or None when full. Never double-allocates."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._in_use.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._in_use:
            raise ValueError(f"slot {slot} is not allocated")
        self._in_use.discard(slot)
        # keep lowest-first order without a heap: n_slots is tiny
        self._free.append(slot)
        self._free.sort(reverse=True)

    @property
    def in_use(self) -> frozenset[int]:
        return frozenset(self._in_use)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def __len__(self) -> int:
        return len(self._in_use)

"""Adafactor (factored second moments) — memory-lean option for 70B+ archs.

Matrix params keep row/col second-moment factors (O(n+m) instead of O(nm));
vectors/scalars fall back to full moments. No momentum, no master copy:
~2 bytes/param of optimizer state for the big matrices.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=["vr", "vc", "v", "count"], meta_fields=[])
@dataclasses.dataclass
class AdafactorState:
    vr: Any      # row factors (or None placeholder zeros for non-factored)
    vc: Any      # col factors
    v: Any       # full second moment for <2D leaves
    count: jnp.ndarray


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params: Any) -> AdafactorState:
    def vr(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32)
                if _factored(p) else jnp.zeros((1,), jnp.float32))

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p) else jnp.zeros((1,), jnp.float32))

    def v(p):
        return jnp.zeros(p.shape, jnp.float32) if not _factored(p) else jnp.zeros((1,), jnp.float32)

    return AdafactorState(jax.tree.map(vr, params), jax.tree.map(vc, params),
                          jax.tree.map(v, params), jnp.zeros((), jnp.int32))


def adafactor_update(
    grads: Any,
    state: AdafactorState,
    params: Any,
    lr,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> tuple[Any, AdafactorState]:
    count = state.count + 1
    beta = 1.0 - (count.astype(jnp.float32) + 1.0) ** (-decay)

    def upd(g, vr, vc, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p):
            vr_n = beta * vr + (1 - beta) * g2.mean(-1)
            vc_n = beta * vc + (1 - beta) * g2.mean(-2)
            denom = (vr_n[..., None] / jnp.maximum(vr_n.mean(-1, keepdims=True)[..., None], eps))
            u = g / jnp.sqrt(jnp.maximum(denom * vc_n[..., None, :], eps))
            v_n = v
        else:
            v_n = beta * v + (1 - beta) * g2
            u = g / jnp.sqrt(jnp.maximum(v_n, eps))
            vr_n, vc_n = vr, vc
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        new_p = p.astype(jnp.float32) - lr * u - lr * weight_decay * p.astype(jnp.float32)
        return vr_n, vc_n, v_n, new_p.astype(p.dtype)

    g_l, treedef = jax.tree.flatten(grads)
    out = [upd(g, vr, vc, v, p) for g, vr, vc, v, p in zip(
        g_l, treedef.flatten_up_to(state.vr), treedef.flatten_up_to(state.vc),
        treedef.flatten_up_to(state.v), treedef.flatten_up_to(params))]
    unflat = lambda i: jax.tree.unflatten(treedef, [o[i] for o in out])
    return unflat(3), AdafactorState(unflat(0), unflat(1), unflat(2), count)

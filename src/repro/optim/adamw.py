"""AdamW with fp32 master weights/moments over bf16 params.

State is a pytree mirroring params, so GSPMD shards optimizer state exactly
like the parameters (FSDP): per-device optimizer memory = 12 bytes/param /
shards (measured by the dry-run memory_analysis).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=["mu", "nu", "master", "count"], meta_fields=[])
@dataclasses.dataclass
class AdamWState:
    mu: Any
    nu: Any
    master: Any     # fp32 master copy of params
    count: jnp.ndarray


def adamw_init(params: Any) -> AdamWState:
    # copy=True: fp32 param leaves (norm scales) must NOT alias the master —
    # a shared buffer would be donated twice by train_step
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        master=jax.tree.map(f32, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jnp.ndarray | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> tuple[Any, AdamWState]:
    count = state.count + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, mu, nu, master, p):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** count)
        nu_hat = nu / (1 - b2 ** count)
        step = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * master
        new_master = master - lr * step
        return mu, nu, new_master, new_master.astype(p.dtype)

    g_l, treedef = jax.tree.flatten(grads)
    mu_l = treedef.flatten_up_to(state.mu)
    nu_l = treedef.flatten_up_to(state.nu)
    ma_l = treedef.flatten_up_to(state.master)
    p_l = treedef.flatten_up_to(params)
    out = [upd(*args) for args in zip(g_l, mu_l, nu_l, ma_l, p_l)]
    unflat = lambda i: jax.tree.unflatten(treedef, [o[i] for o in out])
    return unflat(3), AdamWState(unflat(0), unflat(1), unflat(2), count)

"""Optimizers (pure-pytree, shard-friendly) + LR schedules."""
from .adafactor import adafactor_init, adafactor_update
from .adamw import adamw_init, adamw_update
from .schedule import cosine_warmup

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "cosine_warmup"]

"""Common layers: norms, rotary embeddings, MLPs — pure-JAX, param-dict style.

Every matmul routes through the numerics policy (repro.numerics), which is
how the paper's approximate multiplier enters the model. Params are nested
dicts of jnp arrays; init functions mirror apply functions 1:1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.numerics import AMRNumerics, NumericsPolicy, resolve_numerics
from repro.numerics.approx_matmul import approx_matmul
from repro.parallel.constraints import pin

Numerics = AMRNumerics | NumericsPolicy | None


def dense(x: jnp.ndarray, w: jnp.ndarray, numerics: Numerics = None,
          site: str | None = None) -> jnp.ndarray:
    """x: (..., K) @ w: (K, N) under the numerics policy.

    ``site`` is the static call-site label (e.g. ``"mlp.w_gate"``) that,
    with the ambient step/layer scope (repro.numerics.context), decorrelates
    the amr_noise PRNG stream — without it every projection in every layer
    would draw the identical noise tensor.

    ``numerics`` may also be a site-resolved ``NumericsPolicy``; it resolves
    here against ``site`` and the ambient static layer coordinate, so each
    call site of each (statically indexed) layer can run a different
    multiplier design (numerics/policy.py).
    """
    numerics = resolve_numerics(numerics, site)
    if numerics is None or numerics.is_exact():
        return jnp.matmul(x, w)
    shape = x.shape
    out = approx_matmul(x.reshape(-1, shape[-1]), w, numerics, site=site)
    return out.reshape(*shape[:-1], w.shape[-1]).astype(x.dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def init_rms_norm(d: int) -> jnp.ndarray:
    return jnp.zeros((d,), dtype=jnp.float32)


# ----------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- MLP
def init_mlp(key: jax.Array, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_ff).astype(dtype),
    }


def mlp(params: dict, x: jnp.ndarray, act: str, numerics: Numerics) -> jnp.ndarray:
    g = pin(dense(x, params["w_gate"], numerics, site="mlp.w_gate"), "batch", None, "tp")
    u = pin(dense(x, params["w_up"], numerics, site="mlp.w_up"), "batch", None, "tp")
    if act == "geglu":
        h = jax.nn.gelu(g) * u
    elif act == "swiglu":
        h = jax.nn.silu(g) * u
    elif act == "gelu":
        h = jax.nn.gelu(g + u)  # degenerate non-gated form keeps param tree uniform
    else:
        raise ValueError(act)
    return pin(dense(h, params["w_down"], numerics, site="mlp.w_down"), "batch", None, None)


# -------------------------------------------------------------- embeddings
def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d_model)) * (d_model ** -0.5)).astype(dtype)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, table: jnp.ndarray, numerics: AMRNumerics | None = None) -> jnp.ndarray:
    """Logits; tied embeddings use table.T. Kept exact by default: the LM
    head dominates vocab-scaled error, and the paper's technique targets
    inner matmuls (DESIGN.md §Arch-applicability)."""
    return jnp.matmul(x, table.T.astype(x.dtype))

"""Mamba2 (SSD — state-space duality) mixer: chunked train/prefill + O(1) decode.

Follows the SSD algorithm (Dao & Gu 2024): sequences are split into chunks;
within a chunk the dual quadratic form runs on matmuls (MXU-friendly —
kernels/ssd_scan provides the Pallas version), across chunks a small state
recurrence carries (H, N, P) per-head states. Decode keeps a conv ring
buffer + SSM state and costs O(1) per token.

TP layout: projections are kept as *separate* parameters (wz/wx/wb/wc/wdt
and per-segment depthwise convs) instead of one fused in_proj — fused
concat boundaries do not align with "model"-axis shards and would force
XLA to reshard mid-layer (DESIGN.md §3). x/z shard by heads on "model";
B/C (n_groups * d_state, small) replicate.

Projections route through the numerics policy (the paper's approximate
multiplier applies to in/out projections; the state recurrence accumulates
and is kept exact — DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.numerics import AMRNumerics, resolve_numerics
from repro.numerics.approx_matmul import approx_matmul
from repro.parallel.constraints import pin

from .layers import dense, init_rms_norm, rms_norm


def ssm_dims(d_model: int, cfg: SSMConfig) -> dict:
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    return dict(d_inner=d_inner, n_heads=n_heads, d_bc=cfg.n_groups * cfg.d_state)


def init_ssm(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    dims = ssm_dims(d_model, cfg)
    d_inner, d_bc, H = dims["d_inner"], dims["d_bc"], dims["n_heads"]
    ks = jax.random.split(key, 8)
    s = d_model ** -0.5
    proj = lambda k, n: (jax.random.normal(k, (d_model, n)) * s).astype(dtype)
    return {
        "wz": proj(ks[0], d_inner),
        "wx": proj(ks[1], d_inner),
        "wb": proj(ks[2], d_bc),
        "wc": proj(ks[3], d_bc),
        "wdt": proj(ks[4], H),
        "conv_x": (jax.random.normal(ks[5], (cfg.conv_width, d_inner)) * 0.1).astype(dtype),
        "conv_b": (jax.random.normal(ks[6], (cfg.conv_width, d_bc)) * 0.1).astype(dtype),
        "conv_c": (jax.random.normal(ks[7], (cfg.conv_width, d_bc)) * 0.1).astype(dtype),
        "conv_bias_x": jnp.zeros((d_inner,), dtype),
        "conv_bias_b": jnp.zeros((d_bc,), dtype),
        "conv_bias_c": jnp.zeros((d_bc,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": init_rms_norm(d_inner),
        "out_proj": (jax.random.normal(jax.random.fold_in(key, 99), (d_inner, d_model))
                     * d_inner ** -0.5).astype(dtype),
    }


def _causal_conv(xs: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width W: xs (B,S,C), w (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xs.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, a_log, b, c, chunk: int, return_state: bool = False,
                numerics=None):
    """SSD scan. x:(B,S,H,P) dt:(B,S,H) b,c:(B,S,G,N) -> y:(B,S,H,P).

    return_state: also return the final (B,H,N,P) state (prefill->decode
    handoff). Pure-jnp reference implementation (kernels/ssd_scan/ref.py
    re-exports this; the Pallas kernel matches it in the sweep tests).

    ``numerics`` routes the inter-chunk state readout (the C · h_prev
    contraction) through the activation×activation seam at site
    ``ssm.scan``; None / exact keeps the historical einsum bit-for-bit.
    The intra-chunk dual quadratic form stays exact: its masked-decay
    weighting has no plain matmul form (DESIGN.md §Arch-applicability).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    if S % chunk:
        # right-pad to a chunk multiple; dt=0 makes padding state-neutral
        # (decay exp(0)=1, contribution x*dt=0) — outputs sliced back below
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_pad = x.shape[1]
    nc = S_pad // chunk
    rep = H // G

    a = -jnp.exp(a_log.astype(jnp.float32))                    # (H,)
    la = a * dt.astype(jnp.float32)                            # (B,S,H) log decay
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # chunk views
    lac = la.reshape(B, nc, chunk, H)
    cum = jnp.cumsum(lac, axis=2)                              # (B,nc,Q,H)
    xc = xdt.reshape(B, nc, chunk, H, P)
    bc_ = b.astype(jnp.float32).reshape(B, nc, chunk, G, N)
    cc_ = c.astype(jnp.float32).reshape(B, nc, chunk, G, N)
    bh = jnp.repeat(bc_, rep, axis=3)                          # (B,nc,Q,H,N)
    ch = jnp.repeat(cc_, rep, axis=3)

    # intra-chunk (dual quadratic form); mask BEFORE exp — the upper triangle
    # holds positive log-decays that overflow and would leak NaN into grads
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,Q,Q,H) t,s
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bnthi,bnshi->bntsh", ch, bh)              # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bntsh,bntsh,bnshp->bnthp", cb, decay, xc)

    # chunk states: S_c = sum_s exp(cum_Q - cum_s) * b_s x_s^T
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                    # (B,nc,Q,H)
    states = jnp.einsum("bnsh,bnshi,bnshp->bnhip", tail, bh, xc)  # (B,nc,H,N,P)

    # inter-chunk recurrence: h_{c} = exp(sum la_c) h_{c-1} + S_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    def step(h, inp):
        dec, s_c = inp
        h_new = dec[..., None, None] * h + s_c
        return h_new, h

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                        # (B,nc,H,N,P) state BEFORE chunk

    nm = resolve_numerics(numerics, "ssm.scan")
    if nm is not None and not nm.is_exact():
        # decay-weighted C panel against the carried state, grouped per
        # (batch, chunk, head): (B,nc,H,Q,N) @ (B,nc,H,N,P) seam call
        dc = (ch * jnp.exp(cum)[..., None]).transpose(0, 1, 3, 2, 4)
        y_inter = approx_matmul(dc, h_prev, nm,
                                site="ssm.scan").transpose(0, 1, 3, 2, 4)
    else:
        y_inter = jnp.einsum("bnthi,bnth,bnhip->bnthp", ch, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(B, S_pad, H, P)[:, :S]
    if return_state:
        # note: state axes are (H, N, P); SSMState stores (H, N, P) too
        return y, h_final
    return y


def ssm_forward(params: dict, xin: jnp.ndarray, d_model: int, cfg: SSMConfig,
                numerics: AMRNumerics | None = None, eps: float = 1e-6) -> jnp.ndarray:
    """Full-sequence Mamba2 mixer (train / prefill)."""
    dims = ssm_dims(d_model, cfg)
    d_inner, H = dims["d_inner"], dims["n_heads"]
    z = pin(dense(xin, params["wz"], numerics, site="ssm.wz"), "batch", None, "tp")
    x = pin(dense(xin, params["wx"], numerics, site="ssm.wx"), "batch", None, "tp")
    b = pin(dense(xin, params["wb"], numerics, site="ssm.wb"), "batch", None, None)
    c = pin(dense(xin, params["wc"], numerics, site="ssm.wc"), "batch", None, None)
    dt = dense(xin, params["wdt"], numerics, site="ssm.wdt")

    x = _causal_conv(x, params["conv_x"], params["conv_bias_x"])
    b = _causal_conv(b, params["conv_b"], params["conv_bias_b"])
    c = _causal_conv(c, params["conv_c"], params["conv_bias_c"])

    B_, S, _ = x.shape
    x = pin(x.reshape(B_, S, H, cfg.head_dim), "batch", None, "tp", None)
    b = b.reshape(B_, S, cfg.n_groups, cfg.d_state)
    c = c.reshape(B_, S, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    y = ssd_chunked(x, dt, params["a_log"], b, c, cfg.chunk, numerics=numerics)
    y = y + params["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = pin(y.reshape(B_, S, d_inner), "batch", None, "tp").astype(xin.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], eps)
    return pin(dense(y, params["out_proj"], numerics, site="ssm.out_proj"), "batch", None, None)


# ------------------------------------------------------------------ decode
@partial(jax.tree_util.register_dataclass,
         data_fields=["conv_x", "conv_b", "conv_c", "h"], meta_fields=[])
@dataclasses.dataclass
class SSMState:
    conv_x: jnp.ndarray  # (B, W-1, d_inner) ring of recent x projections
    conv_b: jnp.ndarray  # (B, W-1, d_bc)
    conv_c: jnp.ndarray  # (B, W-1, d_bc)
    h: jnp.ndarray       # (B, H, N, P) SSM state

    @classmethod
    def zeros(cls, batch, d_model, cfg: SSMConfig, dtype):
        dims = ssm_dims(d_model, cfg)
        W = cfg.conv_width - 1
        return cls(
            jnp.zeros((batch, W, dims["d_inner"]), dtype),
            jnp.zeros((batch, W, dims["d_bc"]), dtype),
            jnp.zeros((batch, W, dims["d_bc"]), dtype),
            jnp.zeros((batch, dims["n_heads"], cfg.d_state, cfg.head_dim), jnp.float32),
        )


def _conv_step(ring, new, w, bias):
    window = jnp.concatenate([ring, new[:, None, :]], axis=1)  # (B, W, C)
    out = (window * w[None]).sum(axis=1) + bias
    return jax.nn.silu(out), window[:, 1:, :]


def ssm_decode(params: dict, xin: jnp.ndarray, state: SSMState, d_model: int,
               cfg: SSMConfig, numerics: AMRNumerics | None = None,
               eps: float = 1e-6) -> tuple[jnp.ndarray, SSMState]:
    """One-token step. xin: (B, 1, d_model)."""
    dims = ssm_dims(d_model, cfg)
    d_inner, H = dims["d_inner"], dims["n_heads"]
    x1 = xin[:, 0]
    z = dense(x1, params["wz"], numerics, site="ssm.wz")
    x = dense(x1, params["wx"], numerics, site="ssm.wx")
    b = dense(x1, params["wb"], numerics, site="ssm.wb")
    c = dense(x1, params["wc"], numerics, site="ssm.wc")
    dt = dense(x1, params["wdt"], numerics, site="ssm.wdt")

    x, ring_x = _conv_step(state.conv_x, x, params["conv_x"], params["conv_bias_x"])
    b, ring_b = _conv_step(state.conv_b, b, params["conv_b"], params["conv_bias_b"])
    c, ring_c = _conv_step(state.conv_c, c, params["conv_c"], params["conv_bias_c"])

    Bt = x.shape[0]
    x = x.reshape(Bt, H, cfg.head_dim).astype(jnp.float32)
    b = b.reshape(Bt, cfg.n_groups, cfg.d_state).astype(jnp.float32)
    c = c.reshape(Bt, cfg.n_groups, cfg.d_state).astype(jnp.float32)
    rep = H // cfg.n_groups
    bh = jnp.repeat(b, rep, axis=1)                            # (B,H,N)
    ch = jnp.repeat(c, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(a[None] * dt)                              # (B,H)

    xdt = x * dt[..., None]                                    # (B,H,P)
    h_new = decay[..., None, None] * state.h + bh[..., None] * xdt[:, :, None, :]
    nm = resolve_numerics(numerics, "ssm.scan")
    if nm is not None and not nm.is_exact():
        # one-row state readout through the seam: (B,H,1,N) @ (B,H,N,P)
        yss = approx_matmul(ch[:, :, None, :], h_new, nm,
                            site="ssm.scan")[:, :, 0, :]
    else:
        yss = jnp.einsum("bhn,bhnp->bhp", ch, h_new)
    y = yss + params["d_skip"][None, :, None] * x
    y = y.reshape(Bt, d_inner).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], eps)
    out = dense(y, params["out_proj"], numerics, site="ssm.out_proj")[:, None, :]
    return out, SSMState(ring_x, ring_b, ring_c, h_new)


def ssm_prefill(params: dict, xin: jnp.ndarray, d_model: int, cfg: SSMConfig,
                numerics: AMRNumerics | None = None, eps: float = 1e-6
                ) -> tuple[jnp.ndarray, SSMState]:
    """Full-sequence forward that ALSO returns the decode state
    (prefill -> decode handoff): final SSM state + conv ring tails."""
    dims = ssm_dims(d_model, cfg)
    d_inner, H = dims["d_inner"], dims["n_heads"]
    z = pin(dense(xin, params["wz"], numerics, site="ssm.wz"), "batch", None, "tp")
    x_raw = pin(dense(xin, params["wx"], numerics, site="ssm.wx"), "batch", None, "tp")
    b_raw = pin(dense(xin, params["wb"], numerics, site="ssm.wb"), "batch", None, None)
    c_raw = pin(dense(xin, params["wc"], numerics, site="ssm.wc"), "batch", None, None)
    dt = dense(xin, params["wdt"], numerics, site="ssm.wdt")

    W = cfg.conv_width
    def tail(t):  # last W-1 raw inputs, zero-padded for short sequences
        pad = jnp.zeros((t.shape[0], max(W - 1 - t.shape[1], 0), t.shape[2]), t.dtype)
        return jnp.concatenate([pad, t[:, -(W - 1):, :]], axis=1)

    x = _causal_conv(x_raw, params["conv_x"], params["conv_bias_x"])
    b = _causal_conv(b_raw, params["conv_b"], params["conv_bias_b"])
    c = _causal_conv(c_raw, params["conv_c"], params["conv_bias_c"])

    B_, S, _ = x.shape
    x = pin(x.reshape(B_, S, H, cfg.head_dim), "batch", None, "tp", None)
    b = b.reshape(B_, S, cfg.n_groups, cfg.d_state)
    c = c.reshape(B_, S, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    y, h_final = ssd_chunked(x, dt, params["a_log"], b, c, cfg.chunk,
                             return_state=True, numerics=numerics)
    y = y + params["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = pin(y.reshape(B_, S, d_inner), "batch", None, "tp").astype(xin.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], eps)
    out = pin(dense(y, params["out_proj"], numerics, site="ssm.out_proj"), "batch", None, None)
    state = SSMState(tail(x_raw), tail(b_raw), tail(c_raw), h_final)
    return out, state

"""Model zoo: composable LM blocks covering all assigned architecture families."""
from .model import (
    decode_step,
    encode,
    forward,
    group_structure,
    init_cache,
    init_params,
    prefill_with_cache,
)

__all__ = ["forward", "encode", "decode_step", "init_params", "init_cache",
           "group_structure", "prefill_with_cache"]

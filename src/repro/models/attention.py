"""GQA/MQA attention with qk-norm, sliding windows, RoPE, and KV caches.

Three entry points per layer:
  * ``attend_full``  — training / prefill over a whole sequence (causal,
    optionally sliding-window masked).
  * ``attend_decode`` — one-token step against a (possibly ring-buffered)
    KV cache; this is what ``serve_step`` lowers for decode_* shapes.
Cache layout: (batch, cache_len, n_kv, head_dim) — batch shards on "data",
kv heads on "model" when divisible (parallel/sharding.py decides).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.numerics import AMRNumerics, resolve_numerics
from repro.numerics.approx_matmul import approx_matmul
from repro.parallel.constraints import ambient_axis_size, pin

from .layers import apply_rope, dense, init_rms_norm, rms_norm

NEG_INF = -2.0e38


def init_attention(key, d_model, n_heads, n_kv, head_dim, qk_norm, dtype) -> dict:
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model))
               * (n_heads * head_dim) ** -0.5).astype(dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rms_norm(head_dim)
        p["k_norm"] = init_rms_norm(head_dim)
    return p


def _project_qkv(params, x, n_heads, n_kv, head_dim, positions, theta, qk_norm,
                 numerics: AMRNumerics | None, eps: float):
    B, S, _ = x.shape
    q = dense(x, params["wq"], numerics, site="attn.wq").reshape(B, S, n_heads, head_dim)
    k = dense(x, params["wk"], numerics, site="attn.wk").reshape(B, S, n_kv, head_dim)
    v = dense(x, params["wv"], numerics, site="attn.wv").reshape(B, S, n_kv, head_dim)
    if qk_norm:
        q = rms_norm(q, params["q_norm"], eps)
        k = rms_norm(k, params["k_norm"], eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    q = pin(q, "batch", None, "tp", None)
    k = pin(k, "batch", None, "tp", None)
    v = pin(v, "batch", None, "tp", None)
    return q, k, v


def _seam_scores(q, k, numerics: AMRNumerics):
    """QK^T through the activation×activation numerics seam (``attn.qk``).

    Folds the GQA group into the row dim — one batched seam call
    (B, Hkv, g*S, D) @ (B, Hkv, D, T) — so per-row quantization is per
    (batch, kv head, group, query) row and a slot-batched decode row
    quantizes exactly as its solo decode would (no cross-slot reduction).
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qa = q.reshape(B, S, Hkv, g, D).transpose(0, 2, 3, 1, 4)
    qa = qa.reshape(B, Hkv, g * S, D)
    kb = k.transpose(0, 2, 3, 1)                               # (B, Hkv, D, T)
    scores = approx_matmul(qa, kb, numerics, site="attn.qk") / (D ** 0.5)
    return scores.reshape(B, Hq, S, T)


def _gqa_scores(q, k, numerics=None):
    """q: (B,S,Hq,D), k: (B,T,Hkv,D) -> (B, Hq, S, T) with head grouping.

    Exact numerics keep the historical einsum formulation; approximate
    modes route through the seam at site ``attn.qk`` (resolved against a
    ``NumericsPolicy`` here, so per-layer assignments can pin it)."""
    numerics = resolve_numerics(numerics, "attn.qk")
    if numerics is not None and not numerics.is_exact():
        return _seam_scores(q, k, numerics)
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    q = q.reshape(B, S, Hkv, g, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) / (D ** 0.5)
    return scores.reshape(B, Hkv * g, S, k.shape[1])


def _seam_combine(probs, v, numerics: AMRNumerics):
    """PV through the seam (``attn.pv``): (B, Hkv, g*S, T) @ (B, Hkv, T, D)
    with the same group folding (and bit-exactness argument) as
    ``_seam_scores`` — probabilities quantize per query row, values per
    (kv head, channel) column over the cache axis."""
    B, Hq, S, T = probs.shape
    Hkv, D = v.shape[2], v.shape[3]
    g = Hq // Hkv
    pa = probs.reshape(B, Hkv, g * S, T)
    vb = v.transpose(0, 2, 1, 3)                               # (B, Hkv, T, D)
    out = approx_matmul(pa, vb, numerics, site="attn.pv")
    out = out.reshape(B, Hkv, g, S, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, S, Hq, D).astype(probs.dtype)


def _gqa_combine(probs, v, numerics=None):
    """probs: (B, Hq, S, T), v: (B,T,Hkv,D) -> (B,S,Hq,D)."""
    numerics = resolve_numerics(numerics, "attn.pv")
    if numerics is not None and not numerics.is_exact():
        return _seam_combine(probs, v, numerics)
    B, Hq, S, T = probs.shape
    Hkv = v.shape[2]
    g = Hq // Hkv
    probs = probs.reshape(B, Hkv, g, S, T)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq, v.shape[-1])


def attend_full(
    params: dict,
    x: jnp.ndarray,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    qk_norm: bool = False,
    window: int = 0,
    causal: bool = True,
    numerics: AMRNumerics | None = None,
    eps: float = 1e-6,
    unroll: bool = False,
) -> jnp.ndarray:
    """Self-attention over the full sequence (training / prefill).

    causal=False gives the bidirectional form (encoder stacks)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, theta,
                           qk_norm, numerics, eps)
    if S >= _CHUNKED_THRESHOLD and S % _Q_CHUNK == 0 and causal:
        out = _chunked_attention(q, k, v, window, numerics, unroll=unroll)
    else:
        scores = _gqa_scores(q, k, numerics).astype(jnp.float32)
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        mask = (j <= i) if causal else jnp.ones((S, S), bool)
        if window > 0:
            mask &= jnp.abs(i - j) < window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = _gqa_combine(probs, v, numerics)
    out = pin(out.reshape(B, S, n_heads * head_dim), "batch", None, "tp")
    return pin(dense(out, params["wo"], numerics, site="attn.wo"), "batch", None, None)


_Q_CHUNK = 2048            # query-block size for chunked attention
_CHUNKED_THRESHOLD = 16384  # use chunked attention from this sequence length


def _chunked_attention(q, k, v, window: int, numerics=None, *,
                       unroll: bool = False):
    """Query-block attention: never materialises the S x S score matrix.

    Memory per block is (B, H, Q_CHUNK, S) — the production path for 32k+
    prefill (a Pallas flash kernel would stream K too; this is the XLA
    formulation of the same idea). The block loop is a lax.scan so the HLO
    stays small; cost-extraction unrolls it like the layer scans.
    """
    B, S, Hq, D = q.shape
    nb = S // _Q_CHUNK
    qb = jnp.moveaxis(q.reshape(B, nb, _Q_CHUNK, Hq, D), 1, 0)  # (nb,B,qc,H,D)
    offs = jnp.arange(nb) * _Q_CHUNK

    def block(_, inp):
        qi, off = inp
        scores = _gqa_scores(qi, k, numerics).astype(jnp.float32)  # (B,H,qc,S)
        rows = off + jnp.arange(_Q_CHUNK)[:, None]
        cols = jnp.arange(S)[None, :]
        mask = cols <= rows
        if window > 0:
            mask &= (rows - cols) < window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(qi.dtype)
        return None, _gqa_combine(probs, v, numerics)           # (B,qc,H,D)

    _, outs = jax.lax.scan(block, None, (qb, offs), unroll=nb if unroll else 1)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, D)


# ------------------------------------------------------------------ decode
@partial(jax.tree_util.register_dataclass, data_fields=["k", "v", "length"],
         meta_fields=[])
@dataclasses.dataclass
class KVCache:
    """Ring-buffered KV cache. ``length`` = logical tokens written so far.

    ``length`` is either a scalar (one shared position — single-prompt
    batch decode, the historical layout) or a ``(B,)`` vector of PER-SLOT
    positions (continuous batching: each batch row is an independent
    request admitted at a different time — serve/engine.py).  All decode
    math broadcasts over both.
    """

    k: jnp.ndarray  # (B, C, n_kv, D)
    v: jnp.ndarray
    length: jnp.ndarray  # () or (B,) int32 — logical position of the next token

    @classmethod
    def zeros(cls, batch, capacity, n_kv, head_dim, dtype, per_slot=False):
        shape = (batch, capacity, n_kv, head_dim)
        length = jnp.zeros((batch,) if per_slot else (), jnp.int32)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), length)


def attend_decode(
    params: dict,
    x: jnp.ndarray,               # (B, 1, d_model)
    cache: KVCache,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    qk_norm: bool = False,
    window: int = 0,
    numerics: AMRNumerics | None = None,
    eps: float = 1e-6,
) -> tuple[jnp.ndarray, KVCache]:
    """One decode step: write K/V at the cache slot, attend over valid slots.

    ``cache.length`` may be per-slot (``(B,)`` — continuous batching); all
    position math below is row-wise, so a batched step computes exactly
    what each request's solo decode would.
    """
    B = x.shape[0]
    C = cache.k.shape[1]
    pos = cache.length  # () shared or (B,) per-slot logical position
    pos_b = jnp.broadcast_to(pos.astype(jnp.int32), (B,))
    positions = pos_b[:, None]
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, theta,
                           qk_norm, numerics, eps)
    slot = jnp.where(window > 0, pos_b % C, jnp.minimum(pos_b, C - 1)).astype(jnp.int32)
    # masked select instead of dynamic_update_slice: a DUS with a dynamic
    # index on the model-sharded cache dim makes GSPMD replicate the whole
    # cache per layer ("involuntary full rematerialization"); the select is
    # elementwise — it shards, fuses, and aliases in place under donation
    hit = (jnp.arange(C, dtype=jnp.int32)[None, :] == slot[:, None])[:, :, None, None]
    new_k = jnp.where(hit, k.astype(cache.k.dtype), cache.k)
    new_v = jnp.where(hit, v.astype(cache.v.dtype), cache.v)

    scores = _gqa_scores(q, new_k, numerics).astype(jnp.float32)  # (B, Hq, 1, C)
    idx = jnp.arange(C)[None, :]
    valid = idx <= slot[:, None] if window <= 0 else (
        (idx <= slot[:, None]) | (pos_b[:, None] >= C)  # full ring: all live
    )
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    # scores sharding must FOLLOW the cache layout (parallel/sharding.py):
    # kv heads divisible -> head-sharded; otherwise the cache seq dim is
    # model-sharded (flash-decoding) and scores shard on C — pinning heads
    # there would make XLA all-gather the whole cache (measured 135 GB/step)
    if n_kv % ambient_axis_size("model") == 0:
        scores = pin(scores, "batch", "tp", None, None)
    else:
        scores = pin(scores, "batch", None, None, "tp")
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_combine(probs, new_v, numerics).reshape(B, 1, n_heads * head_dim)
    out = pin(dense(out, params["wo"], numerics, site="attn.wo"), "batch", None, None)
    return out, KVCache(new_k, new_v, pos + 1)


# --------------------------------------------------------------- cross-attn
def init_cross_attention(key, d_model, n_heads, head_dim, dtype) -> dict:
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model))
               * (n_heads * head_dim) ** -0.5).astype(dtype),
    }


def attend_cross(params, x, enc_kv: tuple[jnp.ndarray, jnp.ndarray], *,
                 n_heads: int, head_dim: int,
                 numerics: AMRNumerics | None = None) -> jnp.ndarray:
    """Decoder cross-attention; enc_kv = precomputed (K, V) over encoder frames."""
    B, S, _ = x.shape
    q = dense(x, params["wq"], numerics, site="xattn.wq").reshape(B, S, n_heads, head_dim)
    k, v = enc_kv
    # Hq == Hkv here, so the GQA helpers apply with group size 1 — cross
    # attention shares the attn.qk / attn.pv seam sites with self-attention
    scores = _gqa_scores(q, k, numerics).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_combine(probs, v, numerics).reshape(B, S, n_heads * head_dim)
    return dense(out, params["wo"], numerics, site="xattn.wo")


def encode_cross_kv(params, enc_out: jnp.ndarray, *, n_heads: int, head_dim: int,
                    numerics: AMRNumerics | None = None):
    B, T, _ = enc_out.shape
    k = dense(enc_out, params["wk"], numerics, site="xattn.wk").reshape(B, T, n_heads, head_dim)
    v = dense(enc_out, params["wv"], numerics, site="xattn.wv").reshape(B, T, n_heads, head_dim)
    return k, v


def attend_prefill(
    params: dict,
    x: jnp.ndarray,
    capacity: int,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    theta: float,
    qk_norm: bool = False,
    window: int = 0,
    numerics: AMRNumerics | None = None,
    eps: float = 1e-6,
    unroll: bool = False,
) -> tuple[jnp.ndarray, KVCache]:
    """Full-sequence attention that ALSO builds the decode KV cache
    (prefill -> decode handoff). capacity >= S for full attention; for
    sliding-window layers capacity == min(window, S) ring slots."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions, theta,
                           qk_norm, numerics, eps)
    if S >= _CHUNKED_THRESHOLD and S % _Q_CHUNK == 0:
        out = _chunked_attention(q, k, v, window, numerics, unroll=unroll)
    else:
        scores = _gqa_scores(q, k, numerics).astype(jnp.float32)
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        mask = j <= i
        if window > 0:
            mask &= (i - j) < window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = _gqa_combine(probs, v, numerics)
    out = pin(out.reshape(B, S, n_heads * head_dim), "batch", None, "tp")
    out = pin(dense(out, params["wo"], numerics, site="attn.wo"), "batch", None, None)

    C = capacity
    if window > 0 and C <= S:
        # ring layout: token t lives at slot t % C; the last C tokens survive
        roll = S % C
        k_c = jnp.roll(k[:, -C:], roll, axis=1)
        v_c = jnp.roll(v[:, -C:], roll, axis=1)
    else:
        pad = C - S
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = KVCache(k_c, v_c, jnp.asarray(S, jnp.int32))
    return out, cache

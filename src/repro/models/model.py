"""LM model assembly: scan-over-layers blocks, heterogeneous layer patterns,
train / prefill / decode entry points.

Depth is organised as *groups*: ``pattern.kinds`` describes one group's
layer sequence (e.g. 5 sliding-window + 1 global for gemma3; 5 mamba + 1
shared-attention for zamba2); parameters are stacked over ``n_repeat``
group copies and the model scans over them — the traced HLO contains ONE
group body regardless of depth, keeping 512-way SPMD compiles fast
(DESIGN.md §3). Shared (zamba-style) attention params are captured by the
scan body un-stacked, giving true weight sharing.

Caches for decode are pytrees mirroring the grouped structure: stacked
leaves with a leading ``n_repeat`` axis, scanned in lockstep with params.

Approximate numerics: every matmul in every layer routes through
``cfg.numerics`` via layers.dense — a single ``AMRNumerics`` design point
or a site-resolved ``NumericsPolicy`` (repro.numerics.policy).  Per-layer
heterogeneous policies resolve at trace time against a STATIC flat layer
index: when the policy is invariant across scanned group copies the layer
loops keep their compact ``lax.scan`` (resolving at the group-0
representative index — bit-for-bit the legacy trace), otherwise they
statically unroll one body per group (``_needs_static_unroll``).  Encoder
layers sit outside the decoder's flat index space and resolve with
``layer=None`` (site/default entries only).  This includes
the ``amr_kernel`` mode that dispatches to the Pallas amr_matmul kernel,
whose interpret/compiled execution is backend-autodetected and overridable
with ``REPRO_PALLAS_INTERPRET`` (docs/kernels.md). launch/serve.py exposes
the policy (``--numerics/--border/--rank/--pallas-interpret``) so the
serving path exercises the approximate multiplier end to end.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.numerics import numerics_scope
from repro.parallel.constraints import pin

from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import dense, embed, init_embedding, init_mlp, init_rms_norm, mlp, rms_norm, unembed


# --------------------------------------------------------------------------
# per-layer init / apply
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    km, kf = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    p: dict[str, Any] = {"ln1": init_rms_norm(cfg.d_model), "ln2": init_rms_norm(cfg.d_model)}
    if kind in ("full", "swa", "cross"):
        p["attn"] = attn.init_attention(km, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.head_dim, cfg.qk_norm, dtype)
        if kind == "cross" or cfg.encoder_layers:
            p["xattn"] = attn.init_cross_attention(jax.random.fold_in(km, 1), cfg.d_model,
                                                   cfg.n_heads, cfg.head_dim, dtype)
            p["ln_x"] = init_rms_norm(cfg.d_model)
    elif kind == "ssm":
        p["ssm"] = ssm_lib.init_ssm(km, cfg.d_model, cfg.ssm, dtype)
    elif kind == "shared_attn":
        pass  # shared params live at model level
    else:
        raise ValueError(kind)
    if cfg.moe is not None and kind != "shared_attn":
        p["moe"] = moe_lib.init_moe(kf, cfg.d_model, cfg.moe, dtype)
    elif kind != "ssm":  # ssm blocks in mamba-family have no separate MLP
        p["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return p


def _mixer_full(cfg: ModelConfig, p, x, kind, numerics):
    window = cfg.sliding_window if kind == "swa" else 0
    return attn.attend_full(
        p["attn"], x, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        theta=cfg.rope_theta, qk_norm=cfg.qk_norm, window=window,
        numerics=numerics, eps=cfg.norm_eps, unroll=cfg.unroll_layers)


def _apply_layer_full(cfg: ModelConfig, params: dict, x: jnp.ndarray, kind: str,
                      shared: dict | None, enc_kv, numerics) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence layer (train/prefill). Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "shared_attn":
        h = rms_norm(x, shared["ln1"], cfg.norm_eps)
        x = x + _mixer_full(cfg, shared, h, "full", numerics)
        h = rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + mlp(shared["mlp"], h, cfg.mlp_act, numerics)
        return x, aux
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "ssm":
        x = x + ssm_lib.ssm_forward(params["ssm"], h, cfg.d_model, cfg.ssm,
                                    numerics, cfg.norm_eps)
        return x, aux
    x = x + _mixer_full(cfg, params, h, kind, numerics)
    if "xattn" in params and enc_kv is not None:
        h = rms_norm(x, params["ln_x"], cfg.norm_eps)
        x = x + attn.attend_cross(params["xattn"], h, enc_kv, n_heads=cfg.n_heads,
                                  head_dim=cfg.head_dim, numerics=numerics)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_lib.moe_forward(params["moe"], h, cfg.moe, numerics=numerics)
        x = x + y
    else:
        x = x + mlp(params["mlp"], h, cfg.mlp_act, numerics)
    return x, aux


def _apply_layer_decode(cfg: ModelConfig, params: dict, x, kind: str, cache,
                        shared: dict | None, enc_kv, numerics):
    """One-token layer step. Returns (x, new_cache)."""
    if kind == "shared_attn":
        h = rms_norm(x, shared["ln1"], cfg.norm_eps)
        y, cache = attn.attend_decode(
            shared["attn"], h, cache, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
            window=0, numerics=numerics, eps=cfg.norm_eps)
        x = x + y
        h = rms_norm(x, shared["ln2"], cfg.norm_eps)
        return x + mlp(shared["mlp"], h, cfg.mlp_act, numerics), cache
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "ssm":
        y, cache = ssm_lib.ssm_decode(params["ssm"], h, cache, cfg.d_model, cfg.ssm,
                                      numerics, cfg.norm_eps)
        return x + y, cache  # mamba-family blocks have no separate MLP
    else:
        window = cfg.sliding_window if kind == "swa" else 0
        y, cache = attn.attend_decode(
            params["attn"], h, cache, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
            window=window, numerics=numerics, eps=cfg.norm_eps)
        x = x + y
        if "xattn" in params and enc_kv is not None:
            hx = rms_norm(x, params["ln_x"], cfg.norm_eps)
            x = x + attn.attend_cross(params["xattn"], hx, enc_kv, n_heads=cfg.n_heads,
                                      head_dim=cfg.head_dim, numerics=numerics)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, _ = moe_lib.moe_forward(params["moe"], h, cfg.moe, numerics=numerics)
        x = x + y
    else:
        x = x + mlp(params["mlp"], h, cfg.mlp_act, numerics)
    return x, cache


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------

def group_structure(cfg: ModelConfig) -> tuple[tuple[str, ...], int]:
    """(kinds within one group, n_repeat)."""
    if cfg.pattern is not None:
        return cfg.pattern.kinds, cfg.pattern.n_repeat
    return (cfg.default_mixer,), cfg.n_layers


def _needs_static_unroll(numerics, kinds: tuple[str, ...], n_repeat: int) -> bool:
    """True when the numerics policy varies ACROSS scanned group copies.

    Per-layer design points are static (baked into the jit trace), so a
    policy that assigns different multipliers to different group repeats
    forces the layer loop to unroll with a concrete flat index per copy.
    Bare ``AMRNumerics``, ``UniformPolicy`` and repeat-invariant
    ``PerLayerPolicy`` keep the compact one-body ``lax.scan`` — bit-for-bit
    the legacy trace.  Inside the scan the policy resolves at the
    representative in-group flat index (group 0), which by invariance is
    every copy's answer.
    """
    inv = getattr(numerics, "repeat_invariant", None)
    return inv is not None and not inv(len(kinds), n_repeat)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    kinds, n_repeat = group_structure(cfg)
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)

    def group_params(gkey):
        return [
            _init_layer(jax.random.fold_in(gkey, i), cfg, kind)
            for i, kind in enumerate(kinds)
        ]

    stacked = jax.vmap(lambda k: _stack_to_tree(group_params(k)))(
        jax.random.split(keys[0], n_repeat))

    params: dict[str, Any] = {
        "embed": init_embedding(keys[1], cfg.vocab, cfg.d_model, dtype),
        "final_norm": init_rms_norm(cfg.d_model),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(keys[2], cfg.vocab, cfg.d_model, dtype)
    if "shared_attn" in kinds:
        params["shared"] = {
            "attn": attn.init_attention(keys[3], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm, dtype),
            "ln1": init_rms_norm(cfg.d_model),
            "ln2": init_rms_norm(cfg.d_model),
            "mlp": init_mlp(keys[4], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype),
        }
    if cfg.encoder_layers:
        enc_keys = jax.random.split(keys[5], cfg.encoder_layers)
        enc_layers = [_init_enc_layer(k, cfg) for k in enc_keys]
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers)
        params["enc_norm"] = init_rms_norm(cfg.d_model)
    if cfg.vision_prefix:
        params["vision_proj"] = (jax.random.normal(keys[6], (cfg.d_model, cfg.d_model))
                                 * cfg.d_model ** -0.5).astype(dtype)
    return params


def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    km, kf = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln1": init_rms_norm(cfg.d_model), "ln2": init_rms_norm(cfg.d_model),
        "attn": attn.init_attention(km, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, cfg.qk_norm, dtype),
        "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype),
    }


def _stack_to_tree(trees: list):
    """List of identical pytrees -> single pytree with leading stack axis.

    Heterogeneous group members (different kinds) are kept as a tuple —
    only the *repeat* axis is stacked (outer vmap handles that).
    """
    return tuple(trees)


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def _encoder_forward(cfg: ModelConfig, params, frames, numerics):
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    def enc_body(carry, lp):
        x, g = carry
        # encoder layers get their own numerics-PRNG coordinate space so
        # amr_noise draws decorrelate from the decoder stack (layer < 0);
        # per-layer policies see layer=None here (no static coordinate) and
        # resolve through their site/default entries
        with numerics_scope(layer=-1 - g):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            x = x + attn.attend_full(lp["attn"], h, n_heads=cfg.n_heads,
                                     n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                                     theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                                     window=0, causal=False, numerics=numerics,
                                     eps=cfg.norm_eps)
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + mlp(lp["mlp"], h, cfg.mlp_act, numerics)
        return (x, g + 1), None

    (x, _), _ = jax.lax.scan(enc_body, (frames, jnp.zeros((), jnp.int32)),
                             params["encoder"],
                             unroll=cfg.encoder_layers if cfg.unroll_layers else 1)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def encode(cfg: ModelConfig, params: dict, frames: jnp.ndarray) -> jnp.ndarray:
    """Public encoder entry point (whisper-family): frame embeddings
    (B, P, D) -> encoder output (B, P, D) under ``cfg.numerics``.

    ``decode_step`` takes this as ``enc_out`` so a decode loop can attend
    the same encoder state ``forward``/``prefill_with_cache`` computed —
    the decode-vs-forward parity arm of the conformance matrix needs it.
    """
    if not cfg.encoder_layers:
        raise ValueError("encode() requires cfg.encoder_layers > 0")
    return _encoder_forward(cfg, params, frames, cfg.numerics)


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            extra_embeddings: jnp.ndarray | None = None,
            last_only: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward (training / prefill). Returns (logits, aux_loss).

    tokens: (B, S) int32. extra_embeddings: (B, P, D) stub-frontend prefix
    (vision patches / audio frames) prepended to the token embeddings.
    last_only: unembed only the final position (prefill — sliced BEFORE the
    LM head so the (B, S, vocab) tensor is never built).
    """
    kinds, n_repeat = group_structure(cfg)
    numerics = cfg.numerics
    x = pin(embed(params["embed"], tokens), "batch", None, None)
    if cfg.vision_prefix and extra_embeddings is not None:
        vis = dense(extra_embeddings, params["vision_proj"], None,
                    site="vision.proj")
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)

    enc_kv = None
    if cfg.encoder_layers and extra_embeddings is not None:
        enc_out = _encoder_forward(cfg, params, extra_embeddings, numerics)
        enc_kv = "defer"  # computed per-layer (cross params are per-layer)

    shared = params.get("shared")

    def group_body(carry, group_params, g_static=None):
        # g rides in the carry so scanned group copies see distinct layer
        # indices for the numerics PRNG scope (re-established inside the
        # body: a remat re-trace rebuilds identical noise keys).  g_static
        # is the STATIC group index of the unrolled per-layer-policy path
        # (None when scanning — the policy then resolves at the group-0
        # representative flat index, valid by repeat invariance).
        x, aux, g = carry
        for i, kind in enumerate(kinds):
            lp = group_params[i]
            flat = i if g_static is None else g_static * len(kinds) + i
            with numerics_scope(layer=g * len(kinds) + i, static_layer=flat):
                ekv = None
                if enc_kv is not None and "xattn" in lp:
                    ekv = attn.encode_cross_kv(lp["xattn"], enc_out, n_heads=cfg.n_heads,
                                               head_dim=cfg.head_dim, numerics=numerics)
                x, a = _apply_layer_full(cfg, lp, x, kind, shared, ekv, numerics)
            aux = aux + a
        return (x, aux, g + 1), None

    carry = (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    if _needs_static_unroll(numerics, kinds, n_repeat):
        for gi in range(n_repeat):
            body = partial(group_body, g_static=gi)
            if cfg.remat == "block":
                body = jax.checkpoint(body, prevent_cse=False)
            carry, _ = body(carry, jax.tree.map(lambda l: l[gi], params["layers"]))
        x, aux, _ = carry
    else:
        body = group_body
        if cfg.remat == "block":
            body = jax.checkpoint(group_body, prevent_cse=False)
        (x, aux, _), _ = jax.lax.scan(
            body, carry, params["layers"],
            unroll=n_repeat if cfg.unroll_layers else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:, :]
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = pin(unembed(x, head), "batch", None, "tp")
    if cfg.vision_prefix and extra_embeddings is not None and not last_only:
        logits = logits[:, cfg.vision_prefix:]
    return logits, aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               per_slot: bool = False) -> Any:
    """Grouped cache pytree: leaves stacked over n_repeat (scan axis).

    ``per_slot=True`` gives each batch row its own KV position vector
    (``KVCache.length`` of shape ``(B,)``) — the continuous-batching slot
    cache used by serve/engine.py, where rows decode at different depths.
    """
    kinds, n_repeat = group_structure(cfg)
    dtype = jnp.dtype(cfg.dtype)

    def one(kind):
        if kind == "ssm":
            return ssm_lib.SSMState.zeros(batch, cfg.d_model, cfg.ssm, dtype)
        cap = (min(capacity, cfg.sliding_window)
               if kind == "swa" and cfg.sliding_window else capacity)
        return attn.KVCache.zeros(batch, cap, cfg.n_kv_heads, cfg.head_dim, dtype,
                                  per_slot=per_slot)

    group = tuple(one(k) for k in kinds)
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n_repeat,) + l.shape), group)


def _cache_position(cache: Any):
    """Logical decode position from the first KVCache in the tree (None for
    pure-SSM caches, which carry no position) — folds into the numerics
    PRNG scope so amr_noise draws decorrelate across generated tokens.

    Returns a scalar for shared-position caches or a ``(B,)`` vector for
    per-slot caches (each request then folds its OWN position, keeping
    batched amr_noise draws identical to each request's solo decode)."""
    found: list = []

    def is_kv(node):
        if isinstance(node, attn.KVCache):
            found.append(node.length)
            return True
        return False

    jax.tree_util.tree_flatten(cache, is_leaf=is_kv)
    if not found:
        return None
    length = found[0]  # stacked over n_repeat: every copy holds the same pos
    return length[0] if getattr(length, "ndim", 0) else length


def _merge_active(old: Any, new: Any, active: jnp.ndarray) -> Any:
    """Keep ``new`` cache state only for active slots; inactive rows retain
    ``old`` bit-for-bit (positions don't advance, K/V writes are discarded).

    Cache leaves are stacked ``(n_repeat, B, ...)``; per-slot length leaves
    are ``(n_repeat, B)``. Anything without a batch axis (shared scalar
    positions) passes through unmasked — active-masked decode is only
    meaningful on per-slot caches.
    """
    B = active.shape[0]

    def merge(o, n):
        if n.ndim >= 2 and n.shape[1] == B:
            m = active.reshape((1, B) + (1,) * (n.ndim - 2))
            return jnp.where(m, n, o)
        return n

    return jax.tree.map(merge, old, new)


def decode_step(cfg: ModelConfig, params: dict, token: jnp.ndarray, cache: Any,
                enc_out: jnp.ndarray | None = None,
                active: jnp.ndarray | None = None) -> tuple[jnp.ndarray, Any]:
    """One serving step: token (B, 1) int32 -> (logits (B, 1, V), new cache).

    ``active`` (optional, (B,) bool): continuous-batching slot mask. All
    rows compute (a single fixed-shape jit trace regardless of which slots
    are live), but inactive rows' cache writes and position advances are
    rolled back, so their state — and therefore the next admitted request's
    prefill handoff — is untouched. Logits of inactive rows are garbage;
    callers ignore them.
    """
    kinds, _ = group_structure(cfg)
    numerics = cfg.numerics
    pos = _cache_position(cache)
    x = embed(params["embed"], token)
    shared = params.get("shared")

    def group_body(carry, scanned, g_static=None):
        # cache rides in the CARRY (indexed by the group counter) rather than
        # as scan xs/ys: carry buffers alias in place across iterations,
        # while xs->ys caches double/triple-buffer (measured: 12.8 GB of
        # temps on a 4.3 GB qwen3 decode cache)
        x, cache_all, g = carry
        group_params, _ = scanned
        gi = g if g_static is None else g_static
        group_cache = jax.tree.map(lambda l: l[gi], cache_all)
        new_caches = []
        for i, kind in enumerate(kinds):
            lp = group_params[i]
            flat = i if g_static is None else g_static * len(kinds) + i
            with numerics_scope(step=pos, layer=g * len(kinds) + i,
                                static_layer=flat):
                ekv = None
                if enc_out is not None and "xattn" in lp:
                    ekv = attn.encode_cross_kv(lp["xattn"], enc_out, n_heads=cfg.n_heads,
                                               head_dim=cfg.head_dim, numerics=numerics)
                x, c = _apply_layer_decode(cfg, lp, x, kind, group_cache[i], shared,
                                           ekv, numerics)
            new_caches.append(c)
        cache_all = jax.tree.map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(full, new, gi, 0),
            cache_all, tuple(new_caches))
        return (x, cache_all, g + 1), None

    kinds2, n_repeat = group_structure(cfg)
    carry = (x, cache, jnp.zeros((), jnp.int32))
    if _needs_static_unroll(numerics, kinds, n_repeat):
        # per-layer heterogeneous policy: statically unrolled copies, still
        # ONE jit trace per engine — serve's no-recompile property holds
        for gi in range(n_repeat):
            group_params = jax.tree.map(lambda l: l[gi], params["layers"])
            carry, _ = group_body(carry, (group_params, gi), g_static=gi)
        x, new_cache, _ = carry
    else:
        (x, new_cache, _), _ = jax.lax.scan(
            group_body, carry,
            (params["layers"], jnp.arange(n_repeat)),
            unroll=n_repeat if cfg.unroll_layers else 1)
    if active is not None:
        new_cache = _merge_active(cache, new_cache, active)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, head), new_cache


# --------------------------------------------------------------------------
# prefill -> decode handoff
# --------------------------------------------------------------------------

def _apply_layer_prefill(cfg: ModelConfig, params: dict, x, kind: str, capacity: int,
                         shared, enc_kv, numerics):
    """Full-sequence layer that also emits its decode cache entry."""
    def attn_prefill(p, h, window):
        cap = min(capacity, cfg.sliding_window) if window else capacity
        return attn.attend_prefill(
            p, h, cap, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
            window=cfg.sliding_window if window else 0, numerics=numerics,
            eps=cfg.norm_eps, unroll=cfg.unroll_layers)

    if kind == "shared_attn":
        h = rms_norm(x, shared["ln1"], cfg.norm_eps)
        y, cache = attn_prefill(shared["attn"], h, window=False)
        x = x + y
        h = rms_norm(x, shared["ln2"], cfg.norm_eps)
        return x + mlp(shared["mlp"], h, cfg.mlp_act, numerics), cache
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "ssm":
        y, cache = ssm_lib.ssm_prefill(params["ssm"], h, cfg.d_model, cfg.ssm,
                                       numerics, cfg.norm_eps)
        return x + y, cache
    y, cache = attn_prefill(params["attn"], h, window=(kind == "swa"))
    x = x + y
    if "xattn" in params and enc_kv is not None:
        hx = rms_norm(x, params["ln_x"], cfg.norm_eps)
        x = x + attn.attend_cross(params["xattn"], hx, enc_kv, n_heads=cfg.n_heads,
                                  head_dim=cfg.head_dim, numerics=numerics)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, _ = moe_lib.moe_forward(params["moe"], h, cfg.moe, numerics=numerics)
        x = x + y
    else:
        x = x + mlp(params["mlp"], h, cfg.mlp_act, numerics)
    return x, cache


def prefill_with_cache(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
                       capacity: int,
                       extra_embeddings: jnp.ndarray | None = None
                       ) -> tuple[jnp.ndarray, Any]:
    """One-shot prefill: last-position logits + a ready decode cache.

    The production serving path: O(1) dispatches instead of S sequential
    decode steps (launch/serve.py uses this; consistency vs step-by-step
    prefill is property-tested)."""
    kinds, n_repeat = group_structure(cfg)
    numerics = cfg.numerics
    x = pin(embed(params["embed"], tokens), "batch", None, None)
    if cfg.vision_prefix and extra_embeddings is not None:
        vis = dense(extra_embeddings, params["vision_proj"], None,
                    site="vision.proj")
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)

    enc_out = None
    if cfg.encoder_layers and extra_embeddings is not None:
        enc_out = _encoder_forward(cfg, params, extra_embeddings, numerics)

    shared = params.get("shared")

    def group_body(carry, group_params, g_static=None):
        x, g = carry
        caches = []
        for i, kind in enumerate(kinds):
            lp = group_params[i]
            flat = i if g_static is None else g_static * len(kinds) + i
            with numerics_scope(layer=g * len(kinds) + i, static_layer=flat):
                ekv = None
                if enc_out is not None and "xattn" in lp:
                    ekv = attn.encode_cross_kv(lp["xattn"], enc_out, n_heads=cfg.n_heads,
                                               head_dim=cfg.head_dim, numerics=numerics)
                x, c = _apply_layer_prefill(cfg, lp, x, kind, capacity, shared, ekv,
                                            numerics)
            caches.append(c)
        return (x, g + 1), tuple(caches)

    carry = (x, jnp.zeros((), jnp.int32))
    if _needs_static_unroll(numerics, kinds, n_repeat):
        per_group = []
        for gi in range(n_repeat):
            carry, caches = group_body(
                carry, jax.tree.map(lambda l: l[gi], params["layers"]),
                g_static=gi)
            per_group.append(caches)
        # stack the per-group cache entries into the leading n_repeat axis
        # the scan path's ys would have produced (decode consumes either)
        cache = jax.tree.map(lambda *ls: jnp.stack(ls), *per_group)
        x, _ = carry
    else:
        (x, _), cache = jax.lax.scan(group_body, carry, params["layers"],
                                     unroll=n_repeat if cfg.unroll_layers else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x[:, -1:, :], head)
    return logits, cache

"""Mixture-of-Experts with sorted-capacity dispatch (GShard/Switch-style).

Design (DESIGN.md §3): tokens are routed top-k, sorted by expert id, and
scattered into fixed (E, C, D) capacity buffers; expert FFNs run as plain
einsums (MXU-friendly, cleanly partitionable by XLA SPMD: E or F shard on
"model"); outputs are combined by weighted scatter-add. Fully
differentiable; overflow beyond capacity_factor drops (standard).

The router stays in exact numerics — top-k decisions are sensitive to small
logit perturbations and the paper's technique targets bulk matmuls
(DESIGN.md §Arch-applicability). Expert FFNs follow the numerics policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.numerics import AMRNumerics
from repro.parallel.constraints import pin



def init_moe(key, d_model: int, cfg: MoEConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_ff = cfg.d_ff_expert ** -0.5
    E, F = cfg.n_experts, cfg.d_ff_expert
    return {
        "router": (jax.random.normal(ks[0], (d_model, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, F)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, F)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, d_model)) * s_ff).astype(dtype),
    }


def moe_forward(
    params: dict,
    x: jnp.ndarray,                  # (B, S, D)
    cfg: MoEConfig,
    *,
    capacity_factor: float = 1.25,
    numerics: AMRNumerics | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux load-balancing loss scalar)."""
    if cfg.dispatch_shard == "local":
        return _moe_forward_local(params, x, cfg, capacity_factor=capacity_factor,
                                  numerics=numerics)
    return _moe_forward_global(params, x, cfg, capacity_factor=capacity_factor,
                               numerics=numerics)


def _moe_forward_global(
    params: dict,
    x: jnp.ndarray,
    cfg: MoEConfig,
    *,
    capacity_factor: float = 1.25,
    numerics: AMRNumerics | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, D)

    logits = jnp.matmul(xf.astype(jnp.float32), params["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                             # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- sorted-capacity dispatch. Small token counts (decode steps,
    # short prefills) run DROPLESS (C = T*K): capacity dropping there is
    # degenerate and would make decode disagree with prefill routing.
    C = max(int(T * K * capacity_factor / E + 0.999), 1)
    if T * K <= 4096:
        C = T * K
    fid = top_e.reshape(-1)                                            # (T*K,)
    fw = top_w.reshape(-1)
    tok = jnp.arange(T * K, dtype=jnp.int32) // K
    order = jnp.argsort(fid, stable=True)
    fid_s, fw_s, tok_s = fid[order], fw[order], tok[order]
    counts = jnp.zeros((E,), jnp.int32).at[fid].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[fid_s]           # slot in expert
    keep = pos < C
    slot = jnp.where(keep, pos, C)                                     # C drops (mode=drop)

    xbuf = jnp.zeros((E, C + 1, D), x.dtype).at[fid_s, slot].set(
        xf[tok_s], mode="drop")[:, :C]
    if cfg.dispatch_shard == "batch":
        xbuf = pin(xbuf, None, "batch", None)
    elif cfg.dispatch_shard == "expert":
        xbuf = pin(xbuf, "tp", None, None)

    if cfg.dispatch_shard == "batch":
        hidden_pin = lambda t: pin(t, None, "batch", "tp")
        out_pin = lambda t: pin(t, None, "batch", None)
    elif cfg.dispatch_shard == "expert":
        hidden_pin = lambda t: pin(t, "tp", None, None)
        out_pin = lambda t: pin(t, "tp", None, None)
    else:
        hidden_pin = lambda t: pin(t, None, None, "tp")
        out_pin = lambda t: t
    if numerics is None or numerics.is_exact():
        g = hidden_pin(jnp.einsum("ecd,edf->ecf", xbuf, params["w_gate"]))
        u = hidden_pin(jnp.einsum("ecd,edf->ecf", xbuf, params["w_up"]))
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        ybuf = out_pin(jnp.einsum("ecf,efd->ecd", h, params["w_down"]))  # (E, C, D)
    else:
        from repro.numerics.approx_matmul import approx_matmul

        # ONE grouped seam call per projection: the (E, C, D) @ (E, D, F)
        # activation-form batched matmul (sites "moe.expert.*", resolvable
        # by the "moe.expert" policy prefix — numerics/policy.py).  The
        # grouped route quantizes per expert (per-row of the capacity
        # buffer, per-column of each expert's weight panel), bit-identical
        # to the old per-expert vmap; amr_noise draws ONE (E, C, F) tensor,
        # so experts decorrelate without the unit-scope key plumbing.
        def expert_mm(a, w, site):
            return approx_matmul(a, w, numerics, site=site).astype(x.dtype)

        g = hidden_pin(expert_mm(xbuf, params["w_gate"], "moe.expert.w_gate"))
        u = hidden_pin(expert_mm(xbuf, params["w_up"], "moe.expert.w_up"))
        h = (jax.nn.silu(g) * u).astype(x.dtype)
        ybuf = out_pin(expert_mm(h, params["w_down"], "moe.expert.w_down"))

    ypad = jnp.pad(ybuf, ((0, 0), (0, 1), (0, 0)))                     # slot C reads 0
    gathered = ypad[fid_s, slot] * (fw_s * keep)[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[tok_s].add(gathered)
    return pin(out.reshape(B, S, D), "batch", None, None), aux


# ---------------------------------------------------------------------------
# shard_map-local dispatch (dispatch_shard == "local")
# ---------------------------------------------------------------------------

def _moe_local_body(xf, router, w_gate, w_up, w_down, cfg: MoEConfig,
                    capacity_factor: float, batch_axes, model_axis: str | None):
    """Per-shard MoE: local routing/sort/capacity + TP experts.

    xf: (T_local, D). Weights: router (D, E) replicated; w_gate/w_up
    (E, D, F_local), w_down (E, F_local, D) — model-axis TP shards.
    One psum over the model axis after w_down; NO cross-data collectives:
    every token is dispatched and combined on the shard that owns it.
    """
    import jax
    import jax.numpy as jnp

    T, D = xf.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.matmul(xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)

    C = max(int(T * K * capacity_factor / E + 0.999), 1)
    if T * K <= 4096:
        C = T * K  # dropless for small token counts (see _moe_forward_global)
    fid = top_e.reshape(-1)
    fw = top_w.reshape(-1)
    tok = jnp.arange(T * K, dtype=jnp.int32) // K
    order = jnp.argsort(fid, stable=True)
    fid_s, fw_s, tok_s = fid[order], fw[order], tok[order]
    counts = jnp.zeros((E,), jnp.int32).at[fid].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[fid_s]
    keep = pos < C
    slot = jnp.where(keep, pos, C)

    xbuf = jnp.zeros((E, C + 1, D), xf.dtype).at[fid_s, slot].set(
        xf[tok_s], mode="drop")[:, :C]
    g = jnp.einsum("ecd,edf->ecf", xbuf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xbuf, w_up)
    h = (jax.nn.silu(g) * u).astype(xf.dtype)
    ybuf = jnp.einsum("ecf,efd->ecd", h, w_down)

    ypad = jnp.pad(ybuf, ((0, 0), (0, 1), (0, 0)))
    gathered = ypad[fid_s, slot] * (fw_s * keep)[:, None].astype(xf.dtype)
    out = jnp.zeros((T, D), xf.dtype).at[tok_s].add(gathered)
    if model_axis:
        # TP partial sums: reduce AFTER the combine — (T, D) is top_k *
        # capacity_factor (= 7.5x for moonshot) smaller than (E, C, D)
        out = jax.lax.psum(out, model_axis)
    return out, aux


def _moe_forward_local(params, x, cfg: MoEConfig, *, capacity_factor, numerics):
    """shard_map dispatch: tokens never leave their data shard."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel.constraints import _ambient_axes

    axes = _ambient_axes()
    if not axes:  # no mesh (unit tests): run the body on the whole array
        B, S, D = x.shape
        out, aux = _moe_local_body(
            x.reshape(B * S, D), params["router"], params["w_gate"],
            params["w_up"], params["w_down"], cfg, capacity_factor, None, None)
        return out.reshape(B, S, D), aux

    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    model_axis = "model" if "model" in axes else None
    F = params["w_gate"].shape[-1]
    tp_ok = model_axis and F % axes[model_axis] == 0

    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    x_spec = P(batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None), None)
    w_col = P(None, None, "model" if tp_ok else None)
    w_row = P(None, "model" if tp_ok else None, None)

    body = lambda xs, r, wg, wu, wd: _moe_local_body(
        xs, r, wg, wu, wd, cfg, capacity_factor, batch_axes,
        model_axis if tp_ok else None)
    out, aux = shard_map(
        body,
        mesh=jax.sharding.get_abstract_mesh(),
        in_specs=(x_spec, P(None, None), w_col, w_col, w_row),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(xf, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out.reshape(B, S, D), aux

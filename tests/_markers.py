"""Shared environment-gating markers for the test suite."""
import jax
import pytest

# Mesh/sharding machinery targets modern jax (jax.sharding.AxisType et al.);
# on older jax it fails inside jax itself before testing anything of ours.
#
# Apply this ONLY to tests that actually build meshes / shardings /
# shard_maps (or subprocesses that do).  Plain single-device forward /
# train / decode paths run fine on legacy jax — ``parallel.constraints.pin``
# degrades to a no-op there — and must NOT hide behind this guard.
requires_modern_jax = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="requires modern jax.sharding (AxisType-era) APIs")

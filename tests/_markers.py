"""Shared environment-gating markers for the test suite."""
import os

import jax
import pytest

# Mesh/sharding machinery targets modern jax (jax.sharding.AxisType et al.);
# on older jax it fails inside jax itself before testing anything of ours.
#
# Apply this ONLY to tests that actually build meshes / shardings /
# shard_maps (or subprocesses that do).  Plain single-device forward /
# train / decode paths run fine on legacy jax — ``parallel.constraints.pin``
# degrades to a no-op there — and must NOT hide behind this guard.
requires_modern_jax = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="requires modern jax.sharding (AxisType-era) APIs")

# Full conformance-matrix sweeps (every arch x every mode) are minutes of
# CPU — they run in the nightly workflow (REPRO_NIGHTLY=1), while tier-1
# keeps one representative arm per family.  An env gate rather than a
# pytest -m filter so the tier-1 invocation (`pytest -x -q`) needs no
# extra flags and can never accidentally pick the slow arms up.
nightly = pytest.mark.skipif(
    not os.environ.get("REPRO_NIGHTLY"),
    reason="nightly-only sweep (set REPRO_NIGHTLY=1)")

"""Shared environment-gating markers for the test suite."""
import jax
import pytest

# Mesh/sharding machinery targets modern jax (jax.sharding.AxisType et al.);
# on older jax it fails inside jax itself before testing anything of ours.
requires_modern_jax = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="requires modern jax.sharding (AxisType-era) APIs")

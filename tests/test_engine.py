"""Compiled-engine tests: bit-exact parity vs the numpy replay + caches."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import engine, mrsd, ppgen, reduction  # noqa: E402

DESIGNS = [
    (n_digits, border)
    for n_digits in (2, 4, 8)
    for border in (None, 4, 8)
]


def _random_operand_bits(n_digits, batch, seed):
    rng = np.random.default_rng(seed)
    xd = mrsd.random_digits(rng, n_digits, batch)
    yd = mrsd.random_digits(rng, n_digits, batch)
    return ppgen.flatten_operand_bits(xd), ppgen.flatten_operand_bits(yd)


class TestParity:
    @pytest.mark.parametrize("n_digits,border", DESIGNS)
    def test_split_bit_exact_vs_numpy(self, n_digits, border):
        # 999 deliberately exercises the ragged final 32-sample lane
        batch = 256 if n_digits == 8 else 999
        xb, yb = _random_operand_bits(n_digits, batch, seed=n_digits * 31 + (border or 0))
        sched = reduction.get_schedule(n_digits, border)
        lo_np, hi_np = reduction.evaluate_split(sched, xb, yb)
        eng = engine.get_engine(n_digits, border)
        lo_jx, hi_jx = eng.evaluate_split(xb, yb)
        assert lo_jx.dtype == np.int64 and hi_jx.dtype == np.int64
        np.testing.assert_array_equal(lo_jx, lo_np)
        np.testing.assert_array_equal(hi_jx, hi_np)

    def test_exact_design_matches_integer_products(self):
        """8-digit exact design via the engine == arbitrary-precision ints
        (values reach ~2**69: exercises every limb of the split)."""
        n = 8
        rng = np.random.default_rng(5)
        xd = mrsd.random_digits(rng, n, 64)
        yd = mrsd.random_digits(rng, n, 64)
        lo, hi = engine.evaluate_digits_split(n, None, xd, yd)
        for i in range(64):
            expect = mrsd.decode_int(xd[i]) * mrsd.decode_int(yd[i])
            assert int(lo[i]) + (int(hi[i]) << 32) == expect

    def test_multiplier_backend_switch(self):
        """AMRMultiplier dispatches both backends to identical results."""
        from repro.core import AMRMultiplier

        m = AMRMultiplier(2, border=8, engine="jax")
        rng = np.random.default_rng(9)
        xd = mrsd.random_digits(rng, 2, 333)
        yd = mrsd.random_digits(rng, 2, 333)
        lo_j, hi_j = m.multiply_digits_split(xd, yd)
        lo_n, hi_n = m.multiply_digits_split(xd, yd, engine="numpy")
        np.testing.assert_array_equal(lo_j, lo_n)
        np.testing.assert_array_equal(hi_j, hi_n)
        with pytest.raises(ValueError):
            AMRMultiplier(2, border=8, engine="tpu-magic")

    def test_lut_backends_agree(self):
        from repro.core import lut

        np.testing.assert_array_equal(
            lut.build_int8_lut(8, engine="jax"),
            lut.build_int8_lut(8, engine="numpy"),
        )


class TestCaches:
    def test_schedule_cache_hit(self):
        reduction.get_schedule.cache_clear()
        s1 = reduction.get_schedule(2, 8)
        hits_before = reduction.get_schedule.cache_info().hits
        s2 = reduction.get_schedule(2, 8)
        assert s2 is s1
        assert reduction.get_schedule.cache_info().hits == hits_before + 1

    def test_engine_cache_hit_and_shares_schedule(self):
        engine.get_engine.cache_clear()
        e1 = engine.get_engine(2, 8)
        e2 = engine.get_engine(2, 8)
        assert e2 is e1  # compiled artifact built once per design point
        assert e1.schedule is reduction.get_schedule(2, 8)


class TestLaneHandling:
    @pytest.mark.parametrize("batch", [1, 31, 32, 33, 64, 100])
    def test_ragged_batches(self, batch):
        xb, yb = _random_operand_bits(2, batch, seed=batch)
        sched = reduction.get_schedule(2, 8)
        eng = engine.get_engine(2, 8)
        lo_np, hi_np = reduction.evaluate_split(sched, xb, yb)
        lo_jx, hi_jx = eng.evaluate_split(xb, yb)
        assert lo_jx.shape == (batch,)
        np.testing.assert_array_equal(lo_jx, lo_np)
        np.testing.assert_array_equal(hi_jx, hi_np)

"""Per-arch smoke tests: reduced config, one forward + one decode step on CPU.

Asserts output shapes and finiteness (no NaNs) for every assigned arch —
deliverable (f). The FULL configs are exercised abstractly by the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_reduced_config
from repro.models import decode_step, forward, init_cache, init_params

# Single-device smoke only — no meshes/shardings anywhere in these tests, so
# they run on legacy jax too (pin() is a no-op without an ambient mesh).

ALL = ARCH_NAMES + ["amr-paper-100m"]


def _inputs(cfg, batch=2, seq=32):
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)
    extra = None
    if cfg.vision_prefix:
        extra = jnp.asarray(rng.normal(size=(batch, cfg.vision_prefix, cfg.d_model)),
                            jnp.dtype(cfg.dtype))
    elif cfg.encoder_layers:
        extra = jnp.asarray(rng.normal(size=(batch, cfg.encoder_frames, cfg.d_model)),
                            jnp.dtype(cfg.dtype))
    return tokens, extra


@pytest.mark.parametrize("arch", ALL)
def test_forward_smoke(arch):
    cfg = get_reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens, extra = _inputs(cfg)
    logits, aux = forward(cfg, params, tokens, extra)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    """One grad step: loss finite, grads finite and tree-matching params."""
    cfg = get_reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens, extra = _inputs(cfg)

    def loss_fn(p):
        logits, aux = forward(cfg, p, tokens, extra)
        tgt = jnp.roll(tokens, -1, axis=1)
        ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(ll, tgt[..., None], axis=-1))
        return loss + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert len(flat) == len(jax.tree.leaves(params))
    for g in flat:
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ALL)
def test_decode_smoke(arch):
    cfg = get_reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, batch=2, capacity=16)
    tok = jnp.zeros((2, 1), jnp.int32)
    enc = None
    if cfg.encoder_layers:
        enc = jnp.zeros((2, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    logits, cache = decode_step(cfg, params, tok, cache, enc)
    logits2, cache = decode_step(cfg, params, tok + 1, cache, enc)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()


def test_decode_matches_prefill_gemma():
    """Sequential decode == full forward on the same tokens (KV-cache sanity)."""
    cfg = get_reduced_config("gemma-2b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    full_logits, _ = forward(cfg, params, tokens)

    cache = init_cache(cfg, batch=1, capacity=8)
    outs = []
    for t in range(8):
        lg, cache = decode_step(cfg, params, tokens[:, t : t + 1], cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=0.15, atol=0.15)  # bf16 accumulation-order tolerance


def test_decode_matches_prefill_mamba():
    cfg = get_reduced_config("mamba2-370m")
    params = init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    S = 16  # one SSD chunk
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, S)), jnp.int32)
    full_logits, _ = forward(cfg, params, tokens)
    cache = init_cache(cfg, batch=1, capacity=S)
    outs = []
    for t in range(S):
        lg, cache = decode_step(cfg, params, tokens[:, t : t + 1], cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=0.15, atol=0.2)

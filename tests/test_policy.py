"""Site-resolved numerics policies (numerics/policy.py): UniformPolicy is
bit-for-bit the legacy global AMRNumerics in both train and serve,
PerLayerPolicy resolves exactly the (site, layer) coordinates it names,
policy JSON artifacts round-trip (including schedule_ref re-registration
across a simulated process restart), and heterogeneous policies add zero
decode recompiles in the serve engine."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _trace_utils import assert_single_trace
from repro.configs.base import ModelConfig
from repro.core import reduction
from repro.launch.cli import policy_label
from repro.models import forward, init_params
from repro.numerics import (AMRNumerics, AuditTrace, PerLayerPolicy,
                            UniformPolicy, as_policy, injection, load_policy,
                            numerics_scope, policy_from_json, policy_summary,
                            policy_to_json, resolve_numerics, save_policy,
                            validate_policy)
from repro.serve import Request, ServeEngine
from repro.train.steps import loss_fn

CAP = 24
PROMPTS = [(5, 9, 2, 7), (3, 11, 4, 1, 8, 6), (13, 2)]

# every registered mode, at serve-test-sized parameters
ALL_MODES = [
    AMRNumerics("exact"),
    AMRNumerics("amr_lut", border=2),
    AMRNumerics("amr_inject", border=2),
    AMRNumerics("amr_lowrank", border=2, rank=2),
    AMRNumerics("amr_noise", border=2, noise_seed=3),
    AMRNumerics("amr_kernel", border=2, rank=0),
]


def tiny_cfg(numerics):
    return ModelConfig(
        name="policy-test", family="dense", vocab=61, d_model=32, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, numerics=numerics)


def _tokens(cfg, batch=2, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)


def _train_logits(nm):
    """(loss, float32 logits) through the real training loss."""
    cfg = tiny_cfg(nm)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = _tokens(cfg)
    loss, (_, logits) = loss_fn(cfg, params, toks[:, :-1], toks[:, 1:],
                                step=jnp.zeros((), jnp.int32),
                                with_logits=True)
    return float(loss), np.asarray(logits, np.float32)


def _serve_run(nm, *, n_slots=2):
    cfg = tiny_cfg(nm)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=n_slots, capacity=CAP,
                      record_logits=True)
    for p in PROMPTS:
        eng.submit(Request(prompt=p, max_new_tokens=3))
    return eng, eng.run()


# ------------------------------------------------------- uniform bit-parity
@pytest.mark.parametrize("nm", ALL_MODES, ids=lambda nm: nm.mode)
def test_uniform_policy_train_bit_identical_to_legacy(nm):
    """UniformPolicy(nm) and the bare AMRNumerics trace the SAME training
    computation: loss and float32 logits are bitwise equal."""
    loss_bare, logits_bare = _train_logits(nm)
    loss_pol, logits_pol = _train_logits(UniformPolicy(nm))
    assert loss_pol == loss_bare
    assert np.array_equal(logits_pol, logits_bare)


@pytest.mark.parametrize("nm", ALL_MODES, ids=lambda nm: nm.mode)
def test_uniform_policy_serve_bit_identical_to_legacy(nm):
    """Same engine, same requests: token streams AND recorded logits under
    UniformPolicy(nm) match the bare AMRNumerics bit for bit."""
    _, done_bare = _serve_run(nm)
    _, done_pol = _serve_run(UniformPolicy(nm))
    for b, p in zip(done_bare, done_pol):
        assert b.tokens == p.tokens
        for lb, lp in zip(b.logits, p.logits):
            assert float(np.max(np.abs(np.asarray(lb) - np.asarray(lp)))) == 0.0


# -------------------------------------------------------------- resolution
class TestResolution:
    NM_A = AMRNumerics("amr_lut", border=2)
    NM_B = AMRNumerics("amr_lowrank", border=3, rank=2)
    NM_C = AMRNumerics("amr_inject", border=4)

    def test_precedence_layer_site_over_layer_over_site(self):
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             layers={1: self.NM_A},
                             sites={"mlp.w_down": self.NM_B},
                             layer_sites={(1, "mlp.w_down"): self.NM_C})
        assert pol.resolve("mlp.w_down", 1) == self.NM_C   # (layer, site)
        assert pol.resolve("attn.wq", 1) == self.NM_A      # layer
        assert pol.resolve("mlp.w_down", 0) == self.NM_B   # site
        assert pol.resolve("attn.wq", 0) == pol.default    # default
        # outside the decoder stack: layer=None falls back to site/default
        assert pol.resolve("mlp.w_down", None) == self.NM_B
        assert pol.resolve(None, None) == pol.default

    def test_resolve_numerics_uses_ambient_static_layer(self):
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             layer_sites={(1, "attn.wq"): self.NM_A})
        with numerics_scope(static_layer=1):
            assert resolve_numerics(pol, "attn.wq") == self.NM_A
        with numerics_scope(static_layer=0):
            assert resolve_numerics(pol, "attn.wq") == pol.default
        # bare AMRNumerics passes through untouched
        assert resolve_numerics(self.NM_B, "attn.wq") is self.NM_B

    def test_model_audit_hits_exactly_the_assigned_coords(self):
        """Through the REAL model: an exact-compare audit records error mass
        only at the (site, layer) coordinates the policy approximates."""
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             layer_sites={(0, "mlp.w_down"): self.NM_C,
                                          (1, "attn.wq"): self.NM_C},
                             static_unroll=True)
        cfg = tiny_cfg(pol)
        params = init_params(cfg, jax.random.PRNGKey(0))
        trace = AuditTrace(compare="exact")
        with numerics_scope(audit=trace):
            logits, _ = forward(cfg, params, _tokens(cfg), None)
            jax.block_until_ready(logits)
            jax.effects_barrier()
        hit = {k for k, v in trace.coords.items() if v["calls"]}
        assert hit == {("mlp.w_down", 0), ("attn.wq", 1)}

    def test_dotted_prefix_site_match(self):
        """Sites resolve by dotted prefix: a "moe.expert" entry covers every
        moe.expert.* projection, an exact entry still wins."""
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             sites={"moe.expert": self.NM_A,
                                    "moe.expert.w_down": self.NM_B})
        assert pol.resolve("moe.expert.w_up", 0) == self.NM_A
        assert pol.resolve("moe.expert.w_gate", 0) == self.NM_A
        assert pol.resolve("moe.expert.w_down", 0) == self.NM_B  # exact wins
        assert pol.resolve("moe", 0) == pol.default  # prefixes never widen
        assert pol.resolve("attn.qk", 0) == pol.default

    def test_prefix_respects_level_precedence(self):
        """Prefix matching happens WITHIN each precedence level: a
        (layer, site) prefix still beats the layer map, and the layer map
        still beats a plain site prefix."""
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             layers={1: self.NM_A},
                             sites={"attn": self.NM_B},
                             layer_sites={(1, "attn.qk"): self.NM_C})
        assert pol.resolve("attn.qk", 1) == self.NM_C    # (layer, site)
        assert pol.resolve("attn.pv", 1) == self.NM_A    # layer beats prefix
        assert pol.resolve("attn.qk", 0) == self.NM_B    # site prefix
        assert pol.resolve("attn.pv", 0) == self.NM_B

    def test_prefix_in_layer_sites(self):
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             layer_sites={(0, "attn"): self.NM_A})
        assert pol.resolve("attn.qk", 0) == self.NM_A
        assert pol.resolve("attn.pv", 0) == self.NM_A
        assert pol.resolve("attn.qk", 1) == pol.default

    def test_model_audit_hits_activation_seam_coords(self):
        """The new activation×activation sites are policy-addressable
        through the REAL model: an exact-compare audit records error mass
        exactly at the assigned (attn.qk / attn.pv, layer) coordinates."""
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             layer_sites={(0, "attn.qk"): self.NM_C,
                                          (1, "attn.pv"): self.NM_C},
                             static_unroll=True)
        cfg = tiny_cfg(pol)
        params = init_params(cfg, jax.random.PRNGKey(0))
        trace = AuditTrace(compare="exact")
        with numerics_scope(audit=trace):
            logits, _ = forward(cfg, params, _tokens(cfg), None)
            jax.block_until_ready(logits)
            jax.effects_barrier()
        hit = {k for k, v in trace.coords.items() if v["calls"]}
        assert hit == {("attn.qk", 0), ("attn.pv", 1)}

    def test_validate_policy_checks_every_entry(self):
        validate_policy(PerLayerPolicy(default=AMRNumerics("exact"),
                                       layers={0: self.NM_A}))
        with pytest.raises(ValueError, match="border"):
            PerLayerPolicy(default=AMRNumerics("exact"),
                           layers={0: AMRNumerics("amr_lut", border=None)})

    def test_repeat_invariant_gates_the_scan(self):
        uni = PerLayerPolicy(default=self.NM_A, sites={"mlp.w_down": self.NM_B})
        assert uni.repeat_invariant(2, 3)  # site-keyed: same in every copy
        per = PerLayerPolicy(default=self.NM_A, layers={1: self.NM_B})
        assert not per.repeat_invariant(2, 3)  # group copies differ
        forced = PerLayerPolicy(default=self.NM_A, static_unroll=True)
        assert not forced.repeat_invariant(2, 3)


# ------------------------------------------------------------ JSON artifact
class TestJsonRoundTrip:
    def test_uniform_round_trip(self):
        pol = UniformPolicy(AMRNumerics("amr_lowrank", border=6, rank=4))
        assert policy_from_json(json.loads(json.dumps(policy_to_json(pol)))) == pol

    def test_per_layer_round_trip_with_schedule_ref(self):
        handle = injection.register_schedule(reduction.get_schedule(2, 6),
                                             name="test:policy-rt")
        pol = PerLayerPolicy(
            default=AMRNumerics("exact"),
            layers={1: AMRNumerics("amr_lut", border=2)},
            sites={"attn.wq": AMRNumerics("amr_lowrank", border=3, rank=2)},
            layer_sites={(0, "mlp.w_down"):
                         AMRNumerics("amr_inject", border=6,
                                     schedule_ref=handle)})
        again = policy_from_json(json.loads(json.dumps(policy_to_json(pol))))
        assert again == pol

    def test_save_load_preserves_meta_opaquely(self, tmp_path):
        pol = PerLayerPolicy(default=AMRNumerics("exact"),
                             layers={0: AMRNumerics("amr_lut", border=2)})
        path = tmp_path / "policy.json"
        save_policy(pol, path, meta={"energy": 1.5, "history": []})
        assert load_policy(path) == pol
        assert json.loads(path.read_text())["meta"]["energy"] == 1.5

    def test_unknown_kind_and_fields_rejected(self):
        with pytest.raises(ValueError, match="kind"):
            policy_from_json({"kind": "per_tensor"})
        with pytest.raises(ValueError, match="unknown AMRNumerics fields"):
            policy_from_json({"kind": "uniform",
                              "numerics": {"mode": "exact", "bits": 8}})

    def test_schedule_ref_reregistration_across_restart(self, tmp_path):
        """The restart story for searched policies: the JSON artifact names
        a schedule handle; after a process death the consumer's on_restore
        hook re-registers the schedule under the SAME handle and the policy
        resumes bit-identically (docs/numerics.md#policy-files)."""
        sched = reduction.get_schedule(2, 6)
        handle = injection.register_schedule(sched, name="test:policy-restart")
        pol = PerLayerPolicy(
            default=AMRNumerics("exact"),
            sites={"mlp.w_down": AMRNumerics("amr_inject", border=6,
                                             schedule_ref=handle)})
        cfg = tiny_cfg(pol)
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = _tokens(cfg)
        before = np.asarray(forward(cfg, params, toks, None)[0], np.float32)

        path = tmp_path / "policy.json"
        save_policy(pol, path)
        injection._SCHEDULES.pop(handle)  # the process "dies"

        def on_restore(state=None, step=None):
            # what FaultTolerantLoop(on_restore=...) runs in the new life
            injection.register_schedule(sched, name=handle)

        on_restore()
        loaded = load_policy(path)
        assert loaded == pol
        cfg2 = tiny_cfg(loaded)
        after = np.asarray(forward(cfg2, params, toks, None)[0], np.float32)
        assert np.array_equal(before, after)


# ----------------------------------------------------------------- serving
def test_serve_no_recompile_under_heterogeneous_policy():
    """A per-layer policy resolves at trace time INSIDE the single masked
    decode step — slots joining/finishing still never retrace."""
    pol = PerLayerPolicy(default=AMRNumerics("exact"),
                         layer_sites={(0, "mlp.w_down"):
                                      AMRNumerics("amr_lut", border=2)})
    eng, done = _serve_run(pol, n_slots=2)
    assert len(done) == len(PROMPTS)
    assert_single_trace(eng._decode, "masked decode step")


def test_serve_no_recompile_with_activation_seam_sites():
    """Heterogeneous policies touching the activation×activation sites
    (attn.qk via a dotted prefix, attn.pv per layer, ssm-site entries are
    inert for a dense config) resolve inside the one masked decode trace."""
    pol = PerLayerPolicy(default=AMRNumerics("exact"),
                         sites={"attn.qk": AMRNumerics("amr_lut", border=2),
                                "ssm.scan": AMRNumerics("amr_lut", border=2)},
                         layer_sites={(1, "attn.pv"):
                                      AMRNumerics("amr_inject", border=2)})
    eng, done = _serve_run(pol, n_slots=2)
    assert len(done) == len(PROMPTS)
    assert_single_trace(eng._decode, "masked decode step")


# ------------------------------------------------------------------ labels
def test_policy_labels():
    assert policy_label(UniformPolicy(AMRNumerics("amr_lut", border=8))) \
        == "amr_lut(b=8)"
    pol = PerLayerPolicy(default=AMRNumerics("exact"),
                         layers={0: AMRNumerics("amr_inject", border=5),
                                 1: AMRNumerics("amr_inject", border=7)},
                         sites={"attn.wq": AMRNumerics("amr_lut", border=6)})
    lbl = policy_label(pol)
    assert lbl == policy_summary(pol) == "perlayer[2l+1s: exact; inject b5-b7; lut b6]"
    assert as_policy(AMRNumerics("exact")) == UniformPolicy(AMRNumerics("exact"))

"""Sharding-rule tests: head-gating, divisibility guards, cache specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _markers import requires_modern_jax
from repro.configs import get_config
from repro.launch import specs as specs_lib
from repro.parallel import sharding as shard_lib


def _mesh_1x1(names=("data", "model")):
    return jax.make_mesh((1,) * len(names), names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(names))


class _FakeMesh:
    """Shape-only mesh stand-in so rule tests don't need 256 devices."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})
MESH_POD = _FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestParamRules:
    def test_qwen3_attention_tp(self):
        cfg = get_config("qwen3-32b")  # 64 q heads, 8 kv heads: both % 16 == 0
        params = specs_lib.abstract_params(cfg)
        specs = shard_lib.param_specs(MESH, params, cfg)
        leaf = specs["layers"][0]["attn"]
        assert leaf["wq"] == P(None, "data", "model")  # leading stack axis
        assert leaf["wk"][-1] is None  # kv=8 not divisible by 16 -> replicate
        assert leaf["wo"] == P(None, "model", "data")

    def test_mqa_head_gate_replicates(self):
        cfg = get_config("gemma-2b")  # 8 q heads, 1 kv head on model=16
        params = specs_lib.abstract_params(cfg)
        specs = shard_lib.param_specs(MESH, params, cfg)
        leaf = specs["layers"][0]["attn"]
        assert leaf["wq"][-1] is None   # heads don't divide -> no TP split
        assert leaf["wk"][-1] is None
        # FSDP still shards the d_model dim
        assert leaf["wq"][-2] == "data"

    def test_mlp_col_row(self):
        cfg = get_config("gemma-2b")
        params = specs_lib.abstract_params(cfg)
        specs = shard_lib.param_specs(MESH, params, cfg)
        leaf = specs["layers"][0]["mlp"]
        assert leaf["w_gate"] == P(None, "data", "model")
        assert leaf["w_down"] == P(None, "model", "data")

    def test_vocab_divisibility_guard(self):
        cfg = get_config("mamba2-370m")  # vocab 50280 % 16 != 0
        params = specs_lib.abstract_params(cfg)
        specs = shard_lib.param_specs(MESH, params, cfg)
        assert specs["embed"][0] is None      # vocab replicated
        assert specs["embed"][1] == "data"    # d_model FSDP

    def test_moe_expert_ffn(self):
        cfg = get_config("dbrx-132b")
        params = specs_lib.abstract_params(cfg)
        specs = shard_lib.param_specs(MESH, params, cfg)
        leaf = specs["layers"][0]["moe"]
        assert leaf["w_gate"] == P(None, None, "data", "model")
        assert leaf["w_down"] == P(None, None, "model", "data")

    def test_ssm_projections(self):
        cfg = get_config("mamba2-370m")  # 32 ssm heads % 16 == 0
        params = specs_lib.abstract_params(cfg)
        specs = shard_lib.param_specs(MESH, params, cfg)
        leaf = specs["layers"][0]["ssm"]
        assert leaf["wx"] == P(None, "data", "model")
        assert leaf["out_proj"] == P(None, "model", "data")
        assert leaf["wb"][-1] is None  # small B/C projections replicate on model

    def test_opt_state_mirrors_params(self):
        cfg = get_config("gemma-2b")
        state = specs_lib.abstract_train_state(cfg)
        specs = shard_lib.param_specs(MESH, state, cfg)
        assert (specs.params["layers"][0]["mlp"]["w_gate"]
                == specs.opt.mu["layers"][0]["mlp"]["w_gate"])


@requires_modern_jax
class TestBatchAndCache:
    def test_batch_spec_divisible(self):
        assert shard_lib.batch_partition_spec(MESH, 256, 2) == P(("data",), None)
        assert shard_lib.batch_partition_spec(MESH_POD, 256, 2) == P(("pod", "data"), None)

    def test_batch_spec_indivisible_replicates(self):
        assert shard_lib.batch_partition_spec(MESH, 1, 2) == P(None, None)

    def test_cache_specs(self):
        cfg = get_config("qwen3-32b")
        cache, _ = specs_lib.decode_specs(cfg, type("S", (), {
            "global_batch": 128, "seq_len": 1024, "kind": "decode"})())
        specs = shard_lib.cache_specs(MESH, cache, 128)
        kv_spec = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        assert kv_spec[1] == "data"  # batch dim


@requires_modern_jax
class TestConstraints:
    def test_pin_noop_without_mesh(self):
        from repro.parallel.constraints import pin
        x = jnp.ones((4, 4))
        y = pin(x, "batch", "tp")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_pin_applies_under_mesh(self):
        from repro.parallel.constraints import pin
        mesh = _mesh_1x1()
        with jax.set_mesh(mesh):
            def f(x):
                return pin(x, "batch", "tp")
            out = jax.jit(f)(jnp.ones((4, 4)))
        assert out.shape == (4, 4)


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ["gemma-2b", "dbrx-132b", "whisper-small",
                                      "internvl2-76b", "mamba2-370m"])
    def test_train_specs_shapes(self, arch):
        from repro.configs import SHAPES
        cfg = get_config(arch)
        spec = specs_lib.train_specs(cfg, SHAPES["train_4k"])
        total = spec["tokens"].shape[1] + (cfg.vision_prefix or 0)
        assert total == 4096
        assert spec["tokens"].shape[0] == 256

    def test_param_counts_sane(self):
        # dbrx ~132B total / ~36B active; internvl ~76B; qwen3 ~32B
        assert 1.2e11 < specs_lib.param_count(get_config("dbrx-132b")) < 1.5e11
        a = specs_lib.active_param_count(get_config("dbrx-132b"))
        assert 2.5e10 < a < 4.5e10
        assert 6.5e10 < specs_lib.param_count(get_config("internvl2-76b")) < 8.5e10
        assert 2.8e10 < specs_lib.param_count(get_config("qwen3-32b")) < 3.6e10

"""amr_inject: on-device error injection (engine.CompiledInjector + numerics).

The contract chain under test (docs/numerics.md):
  engine replay == 256x256 LUT == injected products == amr_lut matmul oracle
with the injected path additionally accepting RAW DSE candidate schedules
(no materialized LUT) end-to-end inside a jitted train_step.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import engine, lut, reduction  # noqa: E402
from repro.core.dse import lut_from_schedule, materialize, search_assignments  # noqa: E402
from repro.numerics import AMRNumerics, approx_matmul  # noqa: E402
from repro.numerics import injection  # noqa: E402
from repro.numerics.approx_matmul import matmul_amr_inject, matmul_amr_lut  # noqa: E402


class TestInjectorProducts:
    def test_products_match_lut_random_pairs(self):
        inj = engine.get_injector(2, 8)
        table = lut.build_int8_lut(8)
        rng = np.random.default_rng(0)
        ia = rng.integers(0, 256, 4096)
        ib = rng.integers(0, 256, 4096)
        got = np.asarray(jax.jit(inj.products)(jnp.asarray(ia), jnp.asarray(ib)))
        np.testing.assert_array_equal(got, table[ia, ib])

    def test_products_full_grid_equals_table(self):
        """Every int8 pair: the on-device replay IS the LUT, bit for bit."""
        inj = engine.get_injector(2, 6)
        table = lut.build_int8_lut(6)
        ia, ib = np.meshgrid(np.arange(256), np.arange(256), indexing="ij")
        got = np.asarray(jax.jit(inj.products)(
            jnp.asarray(ia.ravel()), jnp.asarray(ib.ravel())))
        np.testing.assert_array_equal(got.reshape(256, 256), table)

    def test_products_preserve_shape(self):
        inj = engine.get_injector(2, 8)
        ia = jnp.zeros((3, 5, 7), jnp.int32) + 130
        ib = jnp.zeros((3, 5, 7), jnp.int32) + 100
        assert inj.products(ia, ib).shape == (3, 5, 7)

    def test_products_shape_mismatch_raises(self):
        inj = engine.get_injector(2, 8)
        with pytest.raises(ValueError, match="shapes differ"):
            inj.products(jnp.zeros((4,), jnp.int32), jnp.zeros((5,), jnp.int32))

    def test_exact_schedule_products_are_exact(self):
        inj = engine.get_injector(2, None)  # border=None: exact multiplier
        rng = np.random.default_rng(1)
        a = rng.integers(-128, 128, 512)
        b = rng.integers(-128, 128, 512)
        got = np.asarray(inj.products(jnp.asarray(a + 128), jnp.asarray(b + 128)))
        np.testing.assert_array_equal(got, a * b)

    def test_wide_schedule_rejected(self):
        """int32 dynamic-range guard: 4-digit schedules cannot inject."""
        with pytest.raises(ValueError, match="int32"):
            engine.compile_injector(reduction.get_schedule(4, 18))

    def test_inject_products_entry_point(self):
        sched = reduction.get_schedule(2, 8)
        table = lut.build_int8_lut(8)
        got = np.asarray(engine.inject_products(
            sched, jnp.asarray([0, 255, 128]), jnp.asarray([255, 0, 128])))
        np.testing.assert_array_equal(got, table[[0, 255, 128], [255, 0, 128]])


class TestRegistryHandles:
    def test_anonymous_handle_never_clobbers_explicit(self):
        """Regression: ``custom:{len(_SCHEDULES)}`` could silently replace an
        earlier explicit ``custom:<n>`` registration."""
        sched = reduction.get_schedule(2, 8)
        n = injection._ANON_COUNTER
        explicit = injection.register_schedule(sched, name=f"custom:{n}")
        marker = reduction.get_schedule(2, 6)
        injection._SCHEDULES[explicit] = marker  # sentinel to detect clobber
        anon = injection.register_schedule(sched)
        assert anon != explicit
        assert injection._SCHEDULES[explicit] is marker  # untouched
        assert injection._SCHEDULES[anon] is sched

    def test_handles_monotonic_across_replacement(self):
        """Replacing a registration must not make later anonymous handles
        reuse an existing name."""
        sched = reduction.get_schedule(2, 8)
        a1 = injection.register_schedule(sched)
        injection.register_schedule(sched, name=a1)  # replace in place
        a2 = injection.register_schedule(sched)
        assert a2 != a1
        a3 = injection.register_schedule(sched)
        assert len({a1, a2, a3}) == 3


class _RecordingInjector:
    """Duck-typed CompiledInjector proxy recording peak replayed pairs."""

    def __init__(self, inj):
        self._inj = inj
        self.peak_pairs = 0

    def __getattr__(self, name):
        return getattr(self._inj, name)

    def products_outer(self, xm, yw):
        r, c, _ = xm.shape
        self.peak_pairs = max(self.peak_pairs, r * c * yw.shape[-1] * 32)
        return self._inj.products_outer(xm, yw)


class TestInjectedMatmulInt:
    def test_chunking_invariance(self):
        """Any max_pairs budget gives the identical int32 accumulation."""
        inj = engine.get_injector(2, 8)
        rng = np.random.default_rng(2)
        ia = jnp.asarray(rng.integers(0, 256, (6, 24)))
        ib = jnp.asarray(rng.integers(0, 256, (24, 10)))
        ref = injection.injected_matmul_int(inj, ia, ib)
        for max_pairs in (1, 60, 6 * 10 * 5, 1 << 18):
            got = injection.injected_matmul_int(inj, ia, ib, max_pairs=max_pairs)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_matches_lut_gather(self):
        inj = engine.get_injector(2, 8)
        table = lut.build_int8_lut(8)
        rng = np.random.default_rng(3)
        ia = rng.integers(0, 256, (2, 4, 13))  # K=13: prime, exercises kc search
        ib = rng.integers(0, 256, (13, 6))
        got = np.asarray(injection.injected_matmul_int(
            inj, jnp.asarray(ia), jnp.asarray(ib)))
        want = table[ia[..., :, :, None], ib[None, None, :, :]].sum(axis=-2)
        np.testing.assert_array_equal(got, want)

    def test_matches_pairwise_reference_path(self):
        """The outer-product refactor == the PR 4 pairwise replay, bitwise."""
        inj = engine.get_injector(2, 8)
        rng = np.random.default_rng(4)
        ia = jnp.asarray(rng.integers(0, 256, (5, 12)))
        ib = jnp.asarray(rng.integers(0, 256, (12, 9)))
        got = np.asarray(injection.injected_matmul_int(inj, ia, ib))
        want = np.asarray(injection._injected_matmul_pairs(inj, ia, ib))
        np.testing.assert_array_equal(got, want)

    def test_max_pairs_bounds_rows_too(self):
        """Regression: with rows * N > max_pairs and K=1, the PR 4 path
        clamped kc to 1 but still replayed rows * N pairs per step; row
        chunking must keep every step within the budget, bit-identically."""
        inj = engine.get_injector(2, 8)
        table = lut.build_int8_lut(8)
        rng = np.random.default_rng(5)
        ia = jnp.asarray(rng.integers(0, 256, (64, 1)))   # adversarial: M=64,
        ib = jnp.asarray(rng.integers(0, 256, (1, 32)))   # K=1, rows*N = 2048
        max_pairs = 256
        rec = _RecordingInjector(inj)
        got = np.asarray(injection.injected_matmul_int(
            rec, ia, ib, max_pairs=max_pairs))
        assert 0 < rec.peak_pairs <= max_pairs
        want = table[np.asarray(ia)[:, :, None], np.asarray(ib)[None]].sum(1)
        np.testing.assert_array_equal(got, want)

    def test_plan_chunks_budget(self):
        assert injection.plan_chunks(64, 1, 1, 256) == (8, 1)
        rc, kc = injection.plan_chunks(48, 24, 2, 1 << 18)
        assert rc == 48 and kc == 24          # whole problem inside budget
        assert injection.plan_chunks(7, 5, 3, 1) == (1, 1)  # floor case
        for rows, k, w, cap in [(96, 13, 2, 2048), (33, 7, 5, 640)]:
            rc, kc = injection.plan_chunks(rows, k, w, cap)
            assert rows % rc == 0 and k % kc == 0
            assert rc * kc * w * 32 <= max(cap, w * 32)

    def test_saturation_guard_names_both_numbers(self):
        inj = engine.get_injector(2, 8)
        k_bad = 2**31 // inj.max_abs_product + 1
        ia = jnp.zeros((1, k_bad), jnp.int32)
        ib = jnp.zeros((k_bad, 1), jnp.int32)
        for fn in (injection.injected_matmul_int,
                   injection._injected_matmul_pairs):
            with pytest.raises(ValueError, match="saturate") as ei:
                fn(inj, ia, ib)
            assert str(k_bad) in str(ei.value)
            assert str(inj.max_abs_product) in str(ei.value)
        # safe K traces fine
        injection.check_accumulation_bound(inj, 4096)


class TestMatmulAmrInject:
    def setup_method(self):
        self.a = jax.random.normal(jax.random.PRNGKey(0), (4, 16), jnp.float32)
        self.b = jax.random.normal(jax.random.PRNGKey(1), (16, 8), jnp.float32)

    def test_bit_identical_to_lut_oracle(self):
        for border in (4, 8):
            want = np.asarray(matmul_amr_lut(self.a, self.b, border=border))
            got = np.asarray(approx_matmul(
                self.a, self.b, AMRNumerics("amr_inject", border=border)))
            np.testing.assert_array_equal(got, want)  # same ints, same floats

    def test_bit_identical_under_jit_and_batch(self):
        nm = AMRNumerics("amr_inject", border=8)
        a3 = jnp.stack([self.a, self.a * 0.5])
        got = np.asarray(jax.jit(
            lambda a, b: approx_matmul(a, b, nm))(a3, self.b))
        want = np.stack([np.asarray(matmul_amr_lut(self.a, self.b, 8)),
                         np.asarray(matmul_amr_lut(self.a * 0.5, self.b, 8))])
        np.testing.assert_array_equal(got, want)

    def test_lut_oracle_saturation_guard(self):
        """matmul_amr_lut rejects K that could wrap its int32 accumulation,
        naming K and max|product| — the same bound the injected path checks."""
        from repro.core import lut as lut_lib

        max_abs = lut_lib.table_max_abs(8)
        k_bad = 2**31 // max_abs + 1
        a = jnp.zeros((1, k_bad), jnp.float32)
        b = jnp.zeros((k_bad, 1), jnp.float32)
        with pytest.raises(ValueError, match="saturate") as ei:
            matmul_amr_lut(a, b, border=8)
        assert str(k_bad) in str(ei.value) and str(max_abs) in str(ei.value)

    def test_grad_matches_full_precision_surrogate(self):
        """STE backward == plain matmul vjp (finite, correct shapes)."""
        nm = AMRNumerics("amr_inject", border=8)
        ga, gb = jax.grad(
            lambda a, b: matmul_amr_inject(a, b, nm).sum(), argnums=(0, 1)
        )(self.a, self.b)
        ones = np.ones((4, 8), np.float32)
        np.testing.assert_allclose(np.asarray(ga), ones @ np.asarray(self.b).T,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(self.a).T @ ones,
                                   rtol=1e-5)
        assert np.isfinite(np.asarray(ga)).all() and np.isfinite(np.asarray(gb)).all()


class TestDSECandidateInjection:
    def _candidate_schedule(self):
        # Whole-multiplier search: candidate 0 is the joint optimum, which
        # generally differs from the greedy default schedule's assignment.
        cands = search_assignments(2, 8, k=2, beam_width=8, branch_cap=4,
                                   max_nodes=2000)
        return materialize(cands[0]), cands[0]

    def test_candidate_injection_matches_its_lut_export(self):
        sched, _ = self._candidate_schedule()
        handle = injection.register_schedule(sched, name="test:dse-cand")
        nm = AMRNumerics("amr_inject", border=8, schedule_ref=handle)
        a = jax.random.normal(jax.random.PRNGKey(2), (4, 16), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(3), (16, 8), jnp.float32)
        got = np.asarray(approx_matmul(a, b, nm))

        # reference: quantize the same way, gather from the candidate's
        # exported 256x256 table (dse.export round-trip), accumulate int32
        table = lut_from_schedule(sched)
        from repro.numerics.quant import quantize_int8
        qa, sa = quantize_int8(a, axis=-1)
        qb, sb = quantize_int8(b, axis=0)
        ia = np.asarray(qa, np.int64) + 128
        ib = np.asarray(qb, np.int64) + 128
        acc = table[ia[:, :, None], ib[None, :, :]].sum(axis=-2).astype(np.float32)
        want = acc * np.asarray(sa) * np.asarray(sb)
        np.testing.assert_array_equal(got, want)

    def test_candidate_trains_end_to_end_in_jitted_step(self):
        """A raw DSE candidate Schedule (no pre-built LUT) drops straight
        into train_step — the acceptance criterion of the inject tentpole."""
        from repro.configs.base import ModelConfig
        from repro.data import SyntheticLM
        from repro.train.steps import make_train_state, make_train_step

        sched, assignment = self._candidate_schedule()
        handle = injection.register_schedule(sched, name="test:dse-train")
        cfg = ModelConfig(
            name="tiny-inject", family="dense", n_layers=1, d_model=32,
            n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
            mlp_act="swiglu", tie_embeddings=True, remat="none",
            numerics=AMRNumerics("amr_inject", border=8, schedule_ref=handle))
        data = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=2, seed=0)
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup=2, total_steps=4))
        for i in range(2):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, m = step(state, b)
            assert np.isfinite(float(m["loss"])), m
        assert int(state.step) == 2

    def test_register_rejects_non_int8_schedules(self):
        with pytest.raises(ValueError, match="2-digit"):
            injection.register_schedule(reduction.get_schedule(3, 12))

    def test_unregistered_handle_raises(self):
        nm = AMRNumerics("amr_inject", border=8, schedule_ref="test:missing")
        with pytest.raises(KeyError, match="register_schedule"):
            injection.resolve_schedule(nm)

    def test_default_policy_needs_no_registration(self):
        nm = AMRNumerics("amr_inject", border=6)
        sched = injection.resolve_schedule(nm)
        assert sched is reduction.get_schedule(2, 6)


class TestPolicyHashability:
    def test_numerics_with_schedule_ref_is_hashable(self):
        """The policy stays a valid static jit argument with a schedule ref."""
        nm = AMRNumerics("amr_inject", border=8, schedule_ref="x")
        assert hash(nm) == hash(dataclasses.replace(nm))
        assert nm != AMRNumerics("amr_inject", border=8)

"""Shared pytest config: fast hypothesis profile, CPU-only JAX, 1 device.

NOTE: XLA_FLAGS multi-device forcing is intentionally NOT set here — only
launch/dryrun.py uses 512 placeholder devices (see system design). Smoke
tests and benches must see the single real CPU device.

``hypothesis`` is optional: without it the property tests skip (via the
tests/_hyp.py shim) and the rest of the suite still collects and runs —
CI exercises that configuration on purpose.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:  # property tests skip through tests/_hyp.py
    pass
else:
    settings.register_profile(
        "fast",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("fast")

"""Shared pytest config: fast hypothesis profile, CPU-only JAX, 1 device.

NOTE: XLA_FLAGS multi-device forcing is intentionally NOT set here — only
launch/dryrun.py uses 512 placeholder devices (see system design). Smoke
tests and benches must see the single real CPU device.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import HealthCheck, settings

settings.register_profile(
    "fast",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("fast")

"""Numerics mode registry: dispatch table, construction-time validation,
CLI derivation. The registry is the single source of truth for mode names
— MODES, argparse choices and policy validation all derive from it."""
import argparse

import pytest

from repro.numerics import (AMRNumerics, MODES, get_mode, mode_names,
                            register_mode)
from repro.numerics.registry import unregister_mode

CANONICAL = ("exact", "amr_lut", "amr_inject", "amr_lowrank", "amr_noise",
             "amr_kernel")


class TestModeNames:
    def test_canonical_modes_registered_in_order(self):
        assert mode_names() == CANONICAL

    def test_modules_modes_attr_is_live_view(self):
        # both repro.numerics.MODES and approx_matmul.MODES derive from the
        # registry (PEP 562), never a snapshot (the package also exports the
        # approx_matmul FUNCTION, so fetch the module via importlib)
        import importlib

        am = importlib.import_module("repro.numerics.approx_matmul")
        assert MODES == mode_names()
        assert am.MODES == mode_names()

    def test_get_mode_returns_spec_with_impl(self):
        spec = get_mode("amr_lut")
        assert spec.name == "amr_lut"
        assert callable(spec.impl)
        assert "border" in spec.required_params

    def test_unknown_mode_error_names_valid_modes(self):
        with pytest.raises(ValueError) as ei:
            get_mode("bogus")
        msg = str(ei.value)
        assert "bogus" in msg
        for name in CANONICAL:
            assert name in msg


class TestPolicyValidation:
    def test_unknown_mode_fails_at_construction(self):
        with pytest.raises(ValueError, match="valid modes"):
            AMRNumerics("not_a_mode")

    def test_negative_border_rejected(self):
        with pytest.raises(ValueError, match="border"):
            AMRNumerics("amr_lut", border=-1)

    def test_lowrank_requires_positive_rank(self):
        with pytest.raises(ValueError, match="rank"):
            AMRNumerics("amr_lowrank", border=4, rank=0)

    def test_kernel_rank_zero_is_full_lut_variant(self):
        # rank=0 selects the bit-exact full-LUT kernel — valid for amr_kernel
        assert AMRNumerics("amr_kernel", border=4, rank=0).rank == 0

    def test_bad_inject_impl_rejected(self):
        with pytest.raises(ValueError, match="inject_impl"):
            AMRNumerics("amr_inject", border=4, inject_impl="nope")

    def test_valid_policies_construct(self):
        for mode in CANONICAL:
            AMRNumerics(mode, border=4, rank=2)

    def test_is_exact(self):
        assert AMRNumerics("exact").is_exact()
        assert not AMRNumerics("amr_lut", border=4).is_exact()


class TestCustomRegistration:
    def test_register_unregister_roundtrip(self):
        def impl(a, b, nm, *, key=None, site=None):
            return a @ b

        register_mode("test_custom", impl, required_params=("border",),
                      description="test-only mode")
        try:
            assert "test_custom" in mode_names()
            assert AMRNumerics("test_custom", border=1).mode == "test_custom"
            with pytest.raises(ValueError):
                register_mode("test_custom", impl)  # duplicates rejected
        finally:
            unregister_mode("test_custom")
        assert "test_custom" not in mode_names()
        with pytest.raises(ValueError):
            AMRNumerics("test_custom")

    def test_custom_mode_dispatches_through_approx_matmul(self):
        import jax.numpy as jnp

        from repro.numerics import approx_matmul

        def impl(a, b, nm, *, key=None, site=None):
            return jnp.zeros(a.shape[:-1] + (b.shape[-1],), jnp.float32)

        register_mode("test_zero", impl)
        try:
            nm = AMRNumerics("test_zero")
            out = approx_matmul(jnp.ones((2, 3)), jnp.ones((3, 4)), nm)
            assert float(abs(out).max()) == 0.0
        finally:
            unregister_mode("test_zero")


class TestCLIDerivation:
    def test_argparse_choices_derive_from_registry(self):
        from repro.launch.cli import add_numerics_args

        ap = argparse.ArgumentParser()
        add_numerics_args(ap)
        action = next(a for a in ap._actions if a.dest == "numerics")
        assert tuple(action.choices) == mode_names()

    def test_numerics_from_args_builds_policy(self):
        from repro.launch.cli import add_numerics_args, numerics_from_args

        ap = argparse.ArgumentParser()
        add_numerics_args(ap)
        args = ap.parse_args(["--numerics", "amr_lowrank", "--border", "4",
                              "--rank", "2"])
        nm = numerics_from_args(args)
        assert nm == AMRNumerics("amr_lowrank", border=4, rank=2)

    def test_numerics_from_args_none_keeps_config_policy(self):
        from repro.launch.cli import add_numerics_args, numerics_from_args

        ap = argparse.ArgumentParser()
        add_numerics_args(ap)
        assert numerics_from_args(ap.parse_args([])) is None

    def test_multi_mode_parse_and_labels(self):
        from repro.launch.cli import (add_numerics_args, numerics_from_args,
                                      parse_modes, policy_label)

        ap = argparse.ArgumentParser()
        add_numerics_args(ap, multi=True, default="exact,amr_lowrank",
                          rank_default=16)
        args = ap.parse_args(["--border", "8"])
        modes = parse_modes(args)
        assert modes == ["exact", "amr_lowrank"]
        labels = [policy_label(numerics_from_args(args, mode=m)) for m in modes]
        assert labels == ["exact", "amr_lowrank(b=8,r=16)"]

    def test_multi_mode_unknown_raises_with_valid_names(self):
        from repro.launch.cli import add_numerics_args, numerics_from_args

        ap = argparse.ArgumentParser()
        add_numerics_args(ap, multi=True)
        args = ap.parse_args(["--modes", "exact,bogus"])
        with pytest.raises(ValueError, match="valid modes"):
            for m in ["exact", "bogus"]:
                numerics_from_args(args, mode=m)

"""LUT layer + numerics-policy tests (DESIGN.md L1/L2)."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import lut  # noqa: E402
from repro.numerics import AMRNumerics, approx_matmul, dequantize, quantize_int8  # noqa: E402
from repro.numerics.approx_matmul import (  # noqa: E402
    matmul_amr_lowrank, matmul_amr_lut,
)


class TestLUT:
    def test_exact_border_lut_is_exact(self):
        assert np.array_equal(lut.build_int8_lut(None), lut.exact_int8_table())

    def test_lut_matches_bitaccurate_spot(self):
        from repro.core.amrmul import AMRMultiplier
        m = AMRMultiplier(2, border=8)
        table = lut.build_int8_lut(8)
        rng = np.random.default_rng(0)
        a = rng.integers(-128, 128, 100)
        b = rng.integers(-128, 128, 100)
        want = m.multiply_values(a, b)
        got = table[a + 128, b + 128]
        np.testing.assert_array_equal(got, want.astype(np.int64))

    def test_rank256_exact(self):
        f = lut.lowrank_factor(8, 256)
        assert f.residual_fro < 1e-6  # float32 factors
        err = lut.build_int8_lut(8).astype(np.float64) - lut.exact_int8_table()
        np.testing.assert_allclose(f.reconstruct(), err, atol=1e-2)

    def test_residual_monotone_in_rank(self):
        r = [lut.lowrank_factor(8, k).residual_fro for k in (4, 16, 64)]
        assert r[0] > r[1] > r[2]

    def test_multi_border_batch_equals_per_border(self):
        """build_int8_luts (one fused engine call) == per-border builds."""
        tables = lut.build_int8_luts((None, 4, 8))
        for b in (None, 4, 8):
            np.testing.assert_array_equal(tables[b], lut.build_int8_lut(b))
            np.testing.assert_array_equal(
                tables[b], lut.build_int8_lut(b, engine="numpy"))

    def test_lut_record_provenance(self):
        rec = lut.lut_record(8)
        assert (rec.n_digits, rec.border, rec.engine) == (2, 8, "jax")
        assert rec.table.shape == (256, 256) and rec.table.dtype == np.int32
        assert lut.lut_record(8, engine="numpy").engine == "numpy"

    def test_build_rejects_unknown_engine(self):
        with pytest.raises(ValueError):
            lut.build_int8_luts((8,), engine="torch")

    def test_cached_arrays_are_concrete_and_shared(self):
        t1 = lut.table_array(8)
        t2 = lut.table_array(8)
        assert t1 is t2  # one process-level conversion, no per-call rebuild
        u1, v1 = lut.factor_arrays(8, 8)
        u2, _ = lut.factor_arrays(8, 8)
        assert u1 is u2
        np.testing.assert_array_equal(np.asarray(t1), lut.build_int8_lut(8))


class TestQuant:
    def test_roundtrip_small_error(self):
        x = jnp.linspace(-3.0, 3.0, 64).reshape(8, 8)
        q, s = quantize_int8(x)
        back = dequantize(q, s)
        assert float(jnp.abs(back - x).max()) < 3.0 / 127 + 1e-6

    def test_per_axis_scales(self):
        x = jnp.array([[1.0, 100.0], [0.01, 1.0]])
        q, s = quantize_int8(x, axis=0)
        assert s.shape == (1, 2)


class TestApproxMatmul:
    def setup_method(self):
        k = jax.random.PRNGKey(0)
        self.a = jax.random.normal(k, (4, 16), dtype=jnp.float32)
        self.b = jax.random.normal(jax.random.PRNGKey(1), (16, 8), dtype=jnp.float32)

    def test_exact_mode(self):
        out = approx_matmul(self.a, self.b, AMRNumerics("exact"))
        np.testing.assert_allclose(out, self.a @ self.b, rtol=1e-5)

    def test_lut_mode_close_to_exact(self):
        out = approx_matmul(self.a, self.b, AMRNumerics("amr_lut", border=6))
        want = np.asarray(self.a @ self.b)
        rel = np.abs(np.asarray(out) - want) / (np.abs(want) + 1e-3)
        assert np.median(rel) < 0.2

    def test_lowrank_rank256_matches_lut(self):
        """rank-256 low-rank ~= bit-exact LUT path.

        The jnp training path stores error lanes in bf16 (§Perf cell P i3),
        so agreement is to bf16 precision of the *correction term*; the
        Pallas kernel keeps f32 lanes and stays bit-exact at rank 256
        (tests/test_kernels.py::test_rank256_bitexact)."""
        lut_out = np.asarray(matmul_amr_lut(self.a, self.b, border=8))
        lr_out = np.asarray(matmul_amr_lowrank(self.a, self.b, border=8, rank=256))
        scale = np.abs(lut_out).mean() + 1e-6
        assert np.abs(lr_out - lut_out).mean() / scale < 0.02

    def test_lowrank_fidelity_improves_with_rank(self):
        lut_out = np.asarray(matmul_amr_lut(self.a, self.b, border=8))
        errs = []
        for r in (4, 32, 128):
            lr = np.asarray(matmul_amr_lowrank(self.a, self.b, border=8, rank=r))
            errs.append(np.abs(lr - lut_out).mean())
        assert errs[0] > errs[2]

    def test_noise_mode_runs_and_unbiased_scale(self):
        out = approx_matmul(self.a, self.b, AMRNumerics("amr_noise", border=8),
                            key=jax.random.PRNGKey(7))
        assert out.shape == (4, 8)
        assert np.isfinite(np.asarray(out)).all()

    def test_inject_mode_dispatch(self):
        got = np.asarray(approx_matmul(self.a, self.b,
                                       AMRNumerics("amr_inject", border=8)))
        want = np.asarray(matmul_amr_lut(self.a, self.b, border=8))
        np.testing.assert_array_equal(got, want)

    def test_batched_lhs(self):
        a3 = jnp.stack([self.a, self.a * 0.5])
        out = approx_matmul(a3, self.b, AMRNumerics("amr_lowrank", border=8, rank=8))
        assert out.shape == (2, 4, 8)

    def test_jit_compatible(self):
        f = jax.jit(lambda a, b: approx_matmul(a, b, AMRNumerics("amr_lowrank", border=8, rank=8)))
        out = f(self.a, self.b)
        assert out.shape == (4, 8)

    def test_kernel_mode_matches_lowrank(self):
        """amr_kernel (Pallas, interpret on CPU) ~= the jnp lowrank path.

        The kernel keeps f32 error lanes where the jnp training path uses
        bf16, so agreement is to bf16 precision of the correction term."""
        got = np.asarray(approx_matmul(self.a, self.b,
                                       AMRNumerics("amr_kernel", border=8, rank=8)))
        want = np.asarray(approx_matmul(self.a, self.b,
                                        AMRNumerics("amr_lowrank", border=8, rank=8)))
        scale = np.abs(want).mean() + 1e-6
        assert np.abs(got - want).mean() / scale < 0.02

    def test_kernel_mode_rank0_is_full_lut(self):
        """rank=0 selects the bit-exact full-table kernel == amr_lut gather."""
        got = np.asarray(approx_matmul(self.a, self.b,
                                       AMRNumerics("amr_kernel", border=8, rank=0)))
        want = np.asarray(matmul_amr_lut(self.a, self.b, border=8))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_kernel_mode_batched_and_grad(self):
        a3 = jnp.stack([self.a, self.a * 0.5])
        out = approx_matmul(a3, self.b, AMRNumerics("amr_kernel", border=8, rank=8))
        assert out.shape == (2, 4, 8)
        g = jax.grad(lambda a, b: approx_matmul(
            a, b, AMRNumerics("amr_kernel", border=8, rank=8)).sum())(self.a, self.b)
        assert g.shape == self.a.shape  # STE surrogate: plain matmul vjp
        assert np.isfinite(np.asarray(g)).all()


class TestNoisePRNGDecorrelation:
    """Regression: amr_noise must NOT draw the identical tensor at every
    call site / layer / step (the old key=PRNGKey(noise_seed) bug)."""

    def setup_method(self):
        self.a = jax.random.normal(jax.random.PRNGKey(0), (4, 16), jnp.float32)
        self.b = jax.random.normal(jax.random.PRNGKey(1), (16, 8), jnp.float32)
        self.nm = AMRNumerics("amr_noise", border=8)

    def _mm(self, **kw):
        from repro.numerics import numerics_scope
        scope_kw = {k: kw.pop(k) for k in ("step", "layer") if k in kw}
        with numerics_scope(**scope_kw):
            return np.asarray(approx_matmul(self.a, self.b, self.nm, **kw))

    def test_same_coordinates_reproduce(self):
        np.testing.assert_array_equal(self._mm(site="s", step=3, layer=1),
                                      self._mm(site="s", step=3, layer=1))

    def test_two_call_sites_differ(self):
        assert not np.array_equal(self._mm(site="mlp.w_gate"),
                                  self._mm(site="mlp.w_up"))

    def test_two_layers_differ(self):
        assert not np.array_equal(self._mm(site="s", layer=0),
                                  self._mm(site="s", layer=1))

    def test_two_steps_differ(self):
        assert not np.array_equal(self._mm(site="s", step=0),
                                  self._mm(site="s", step=1))

    def test_explicit_key_still_wins(self):
        k = jax.random.PRNGKey(7)
        from repro.numerics import numerics_scope
        with numerics_scope(step=jnp.int32(0)):
            o1 = np.asarray(approx_matmul(self.a, self.b, self.nm, key=k))
        with numerics_scope(step=jnp.int32(1)):
            o2 = np.asarray(approx_matmul(self.a, self.b, self.nm, key=k))
        np.testing.assert_array_equal(o1, o2)

    def test_model_layers_see_distinct_noise(self, monkeypatch):
        """Two stacked layers draw different noise; forcing the layer scope
        to a no-op collapses them back (proves the model threads indices)."""
        import contextlib

        from repro.configs.base import ModelConfig
        from repro.models import forward, init_params
        from repro.models import model as model_mod

        cfg = ModelConfig(
            name="tiny-noise", family="dense", n_layers=2, d_model=32,
            n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
            mlp_act="swiglu", tie_embeddings=True, remat="none",
            numerics=self.nm)
        params = init_params(cfg, jax.random.PRNGKey(0))
        tokens = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 8)),
                             jnp.int32)
        ref = np.asarray(forward(cfg, params, tokens)[0], np.float32)
        rep = np.asarray(forward(cfg, params, tokens)[0], np.float32)
        np.testing.assert_array_equal(ref, rep)  # deterministic given scope

        monkeypatch.setattr(model_mod, "numerics_scope",
                            lambda **kw: contextlib.nullcontext())
        collapsed = np.asarray(forward(cfg, params, tokens)[0], np.float32)
        assert not np.array_equal(ref, collapsed)

    def test_decode_positions_decorrelate(self, monkeypatch):
        """The decode path folds the KV-cache position into the PRNG scope:
        the old bug drew identical noise at every generated token.  Decode is
        deterministic given a cache state, and successive steps see an
        advancing position (a distinct noise stream per token)."""
        from repro.configs.base import ModelConfig
        from repro.models import decode_step, init_cache, init_params
        from repro.models import model as model_mod
        from repro.models.model import _cache_position

        cfg = ModelConfig(
            name="tiny-noise3", family="dense", n_layers=1, d_model=32,
            n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
            mlp_act="swiglu", tie_embeddings=True, remat="none",
            numerics=self.nm)
        params = init_params(cfg, jax.random.PRNGKey(0))
        cache0 = init_cache(cfg, batch=1, capacity=8)
        assert int(_cache_position(cache0)) == 0
        tok = jnp.zeros((1, 1), jnp.int32)
        lg_a, cache1 = decode_step(cfg, params, tok, cache0)
        lg_b, _ = decode_step(cfg, params, tok, cache0)  # replay: deterministic
        np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))
        assert int(_cache_position(cache1)) == 1  # next step folds a new pos

        # record what decode actually folds into the scope per step
        seen = []
        real_scope = model_mod.numerics_scope

        def spy_scope(**kw):
            seen.append(kw.get("step"))
            return real_scope(**kw)

        monkeypatch.setattr(model_mod, "numerics_scope", spy_scope)
        _, cache2 = decode_step(cfg, params, tok, cache1)
        assert [int(s) for s in seen if s is not None] == [1]

    def test_loss_fn_steps_decorrelate(self):
        """Same params + batch, different step -> different noisy loss."""
        from repro.configs.base import ModelConfig
        from repro.models import init_params
        from repro.train.steps import loss_fn

        cfg = ModelConfig(
            name="tiny-noise2", family="dense", n_layers=1, d_model=32,
            n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
            mlp_act="swiglu", tie_embeddings=True, remat="none",
            numerics=self.nm)
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32)
        targets = jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32)
        l0 = float(loss_fn(cfg, params, tokens, targets, step=jnp.int32(0))[0])
        l0b = float(loss_fn(cfg, params, tokens, targets, step=jnp.int32(0))[0])
        l1 = float(loss_fn(cfg, params, tokens, targets, step=jnp.int32(1))[0])
        assert l0 == l0b
        assert l0 != l1

"""Property + unit tests for the MRSD number system."""
import numpy as np
import pytest

from _hyp import given, st
from repro.core import mrsd


class TestEncodeDecode:
    @given(st.integers(min_value=-272, max_value=255))
    def test_roundtrip_2digit(self, x):
        d = mrsd.encode(x, 2)
        assert mrsd.decode_int(d) == x
        assert np.all(d >= mrsd.DIGIT_MIN) and np.all(d <= mrsd.DIGIT_MAX)

    @given(st.integers(min_value=1, max_value=6), st.data())
    def test_roundtrip_any_width(self, n, data):
        x = data.draw(st.integers(mrsd.min_value(n), mrsd.max_value(n)))
        assert mrsd.decode_int(mrsd.encode(x, n)) == x

    def test_range_matches_paper(self):
        # paper §IV.B: 2-digit MRSD dynamic range is [-272, 255]
        assert mrsd.min_value(2) == -272
        assert mrsd.max_value(2) == 255

    def test_out_of_range_raises(self):
        with pytest.raises(ValueError):
            mrsd.encode(256, 2)
        with pytest.raises(ValueError):
            mrsd.encode(-273, 2)

    def test_vectorized_encode(self):
        xs = np.arange(-272, 256)
        d = mrsd.encode(xs, 2)
        vals = mrsd.decode(d)
        np.testing.assert_array_equal(vals, xs.astype(np.float64))


class TestBits:
    @given(st.integers(min_value=-16, max_value=15))
    def test_single_digit_bits(self, v):
        pos, neg = mrsd.digits_to_bits(np.array([v]))
        # value = sum posibits*2^i + (stored_negabit - 1)*16
        val = sum(int(pos[i]) << i for i in range(4)) + (int(neg[0]) - 1) * 16
        assert val == v

    @given(st.lists(st.integers(-16, 15), min_size=1, max_size=8))
    def test_bits_value_matches_decode(self, digits):
        d = np.array(digits)
        pos, neg = mrsd.digits_to_bits(d)
        assert mrsd.bits_value(pos, neg) == pytest.approx(float(mrsd.decode_int(d)))

    @given(st.lists(st.integers(-16, 15), min_size=1, max_size=8))
    def test_bits_digits_roundtrip(self, digits):
        d = np.array(digits)
        pos, neg = mrsd.digits_to_bits(d)
        np.testing.assert_array_equal(mrsd.bits_to_digits(pos, neg), d)

    def test_batch_shapes(self):
        rng = np.random.default_rng(0)
        d = mrsd.random_digits(rng, 4, 10)
        pos, neg = mrsd.digits_to_bits(d)
        assert pos.shape == (10, 16) and neg.shape == (10, 4)

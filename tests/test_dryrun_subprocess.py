"""Dry-run machinery integration test (subprocess: needs 512 fake devices,
while the test process itself must keep the single real CPU device)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from _markers import requires_modern_jax

REPO = Path(__file__).resolve().parents[1]

# The dryrun subprocess needs the same modern-jax mesh APIs.
pytestmark = requires_modern_jax


@pytest.mark.slow
def test_dryrun_cell_end_to_end(tmp_path):
    """Lower+compile one cheap cell on the 16x16 mesh; artifact is complete."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "long_500k",
         "--mesh", "single", "--out", str(tmp_path)],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=560, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.loads((tmp_path / "mamba2-370m__long_500k__single.json").read_text())
    assert rec["status"] == "ok"
    assert rec["fits"] is True
    assert rec["cost"]["flops"] > 0
    assert rec["collectives"]["total_bytes"] >= 0
    assert rec["memory"]["peak_bytes"] < 16 * 2**30


@pytest.mark.slow
def test_dryrun_skip_policy(tmp_path):
    """long_500k on a pure full-attention arch records a documented skip."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-32b", "--shape", "long_500k",
         "--mesh", "single", "--out", str(tmp_path)],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=300, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.loads((tmp_path / "qwen3-32b__long_500k__single.json").read_text())
    assert rec["status"] == "skipped"
    assert "full-attention" in rec["reason"]

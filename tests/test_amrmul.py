"""End-to-end multiplier tests: exactness, approximation trends, Fig. 5 usage."""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import amrmul, mrsd


@pytest.fixture(scope="module")
def exact2():
    return amrmul.AMRMultiplier(2, border=None)


class TestExactMultiplier:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(-272, 255), st.integers(-272, 255))
    def test_exact_2digit_values(self, x, y):
        m = amrmul.exact_multiplier(2)
        prod = m.multiply_values(np.array([x]), np.array([y]))
        assert prod[0] == float(x * y)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 5), st.data())
    def test_exact_any_width_random_digits(self, n, data):
        m = amrmul.exact_multiplier(n)
        digs = st.lists(st.integers(-16, 15), min_size=n, max_size=n)
        xd = np.array([data.draw(digs)])
        yd = np.array([data.draw(digs)])
        lo, hi = m.multiply_digits_split(xd, yd)
        got = int(lo[0]) + (int(hi[0]) << 32)
        assert got == mrsd.decode_int(xd[0]) * mrsd.decode_int(yd[0])

    def test_exact_8digit_batch(self):
        m = amrmul.exact_multiplier(8)
        rng = np.random.default_rng(3)
        xd = mrsd.random_digits(rng, 8, 64)
        yd = mrsd.random_digits(rng, 8, 64)
        lo, hi = m.multiply_digits_split(xd, yd)
        for i in range(64):
            expect = mrsd.decode_int(xd[i]) * mrsd.decode_int(yd[i])
            assert int(lo[i]) + (int(hi[i]) << 32) == expect

    def test_no_approx_cells_in_exact_design(self):
        m = amrmul.exact_multiplier(4)
        assert all(k in ("FA", "HA") for k in m.cell_counts)


class TestApproximateMultiplier:
    def test_monotonic_mared_in_border(self):
        """Table I: widening the approximate part degrades accuracy."""
        mareds = []
        for b in (6, 8, 10):
            m = amrmul.AMRMultiplier(2, border=b)
            mareds.append(m.monte_carlo(20000, seed=7)["mared"])
        assert mareds[0] < mareds[1] < mareds[2]

    def test_wider_multiplier_more_accurate(self):
        """Table I discussion: more rows -> better compensation opportunity.

        Compare at equivalent relative border position (b/columns)."""
        m2 = amrmul.AMRMultiplier(2, border=8).monte_carlo(20000, seed=1)
        m4 = amrmul.AMRMultiplier(4, border=16).monte_carlo(20000, seed=1)
        assert m4["mared"] < m2["mared"]

    def test_error_distribution_near_zero_mean(self):
        """Fig. 6: relative error distribution is ~Gaussian with mu ~= 0:
        |MRED| << MARED."""
        m = amrmul.AMRMultiplier(2, border=8)
        r = m.monte_carlo(50000, seed=2)
        assert abs(r["mred"]) < 0.3 * r["mared"]

    def test_exact_region_untouched(self):
        """Products with no bits below the border are exact.

        Single-digit operands only occupy low columns — instead check that a
        border beyond the last column reproduces the exact multiplier."""
        m = amrmul.AMRMultiplier(2, border=0)  # approximate part empty
        rng = np.random.default_rng(0)
        xd = mrsd.random_digits(rng, 2, 512)
        yd = mrsd.random_digits(rng, 2, 512)
        lo, hi = m.multiply_digits_split(xd, yd)
        elo, ehi = amrmul.exact_multiplier(2).multiply_digits_split(xd, yd)
        # border 0 means only column 0 may host approximate cells; column 0
        # never has 3+ bits beyond stage 1 in practice — tolerate tiny error
        ed = (hi - ehi).astype(np.float64) * 2**32 + (lo - elo)
        assert np.abs(ed).max() <= 2.0

    def test_fig5_fa_pp_dominant(self):
        """Fig. 5: FA_PP is the most-used approximate cell."""
        m = amrmul.AMRMultiplier(4, border=18)
        usage = m.cell_usage_percent()
        approx = {k: v for k, v in usage.items() if k != "FA"}
        assert max(approx, key=approx.get) == "FA_PP"

    def test_schedule_deterministic(self):
        a = amrmul.AMRMultiplier(2, border=8)
        b = amrmul.AMRMultiplier(2, border=8)
        assert a.cell_counts == b.cell_counts
        assert a.schedule.expected_error == b.schedule.expected_error

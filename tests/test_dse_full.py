"""Whole-multiplier DSE tests: shape invariance, export round-trip, Pareto.

The contract chain under test: the count-level search simulation must agree
with the wired schedule builder bit for bit (greedy parity + materialize
round-trip), the fused candidate dispatch must agree with a direct engine
replay bit for bit (measured-error identity), and the exported 2-digit LUT
must agree with the production LUT builder.
"""
from fractions import Fraction

import numpy as np
import pytest

from repro.core import dse, mrsd, ppgen, reduction

jax = pytest.importorskip("jax")

from repro.core import engine as engine_mod  # noqa: E402
from repro.core import lut as lut_lib  # noqa: E402

DESIGNS = [(2, 6), (2, 8), (4, 12), (4, 18)]
FAST_SEARCH = dict(beam_width=12, branch_cap=4, max_nodes=4000)


class TestShapeAndGreedyParity:
    @pytest.mark.parametrize("n_digits,border", DESIGNS)
    def test_shape_matches_schedule_structure(self, n_digits, border):
        """compile_shape's skeleton reproduces the real schedule's stage
        count and FA/HA totals (heights are choice-independent)."""
        events = dse.compile_shape(n_digits, border)
        sched = reduction.get_schedule(n_digits, border)
        assert max(ev.stage for ev in events) + 1 == sched.n_stages
        n_fa = sum(ev.n_fa for ev in events)
        n_ha = sum(1 for ev in events if ev.height - 3 * ev.n_fa == 2)
        counts = sched.cell_counts
        assert n_fa == sum(v for k, v in counts.items() if k != "HA")
        assert n_ha == counts.get("HA", 0)

    @pytest.mark.parametrize("n_digits,border", DESIGNS)
    def test_greedy_parity_with_build_schedule(self, n_digits, border):
        """The simulated greedy composition IS the builder's policy."""
        g = dse.greedy_assignment(n_digits, border)
        sched = reduction.get_schedule(n_digits, border)
        assert g.expected_error == sched.expected_error

    @pytest.mark.parametrize("n_digits,border", DESIGNS)
    def test_greedy_materializes_to_the_cached_schedule(self, n_digits, border):
        sched = dse.materialize(dse.greedy_assignment(n_digits, border))
        ref = reduction.get_schedule(n_digits, border)
        assert sched.cell_counts == ref.cell_counts
        assert sched.expected_error == ref.expected_error
        assert sched.n_stages == ref.n_stages


class TestSearch:
    @pytest.mark.parametrize("n_digits,border", DESIGNS)
    def test_search_never_worse_than_greedy(self, n_digits, border):
        res = dse.search_assignments(n_digits, border, k=2, **FAST_SEARCH)
        g = dse.greedy_assignment(n_digits, border)
        assert abs(res[0].expected_error) <= abs(g.expected_error)

    def test_search_results_distinct_and_sorted(self):
        res = dse.search_assignments(4, 15, k=3, **FAST_SEARCH)
        errs = [abs(a.expected_error) for a in res]
        assert errs == sorted(errs)
        assert len({a.choices for a in res}) == len(res)

    def test_exact_design_has_no_decisions(self):
        res = dse.search_assignments(2, None)
        assert res == [dse.MultiplierAssignment(2, None, (), Fraction(0), 0, True)]

    def test_round_trip_expected_error(self):
        """Export asserts the search's exact error against the builder's."""
        for a in dse.search_assignments(4, 12, k=2, **FAST_SEARCH):
            sched = dse.materialize(a)
            assert sched.expected_error == a.expected_error
            assert sched.border == a.border and sched.n_digits == a.n_digits

    def test_score_hook_reranks_from_a_wider_pool(self):
        """A score_hook sees the analytic pool (>= 3k) and its ranking —
        not the analytic |E| order — decides the returned k."""
        seen = {}

        def hook(assignments):
            seen["n"] = len(assignments)
            # invert the analytic preference: worst |E| scores best
            return [-abs(a.expected_error) for a in assignments]

        plain = dse.search_assignments(2, 7, k=2, **FAST_SEARCH)
        res = dse.search_assignments(2, 7, k=2, score_hook=hook, **FAST_SEARCH)
        assert len(res) == 2 and seen["n"] >= 6  # pool default 3 * k
        # the hook's best is the pool's analytically-worst candidate
        assert abs(res[0].expected_error) >= abs(plain[0].expected_error)

    def test_score_hook_length_mismatch_rejected(self):
        with pytest.raises(ValueError, match="score_hook"):
            dse.search_assignments(2, 7, k=2, score_hook=lambda a: [0.0],
                                   **FAST_SEARCH)

    def test_measured_score_hook_matches_engine_std(self):
        """pareto.measured_score_hook scores by Monte-Carlo std_ed through
        the fused engine dispatch — deterministic for a fixed seed."""
        from repro.core.dse.pareto import measured_score_hook

        cands = dse.search_assignments(2, 7, k=3, **FAST_SEARCH)
        hook = measured_score_hook(n_samples=2000, seed=3)
        s1, s2 = list(hook(cands)), list(hook(cands))
        assert s1 == s2 and len(s1) == len(cands)
        assert all(np.isfinite(s) and s >= 0 for s in s1)

    def test_materialize_rejects_desynced_assignment(self):
        a = dse.greedy_assignment(2, 8)
        bad_first = dse.ColumnChoice(
            a.choices[0].stage, a.choices[0].p,
            a.choices[0].pos_cnt + 1, a.choices[0].neg_cnt,
            a.choices[0].cells)
        bad = dse.MultiplierAssignment(
            a.n_digits, a.border, (bad_first,) + a.choices[1:],
            a.expected_error, a.nodes, a.complete)
        with pytest.raises(AssertionError, match="desync"):
            dse.materialize(bad)


class TestMeasuredIdentity:
    """Acceptance: fused-dispatch measured error == direct engine replay."""

    def test_fused_candidates_match_direct_replay_bitwise(self):
        cands = dse.search_assignments(2, 7, k=2, **FAST_SEARCH)
        scheds = [dse.materialize(a) for a in cands]
        batch = engine_mod.compile_candidates(scheds)
        rng = np.random.default_rng(7)
        xb = ppgen.flatten_operand_bits(mrsd.random_digits(rng, 2, 2048))
        yb = ppgen.flatten_operand_bits(mrsd.random_digits(rng, 2, 2048))
        fused = batch.evaluate_split(xb, yb)
        for sched, (flo, fhi) in zip(scheds, fused):
            dlo, dhi = engine_mod.compile_schedule(sched).evaluate_split(xb, yb)
            np.testing.assert_array_equal(flo, dlo)
            np.testing.assert_array_equal(fhi, dhi)

    def test_candidate_batch_rejects_mixed_widths(self):
        with pytest.raises(ValueError, match="n_digits"):
            engine_mod.compile_candidates(
                [reduction.get_schedule(2, None), reduction.get_schedule(4, None)])

    def test_measured_metrics_match_direct_protocol(self):
        """measure_candidates (fused) equals a hand-rolled direct-replay
        accumulation over the same seeded operand stream, float-for-float."""
        from repro.core.metrics import ErrorAccumulator

        sched = dse.materialize(dse.greedy_assignment(2, 8))
        got = dse.measure_candidates(
            [sched], n_samples=4096, seed=3, chunk=2048)[0]
        eng = engine_mod.compile_schedule(sched)
        exact = engine_mod.get_engine(2, None)
        acc = ErrorAccumulator(max_abs=(16.0 ** 2 * (16.0 / 15.0)) ** 2)
        rng = np.random.default_rng(3)
        for _ in range(2):
            xb = ppgen.flatten_operand_bits(mrsd.random_digits(rng, 2, 2048))
            yb = ppgen.flatten_operand_bits(mrsd.random_digits(rng, 2, 2048))
            acc.update_split(*eng.evaluate_split(xb, yb),
                             *exact.evaluate_split(xb, yb))
        assert got == acc.result()


class TestLUTExport:
    def test_greedy_export_matches_production_lut(self):
        sched = dse.materialize(dse.greedy_assignment(2, 8))
        np.testing.assert_array_equal(
            dse.lut_from_schedule(sched), lut_lib.build_int8_lut(8, engine="jax"))

    def test_exact_schedule_export_is_exact_table(self):
        sched = dse.materialize(dse.greedy_assignment(2, None))
        np.testing.assert_array_equal(
            dse.lut_from_schedule(sched), lut_lib.exact_int8_table())

    def test_rejects_non_int8_widths(self):
        with pytest.raises(ValueError, match="2-digit"):
            dse.lut_from_schedule(reduction.get_schedule(4, 12))


class TestPareto:
    def test_pareto_front_flags(self):
        errs = [1.0, 2.0, 3.0, 0.5, 3.0]
        costs = [3.0, 2.0, 1.0, 9.0, 1.5]
        #       ok   ok   ok   ok   dominated by (3.0, 1.0)
        assert dse.pareto_front(errs, costs) == [True, True, True, True, False]

    def test_pareto_front_keeps_duplicates(self):
        assert dse.pareto_front([1.0, 1.0], [2.0, 2.0]) == [True, True]

    def test_sweep_points_carry_frontier_and_measured(self):
        pts = dse.pareto_sweep(
            2, [6, 8], k=1, n_samples=2048, chunk=2048, **FAST_SEARCH)
        assert len(pts) == 2
        assert all("mred" in pt.measured for pt in pts)
        # monotone design family: wider approximate region, cheaper + worse
        assert pts[0].energy > pts[1].energy
        assert sum(pt.frontier for pt in pts) >= 1

    def test_select_border_respects_budget(self):
        b = dse.select_border(
            2, (6, 8), max_err=1.0, err_key="mared",
            n_samples=2048, chunk=2048, **FAST_SEARCH)
        assert b == 8  # loose budget -> cheapest explored design
        with pytest.raises(ValueError, match="meets"):
            dse.select_border(
                2, (6, 8), max_err=1e-9, err_key="mared",
                n_samples=2048, chunk=2048, **FAST_SEARCH)

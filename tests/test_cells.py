"""Tests for exact/approximate reduction cells (paper §III.A, Fig. 2)."""
import pytest

from repro.core.cells import (
    APPROX_BY_NEG, CELLS, PAPER_AVG_ERR, logic_complexity, output_polarity,
)

_IN3 = [(x, y, z) for x in (0, 1) for y in (0, 1) for z in (0, 1)]


class TestExactCells:
    def test_fa_exact(self):
        c = CELLS["FA"]
        for m, (x, y, z) in enumerate(_IN3):
            assert 2 * c.carry_table[m] + c.sum_table[m] == x + y + z

    def test_ha_exact(self):
        c = CELLS["HA"]
        for m, (x, y) in enumerate([(0, 0), (0, 1), (1, 0), (1, 1)]):
            assert 2 * c.carry_table[m] + c.sum_table[m] == x + y


class TestApproxCells:
    @pytest.mark.parametrize("name,err", sorted(PAPER_AVG_ERR.items()))
    def test_paper_average_errors(self, name, err):
        """The published signed mean errors hold exactly (paper §III.A)."""
        assert CELLS[name].avg_err == pytest.approx(err)

    @pytest.mark.parametrize("name", sorted(PAPER_AVG_ERR))
    def test_simpler_than_exact(self, name):
        """Approximate cells are simplifications of the exact FA."""
        def lits(cell):
            sk = sum(b << i for i, b in enumerate(cell.sum_table))
            ck = sum(b << i for i, b in enumerate(cell.carry_table))
            return logic_complexity(sk) + logic_complexity(ck)
        assert lits(CELLS[name]) < lits(CELLS["FA"])

    def test_classes_cover_all_polarity_mixes(self):
        assert sorted(APPROX_BY_NEG) == [0, 1, 2, 3]
        assert APPROX_BY_NEG[0] == ["FA_PP"]
        assert len(APPROX_BY_NEG[1]) == 2 and len(APPROX_BY_NEG[2]) == 2
        assert APPROX_BY_NEG[3] == ["FA_NN"]

    def test_pn_np_variant_signs(self):
        """Each 2-variant class has one positive and one negative cell
        (the paper's compensation mechanism)."""
        s1 = CELLS["FA_PN1"].avg_err
        s2 = CELLS["FA_PN2"].avg_err
        assert s1 > 0 > s2
        s1 = CELLS["FA_NP1"].avg_err
        s2 = CELLS["FA_NP2"].avg_err
        assert s2 > 0 > s1


class TestPolarity:
    def test_output_polarity_table(self):
        assert output_polarity(3, 0) == (False, False)
        assert output_polarity(3, 1) == (True, False)
        assert output_polarity(3, 2) == (False, True)
        assert output_polarity(3, 3) == (True, True)

    def test_polarity_arithmetic_consistency(self):
        """2c + s - neg_in == value of outputs under polarity interpretation.

        For every input combo and negabit-input count, the exact FA output
        interpreted with output_polarity reproduces the input value sum.
        """
        c = CELLS["FA"]
        for k in range(4):
            spol, cpol = output_polarity(3, k)
            for m, (x, y, z) in enumerate(_IN3):
                stored = [x, y, z]
                # inputs: first (3-k) posibits then k negabits
                vals = stored[: 3 - k] + [b - 1 for b in stored[3 - k:]]
                s = c.sum_table[m] - (1 if spol else 0)
                cr = c.carry_table[m] - (1 if cpol else 0)
                # careful: table index must match the stored-bit order used
                idx = (stored[0] << 2) | (stored[1] << 1) | stored[2]
                s = c.sum_table[idx] - (1 if spol else 0)
                cr = c.carry_table[idx] - (1 if cpol else 0)
                assert 2 * cr + s == sum(vals)

"""Registry-wide config validity: every registered arch, full AND reduced,
passes ``validate_config``; reduced variants are genuinely CPU-sized; the
family index covers the whole zoo and the conformance representatives.
Negative cases pin down that the validator actually rejects the shrink
mistakes it exists to catch."""
import dataclasses

import pytest

from repro.configs import (
    ALL_NAMES,
    families,
    family_of,
    get_config,
    get_reduced_config,
    validate_config,
)
from repro.conformance import REPRESENTATIVE

FAMILY_NAMES = ("dense", "ssm", "hybrid", "moe", "audio", "vlm")


@pytest.mark.parametrize("arch", ALL_NAMES)
def test_full_config_valid(arch):
    cfg = get_config(arch)
    assert validate_config(cfg) is cfg


@pytest.mark.parametrize("arch", ALL_NAMES)
def test_reduced_config_valid_and_tiny(arch):
    cfg = validate_config(get_reduced_config(arch))
    assert cfg.n_layers <= 4, f"{arch}: reduced n_layers={cfg.n_layers}"
    assert cfg.d_model <= 256, f"{arch}: reduced d_model={cfg.d_model}"
    assert cfg.vocab <= 4096, f"{arch}: reduced vocab={cfg.vocab}"
    # the shrink must not change what the config IS
    assert cfg.family == get_config(arch).family


def test_families_cover_registry():
    fams = families()
    assert set(fams) == set(FAMILY_NAMES)
    listed = [a for members in fams.values() for a in members]
    assert sorted(listed) == sorted(ALL_NAMES)
    for fam, members in fams.items():
        for a in members:
            assert family_of(a) == fam


def test_representatives_exist_with_matching_family():
    assert set(REPRESENTATIVE) == set(FAMILY_NAMES)
    for fam, arch in REPRESENTATIVE.items():
        assert arch in ALL_NAMES
        assert family_of(arch) == fam


# ------------------------------------------------------------ negative cases

def _reduced(arch):
    return get_reduced_config(arch)


def test_rejects_bad_gqa_grouping():
    cfg = dataclasses.replace(_reduced("gemma-2b"), n_heads=4, n_kv_heads=3)
    with pytest.raises(ValueError, match="GQA"):
        validate_config(cfg)


def test_rejects_pattern_layer_mismatch():
    cfg = _reduced("gemma3-1b")
    assert cfg.pattern is not None
    cfg = dataclasses.replace(cfg, n_layers=cfg.pattern.n_layers + 1)
    with pytest.raises(ValueError, match="pattern"):
        validate_config(cfg)


def test_rejects_bad_ssm_head_divisibility():
    cfg = _reduced("mamba2-370m")
    bad_ssm = dataclasses.replace(cfg.ssm, head_dim=cfg.ssm.head_dim + 1)
    with pytest.raises(ValueError, match="SSM"):
        validate_config(dataclasses.replace(cfg, ssm=bad_ssm))


def test_rejects_bad_moe_top_k():
    cfg = _reduced("dbrx-132b")
    bad_moe = dataclasses.replace(cfg.moe, top_k=cfg.moe.n_experts + 1)
    with pytest.raises(ValueError, match="top_k"):
        validate_config(dataclasses.replace(cfg, moe=bad_moe))


def test_rejects_swa_without_window():
    cfg = _reduced("gemma3-1b")  # has swa layers in its pattern
    with pytest.raises(ValueError, match="sliding_window"):
        validate_config(dataclasses.replace(cfg, sliding_window=0))

"""Kill/restart bit-consistency: a FaultTolerantLoop under amr_inject,
interrupted mid-run, must resume from ckpt/ and reproduce the
uninterrupted float32 loss stream bitwise.

Covers the three things a process death actually breaks: the step counter
(resume must not replay or skip a batch), the PRNG/step fold (losses after
the boundary must match, not just stay finite), and the injection schedule
registry (process-local — a DSE schedule_ref dangles in the new life until
``on_restore`` re-registers it).
"""
import signal

import pytest
from _markers import nightly

from repro.conformance import run_restart_arm
from repro.core import reduction
from repro.numerics import injection

ARCH = "gemma-2b"


def _assert_bitwise(row):
    assert row["resumed_from"] > 0, row
    assert row["tmp_cleaned"], "stale .tmp-step_* debris survived restore"
    assert row["bit_exact"], (
        f"loss streams diverged after resume (max diff "
        f"{row['max_abs_diff']}): ref={row['ref_losses']} "
        f"resumed={row['resumed_losses']}")


def test_restart_bit_consistency_event_preemption():
    row = run_restart_arm(ARCH, total_steps=6, preempt_at=3)
    _assert_bitwise(row)
    assert row["resumed_from"] == 3


@nightly
def test_restart_bit_consistency_real_sigterm():
    """Same proof via an actual SIGTERM delivered to this process (the
    handler installed by install_preemption_handler)."""
    prev = signal.getsignal(signal.SIGTERM)
    try:
        row = run_restart_arm(ARCH, total_steps=6, preempt_at=3,
                              use_signal=True)
    finally:
        signal.signal(signal.SIGTERM, prev)
    _assert_bitwise(row)


def test_restart_reregisters_dse_schedule():
    """schedule_ref policies survive a restart only because on_restore
    re-registers the schedule; between_lives wipes the registry the way a
    real process death would."""
    sched = reduction.get_schedule(2, 8)
    handle = injection.register_schedule(sched, name="conf:restart")

    def between_lives():
        injection._SCHEDULES.pop(handle, None)
        injection._INJECTORS.pop(handle, None)

    def on_restore(state, step):  # noqa: ARG001 — loop hook signature
        injection.register_schedule(sched, name=handle)

    try:
        row = run_restart_arm(ARCH, total_steps=6, preempt_at=3,
                              schedule_ref=handle,
                              between_lives=between_lives,
                              on_restore=on_restore)
        _assert_bitwise(row)
    finally:
        between_lives()


@nightly
def test_restart_without_reregistration_fails_loudly():
    """The negative control: if nothing re-registers the schedule, the
    resumed life must fail with the registry's actionable KeyError — not
    silently fall back to the default schedule (that would *change the
    numerics* mid-run)."""
    sched = reduction.get_schedule(2, 8)
    handle = injection.register_schedule(sched, name="conf:restart-neg")

    def between_lives():
        injection._SCHEDULES.pop(handle, None)
        injection._INJECTORS.pop(handle, None)

    try:
        with pytest.raises(KeyError, match="not.*registered"):
            run_restart_arm(ARCH, total_steps=6, preempt_at=3,
                            schedule_ref=handle,
                            between_lives=between_lives)
    finally:
        between_lives()

"""ckpt/checkpoint.py round-trip and crash-debris properties.

The restart bit-consistency proof rests on these: custom-dtype leaves
(bf16/f8) restoring bit-exactly, half-written ``.tmp-step_*`` dirs never
shadowing a good checkpoint, and retention keeping step 0.
"""
import json

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.ckpt import (
    CheckpointManager,
    clean_stale_tmp,
    latest_step,
    restore_tree,
    save_tree,
)

CUSTOM_DTYPES = ["bfloat16", "float8_e4m3fn", "float8_e5m2"]


def _assert_bit_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype and a.shape == b.shape
    # byte-level comparison: NaN-safe and works for 0-d leaves
    assert a.tobytes() == b.tobytes()


def _tree_for(dtype: str, seed: int, shape=(3, 5)):
    rng = np.random.default_rng(seed)
    vals = rng.normal(scale=4.0, size=shape)
    return {
        "w": jnp.asarray(vals.astype(getattr(ml_dtypes, dtype))),
        "nested": {"b": jnp.asarray(rng.normal(size=(7,)), jnp.float32),
                   "step": jnp.asarray(seed, jnp.int32)},
    }


@pytest.mark.parametrize("dtype", CUSTOM_DTYPES)
def test_custom_dtype_round_trip_bit_exact(tmp_path, dtype):
    tree = _tree_for(dtype, 0)
    path = save_tree(tmp_path, tree, step=3)
    assert path.name == "step_00000003"
    back = restore_tree(path, tree)
    for orig, rest in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        _assert_bit_equal(orig, rest)


@given(seed=st.integers(0, 2**16), dtype=st.sampled_from(CUSTOM_DTYPES))
@settings(max_examples=10)
def test_round_trip_property(tmp_path_factory, seed, dtype):
    tmp = tmp_path_factory.mktemp("ckpt")
    tree = _tree_for(dtype, seed)
    back = restore_tree(save_tree(tmp, tree, step=1), tree)
    for orig, rest in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        _assert_bit_equal(orig, rest)


def test_nonfinite_values_round_trip(tmp_path):
    tree = {"w": jnp.asarray([np.inf, -np.inf, np.nan, 0.0],
                             ml_dtypes.bfloat16)}
    back = restore_tree(save_tree(tmp_path, tree, step=0), tree)
    _assert_bit_equal(tree["w"], back["w"])


def _plant_tmp_debris(directory, step: int, tree=None):
    tmp = directory / f".tmp-step_{step:08d}"
    tmp.mkdir(parents=True)
    (tmp / "leaf_00000.npy").write_bytes(b"half-written")
    if tree is not None:
        # even a COMPLETE-looking tmp dir (manifest present) must not count
        manifest = {"step": step, "leaves": {}}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
    return tmp


def test_stale_tmp_never_shadows_latest(tmp_path):
    tree = _tree_for("bfloat16", 1)
    save_tree(tmp_path, tree, step=5)
    _plant_tmp_debris(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 5  # .tmp-step_7 invisible to the glob


def test_restore_latest_cleans_stale_tmp(tmp_path):
    tree = _tree_for("bfloat16", 2)
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(tree, step=4)
    debris = _plant_tmp_debris(tmp_path, 9)
    restored, step = mgr.restore_latest(tree)
    assert step == 4
    assert not debris.exists(), "restore must sweep mid-save debris"
    for orig, rest in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert (np.asarray(orig) == np.asarray(rest)).all()


def test_clean_stale_tmp_reports_and_tolerates_missing_dir(tmp_path):
    assert clean_stale_tmp(tmp_path / "never-created") == []
    _plant_tmp_debris(tmp_path, 1)
    _plant_tmp_debris(tmp_path, 2)
    removed = clean_stale_tmp(tmp_path)
    assert removed == [".tmp-step_00000001", ".tmp-step_00000002"]
    assert clean_stale_tmp(tmp_path) == []


def test_interrupted_save_leaves_previous_checkpoint_usable(tmp_path):
    """A save that dies mid-write (simulated: only the tmp dir exists for
    the new step) must leave restore_latest returning the previous step."""
    tree = _tree_for("bfloat16", 3)
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(tree, step=2)
    _plant_tmp_debris(tmp_path, 3, tree)  # step 3's save never renamed
    restored, step = mgr.restore_latest(tree)
    assert step == 2 and restored is not None


def test_retention_keeps_step_zero(tmp_path):
    tree = _tree_for("bfloat16", 4)
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (0, 1, 2, 3, 4):
        mgr.save(tree, step=s)
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000000", "step_00000003", "step_00000004"]


def test_async_save_then_restore(tmp_path):
    tree = _tree_for("bfloat16", 5)
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save_async(tree, step=6)
    restored, step = mgr.restore_latest(tree)  # waits for the writer
    assert step == 6
    for orig, rest in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        _assert_bit_equal(orig, rest)

"""Audit-scope and PRNG-coordinate unit tests: the hooks the conformance
matrix rides on, plus the MoE per-expert decorrelation regression."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MoEConfig
from repro.models.moe import init_moe, moe_forward
from repro.numerics import (
    AMRNumerics,
    AuditTrace,
    approx_matmul,
    noise_key,
    numerics_scope,
)
from repro.numerics import registry


@pytest.fixture
def operands():
    a = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    b = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    return a, b


def test_audit_records_per_site(operands):
    a, b = operands
    nm = AMRNumerics(mode="amr_inject", border=8)
    trace = AuditTrace()

    @jax.jit
    def f(a, b):
        with numerics_scope(audit=trace):
            x = approx_matmul(a, b, nm, site="site.one")
            y = approx_matmul(a, b, nm, site="site.two")
            z = approx_matmul(a, b, nm, site="site.one")
        return x + y + z

    f(a, b).block_until_ready()
    jax.effects_barrier()
    assert set(trace.sites) == {"site.one", "site.two"}
    assert trace.sites["site.one"]["calls"] == 2
    assert trace.calls == 3
    assert trace.bit_exact() and trace.max_abs_diff == 0.0


def test_audit_absent_means_no_oracle_cost(operands):
    a, b = operands
    nm = AMRNumerics(mode="amr_inject", border=8)
    out = approx_matmul(a, b, nm, site="s")  # no scope: must not record
    assert bool(jnp.isfinite(out).all())


def test_audit_detects_corrupted_oracle(operands):
    a, b = operands
    nm = AMRNumerics(mode="amr_inject", border=8)
    spec = registry.get_mode("amr_inject")
    # snapshot the whole registry dict: restoring it wholesale preserves the
    # canonical registration ORDER (re-registering would move amr_inject to
    # the end and break mode_names()-order assertions elsewhere)
    snapshot = dict(registry._REGISTRY)
    registry.unregister_mode("amr_inject")
    try:
        registry.register_mode(
            "amr_inject", spec.impl, required_params=spec.required_params,
            validate=spec.validate,
            # off-by-two-grid-steps oracle: the audit must see it
            oracle=lambda a, b, n: spec.oracle(a, b, n) * 1.5 + 1.0)
        trace = AuditTrace()
        with numerics_scope(audit=trace):
            approx_matmul(a, b, nm, site="s").block_until_ready()
        jax.effects_barrier()
        assert not trace.bit_exact()
        assert trace.max_abs_diff >= 1.0
    finally:
        registry._REGISTRY.clear()
        registry._REGISTRY.update(snapshot)


def test_audit_inject_oracle_custom_schedule(operands):
    from repro.core import reduction
    from repro.numerics import injection

    a, b = operands
    handle = injection.register_schedule(reduction.get_schedule(2, 6),
                                         name="conf:audit-custom")
    nm = AMRNumerics(mode="amr_inject", border=6, schedule_ref=handle)
    trace = AuditTrace()

    @jax.jit
    def f(a, b):
        with numerics_scope(audit=trace):
            return approx_matmul(a, b, nm, site="s")

    f(a, b).block_until_ready()
    jax.effects_barrier()
    assert trace.bit_exact(), trace.sites


def test_noise_key_folds_unit():
    k_base = noise_key(0, "s")
    with numerics_scope(unit=jnp.asarray(0, jnp.int32)):
        k0 = noise_key(0, "s")
    with numerics_scope(unit=jnp.asarray(1, jnp.int32)):
        k1 = noise_key(0, "s")
    assert not jnp.array_equal(k0, k1)
    assert not jnp.array_equal(k_base, k0)


def test_noise_key_unit_folds_in_vector_step_path():
    steps = jnp.asarray([3, 5], jnp.int32)
    with numerics_scope(step=steps, unit=jnp.asarray(1, jnp.int32)):
        ku = noise_key(0, "s")
    with numerics_scope(step=steps):
        kv = noise_key(0, "s")
    assert ku.shape[0] == 2 and kv.shape[0] == 2
    assert not jnp.array_equal(ku, kv)


def test_vmapped_units_decorrelate_noise():
    """The exact shape of the MoE bug: one traced site under vmap."""
    nm = AMRNumerics(mode="amr_noise", border=8, noise_seed=0)
    E = 4
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(2), (1, 6, 16)),
                         (E, 6, 16))
    w = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(3), (1, 16, 8)),
                         (E, 16, 8))

    def with_unit(e, xe, we):
        with numerics_scope(unit=e):
            return approx_matmul(xe, we, nm, site="s")

    ys = jax.vmap(with_unit)(jnp.arange(E, dtype=jnp.int32), x, w)
    for e in range(1, E):
        assert float(jnp.max(jnp.abs(ys[0] - ys[e]))) > 0, (
            f"expert {e} drew the same noise as expert 0")

    # without the unit coordinate the draws ARE identical — the regression
    # this guards against (delete the unit fold and this starts failing)
    def without_unit(xe, we):
        return approx_matmul(xe, we, nm, site="s")

    ys_bug = jax.vmap(without_unit)(x, w)
    assert float(jnp.max(jnp.abs(ys_bug[0] - ys_bug[1]))) == 0.0


def test_moe_experts_draw_distinct_noise():
    """Model-level: identical expert weights + identical token buffers must
    still produce distinct per-expert outputs under amr_noise."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32)
    params = init_moe(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    # clone expert 0's weights into every expert
    for k in ("w_gate", "w_up", "w_down"):
        params[k] = jnp.broadcast_to(params[k][:1], params[k].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))

    nm = AMRNumerics(mode="amr_noise", border=8, noise_seed=3)
    out, _ = jax.jit(lambda p, x: moe_forward(p, x, cfg, numerics=nm))(params, x)
    assert bool(jnp.isfinite(out).all())

    nm_exact = AMRNumerics("exact")
    out_a, _ = moe_forward(params, x, cfg, numerics=nm_exact)
    # exact path with cloned weights: routing still mixes experts; just
    # check the noise path changed SOMETHING (it injected per-expert noise)
    assert float(jnp.max(jnp.abs(out - out_a))) > 0


def test_moe_inject_unit_scope_stays_deterministic():
    """Deterministic modes must be repeatable across calls, and the MoE
    grouped expert matmuls (one batched seam call per projection, sites
    ``moe.expert.*``) must pass the audit bit-identity vs the LUT oracle."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32)
    params = init_moe(jax.random.PRNGKey(0), 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    nm = AMRNumerics(mode="amr_inject", border=8)
    trace = AuditTrace()

    @jax.jit
    def f(p, x):
        with numerics_scope(audit=trace):
            out, _ = moe_forward(p, x, cfg, numerics=nm)
        return out

    out1 = f(params, x)
    out2 = f(params, x)
    assert bool(jnp.all(out1 == out2))
    jax.effects_barrier()
    assert trace.bit_exact(), trace.sites
    assert set(trace.sites) == {"moe.expert.w_gate", "moe.expert.w_up",
                                "moe.expert.w_down"}

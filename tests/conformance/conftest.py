"""Make the shared test helpers (tests/_hyp.py, tests/_markers.py)
importable from this subpackage — pytest puts each test file's own
directory on sys.path, not the parent tests/ dir."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

"""Conformance matrix: every config family x every registry mode.

Tier-1 keeps one representative arch per family on the load-bearing
invariants (amr_inject train + bit-identity, decode parity under the
Pallas kernel path); the full arch x mode sweep runs nightly
(REPRO_NIGHTLY=1 — .github/workflows/nightly.yml).
"""
import pytest
from _markers import nightly

from repro.configs import ALL_NAMES
from repro.conformance import (
    ACTIVATION_SITES,
    PARITY_TOL,
    REPRESENTATIVE,
    arch_mode_arms,
    run_decode_parity,
    run_inject_audit,
    run_noise_decorrelation,
    run_train_arm,
)
from repro.numerics import mode_names

FAMILY_REPS = sorted(REPRESENTATIVE.items())
REP_ARCHS = [a for _, a in FAMILY_REPS]


def test_parity_tolerances_cover_all_modes():
    assert set(PARITY_TOL) == set(mode_names()), (
        "PARITY_TOL must name every registered mode")


# ------------------------------------------------------------------ tier-1

@pytest.mark.parametrize("family,arch", FAMILY_REPS)
def test_representative_trains_under_inject(family, arch):
    row = run_train_arm(arch, "amr_inject", steps=2)
    assert row["loss_finite"], row
    assert row["grad_finite"], row
    assert row["nondegenerate"], row


@pytest.mark.parametrize("family,arch", FAMILY_REPS)
def test_representative_inject_bit_identity(family, arch):
    row = run_inject_audit(arch)
    assert row["sites"] > 0 and row["calls"] > 0, row
    assert row["bit_exact"], (
        f"{arch}: inject != LUT oracle at sites {row['site_diffs']}")
    # hot-path coverage: the family's activation×activation sites must all
    # appear in the audit (and, via the assertion above, be bit-identical)
    missing = ACTIVATION_SITES[family] - set(row["site_diffs"])
    assert not missing, (
        f"{arch}: activation seam sites {sorted(missing)} never reached the "
        f"audit — a call site fell back to plain einsum?")


@pytest.mark.parametrize("family,arch", FAMILY_REPS)
def test_representative_decode_parity_exact(family, arch):
    row = run_decode_parity(arch, "exact")
    assert row["within_tol"], row


def test_representative_decode_parity_kernel():
    # one kernel-path parity arm stays tier-1 (full sweep is nightly)
    row = run_decode_parity(REPRESENTATIVE["dense"], "amr_kernel")
    assert row["within_tol"], row


def test_representative_noise_decorrelation():
    row = run_noise_decorrelation(REPRESENTATIVE["dense"])
    assert row["reproducible"], row
    assert row["steps_decorrelated"], row


# ----------------------------------------------------------------- nightly

@nightly
@pytest.mark.parametrize("arch,mode", arch_mode_arms())
def test_matrix_train(arch, mode):
    row = run_train_arm(arch, mode, steps=2)
    assert row["loss_finite"] and row["grad_finite"] and row["nondegenerate"], row


@nightly
@pytest.mark.parametrize("arch,mode", arch_mode_arms())
def test_matrix_decode_parity(arch, mode):
    row = run_decode_parity(arch, mode)
    assert row["within_tol"], row


@nightly
@pytest.mark.parametrize("arch", ALL_NAMES)
def test_matrix_inject_bit_identity(arch):
    row = run_inject_audit(arch)
    assert row["bit_exact"], row["site_diffs"]


@nightly
@pytest.mark.parametrize("arch", REP_ARCHS)
def test_matrix_noise_decorrelation(arch):
    row = run_noise_decorrelation(arch)
    assert row["reproducible"] and row["steps_decorrelated"], row

"""Bench-regression gate tests: drift in accuracy fields must fail the build."""
import copy
import importlib.util
import json
from pathlib import Path

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench", Path(__file__).resolve().parents[1] / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)

KERNEL = {
    "schema": "BENCH_kernel/v1", "backend": "cpu", "interpret": True,
    "engine": "jax",
    "results": [
        {"variant": "lut", "border": 4, "rank": None, "m": 128, "n": 128,
         "k": 128, "bm": 32, "bn": 32, "bk": 32, "us_per_call": 100.0,
         "max_abs_err_vs_amr": 0.0, "bit_exact_vs_amr": True},
        {"variant": "lowrank", "border": 4, "rank": 8, "m": 128, "n": 128,
         "k": 128, "bm": 32, "bn": 32, "bk": 32, "us_per_call": 50.0,
         "max_abs_err_vs_amr": 123.456, "bit_exact_vs_amr": False},
    ],
}
DSE = {
    "schema": "BENCH_dse/v1", "engine": "jax", "quick": True,
    "samples": {"4": 1024},
    "results": [
        {"n_digits": 4, "border": 12, "candidate": 0, "expected_error": 113.0,
         "mred": 1.2e-4, "mared": 3.4e-4, "nmed": -1e-6, "energy_pj": 3.9,
         "nodes": 1000, "complete": False, "frontier": True,
         "replay_match": True},
    ],
    "frontier_sizes": {"4": 1}, "nodes_visited": 1000, "wall_clock_s": 1.0,
}
TRAIN = {
    "schema": "BENCH_train/v1", "engine": "jax", "quick": True, "steps": 6,
    "border": 8,
    "config": {"d_model": 32, "d_ff": 64, "vocab": 64, "n_layers": 2,
               "seq": 16, "batch": 4},
    "results": [
        {"mode": "consistency", "case": "inject_vs_lut_b8",
         "bit_exact": True, "max_abs_diff": 0.0},
        {"mode": "exact", "schedule": "default", "border": None,
         "first_loss": 4.5, "final_loss": 3.8, "loss_finite": True,
         "grad_finite": True, "params_finite": True, "s_per_step": 0.005},
        {"mode": "amr_inject", "schedule": "dse_c0", "border": 8,
         "first_loss": 4.6, "final_loss": 4.0, "loss_finite": True,
         "grad_finite": True, "params_finite": True, "s_per_step": 0.4},
    ],
    "wall_clock_s": 60.0,
}
INJECT = {
    "schema": "BENCH_inject/v1", "backend": "cpu", "interpret": True,
    "quick": True, "border": 8,
    "results": [
        {"impl": "pairs", "schedule": "default", "m": 32, "n": 64, "k": 48,
         "bit_exact_vs_lut": True, "max_abs_diff": 0.0, "us_per_call": 20000.0},
        {"impl": "xla_cached", "schedule": "default", "m": 32, "n": 64, "k": 48,
         "bit_exact_vs_lut": True, "max_abs_diff": 0.0, "us_per_call": 9000.0},
        {"impl": "pallas", "schedule": "dse_c0", "m": 32, "n": 64, "k": 48,
         "bit_exact_vs_lut": True, "max_abs_diff": 0.0, "us_per_call": 11000.0},
    ],
    "wall_clock_s": 30.0,
}
SERVE = {
    "schema": "BENCH_serve/v1", "engine": "jax", "quick": True, "gen": 4,
    "capacity": 11, "border": 8,
    "config": {"d_model": 32, "d_ff": 64, "vocab": 64, "n_layers": 2},
    "results": [
        {"kind": "throughput", "mode": "exact", "concurrency": 1,
         "requests": 4, "tokens": 16, "complete": True,
         "p50_latency_ms": 15.0, "p99_latency_ms": 21.0,
         "tokens_per_s": 700.0, "steady_tokens_per_s": 2900.0},
        {"kind": "throughput", "mode": "exact", "concurrency": 4,
         "requests": 4, "tokens": 16, "complete": True,
         "p50_latency_ms": 10.0, "p99_latency_ms": 10.2,
         "tokens_per_s": 1500.0, "steady_tokens_per_s": 13000.0},
        {"kind": "bit_exact", "mode": "exact", "concurrency": 3,
         "requests": 4, "bit_exact": True, "tokens_match": True,
         "max_abs_diff": 0.0},
        {"kind": "bit_exact", "mode": "amr_inject", "concurrency": 3,
         "requests": 4, "bit_exact": True, "tokens_match": True,
         "max_abs_diff": 0.0},
    ],
    "wall_clock_s": 40.0,
}


MATRIX = {
    "schema": "BENCH_matrix/v1", "engine": "jax", "quick": True, "border": 8,
    "results": [
        {"kind": "train", "arch": "gemma3-1b", "mode": "amr_inject",
         "steps": 2, "loss_finite": True, "grad_finite": True,
         "nondegenerate": True, "first_loss": 6.2, "final_loss": 5.9},
        {"kind": "inject_audit", "arch": "dbrx-132b", "schedule": "default",
         "bit_exact": True, "max_abs_diff": 0.0, "sites": 9, "calls": 18,
         "site_diffs": {"moe.w_gate": 0.0}},
        {"kind": "decode_parity", "arch": "whisper-small", "mode": "exact",
         "applicable": True, "within_tol": True, "parity_diff": 0.02,
         "tol": 0.15},
        {"kind": "noise_decorrelation", "arch": "gemma3-1b",
         "reproducible": True, "steps_decorrelated": True},
        {"kind": "restart", "arch": "gemma-2b", "schedule": "default",
         "bit_exact": True, "max_abs_diff": 0.0, "steps": 6,
         "resumed_from": 3, "tmp_cleaned": True,
         "ref_losses": [6.1, 6.0], "resumed_losses": [6.1, 6.0]},
    ],
    "wall_clock_s": 300.0,
}


POLICY = {
    "schema": "BENCH_policy/v1", "engine": "jax", "quick": True,
    "samples": 4000,
    "results": [
        {"kind": "uniform_parity", "mode": "exact", "bit_exact": True,
         "tokens_match": True, "max_abs_diff": 0.0},
        {"kind": "uniform_parity", "mode": "amr_inject", "bit_exact": True,
         "tokens_match": True, "max_abs_diff": 0.0},
        {"kind": "frontier", "label": "dse:b8.0", "energy_per_mac": 1609.0,
         "err": 0.1074},
        {"kind": "uniform", "label": "dse:b8.0", "energy": 2.2e8,
         "feasible": True, "fidelity": 0.31, "loss": 5.4},
        {"kind": "searched", "label": "searched",
         "policy": "perlayer[4l: exact; inject b4-b7]", "energy": 2.5e8,
         "fidelity": 0.048, "moves": 2, "dominates_best_uniform": True},
    ],
    "wall_clock_s": 250.0,
}


ATTN = {
    "schema": "BENCH_attn/v1", "backend": "cpu", "interpret": True,
    "results": [
        {"method": "lut", "border": 8, "g": 2, "m": 8, "d": 8, "t": 32,
         "p": 16, "bm": 8, "us_per_call": 64.0, "ref_us_per_call": 49.0,
         "max_abs_diff": 0.0, "bit_exact": True},
        {"method": "inject", "border": 8, "g": 2, "m": 8, "d": 8, "t": 40,
         "p": 24, "bm": 8, "us_per_call": 3988.0, "ref_us_per_call": 5319.0,
         "max_abs_diff": 0.0, "bit_exact": True},
    ],
}


def _errors(fresh, baseline):
    errs, _ = check_bench.compare_artifacts(fresh, baseline, "t.json")
    return errs


class TestCompare:
    def test_identical_passes(self):
        assert _errors(copy.deepcopy(KERNEL), KERNEL) == []
        assert _errors(copy.deepcopy(DSE), DSE) == []

    def test_injected_error_delta_is_caught(self):
        """The acceptance case: a perturbed error field fails the gate."""
        bad = copy.deepcopy(DSE)
        bad["results"][0]["mred"] += 1e-12
        errs = _errors(bad, DSE)
        assert len(errs) == 1 and "mred drifted" in errs[0]

    def test_bit_exact_flip_is_caught(self):
        bad = copy.deepcopy(KERNEL)
        bad["results"][0]["bit_exact_vs_amr"] = False
        assert any("bit_exact" in e for e in _errors(bad, KERNEL))

    def test_integer_exact_row_error_must_match_exactly(self):
        bad = copy.deepcopy(KERNEL)
        bad["results"][0]["max_abs_err_vs_amr"] = 1e-9
        assert any("max_abs_err" in e for e in _errors(bad, KERNEL))

    def test_float_path_row_tolerates_last_ulp(self):
        """Low-rank rows go through BLAS/SVD: tiny cross-platform drift is
        tolerated, real drift is not."""
        near = copy.deepcopy(KERNEL)
        near["results"][1]["max_abs_err_vs_amr"] *= 1 + 1e-9
        assert _errors(near, KERNEL) == []
        far = copy.deepcopy(KERNEL)
        far["results"][1]["max_abs_err_vs_amr"] *= 1.01
        assert any("max_abs_err" in e for e in _errors(far, KERNEL))

    def test_timing_drift_is_advisory_only(self):
        slow = copy.deepcopy(KERNEL)
        slow["results"][0]["us_per_call"] *= 10
        errs, advisories = check_bench.compare_artifacts(slow, KERNEL, "t")
        assert errs == [] and any("us_per_call" in a for a in advisories)


class TestTrainArtifact:
    def test_identical_passes(self):
        assert _errors(copy.deepcopy(TRAIN), TRAIN) == []

    def test_inject_oracle_mismatch_is_caught(self):
        """amr_inject drifting off the amr_lut oracle must fail the gate,
        even by one ulp — the agreement is integer-derived."""
        bad = copy.deepcopy(TRAIN)
        bad["results"][0]["bit_exact"] = False
        bad["results"][0]["max_abs_diff"] = 1e-7
        errs = _errors(bad, TRAIN)
        assert any("bit_exact" in e for e in errs)
        assert any("max_abs_diff" in e for e in errs)

    def test_nonfinite_loss_is_caught(self):
        bad = copy.deepcopy(TRAIN)
        bad["results"][1]["loss_finite"] = False
        assert any("loss_finite" in e for e in _errors(bad, TRAIN))

    def test_loss_value_drift_is_advisory(self):
        """Loss trajectories ride on float matmuls: platform drift must
        not fail the build, only surface as a note."""
        drift = copy.deepcopy(TRAIN)
        drift["results"][1]["final_loss"] *= 1.5
        errs, advisories = check_bench.compare_artifacts(drift, TRAIN, "t")
        assert errs == [] and any("final_loss" in a for a in advisories)

    def test_missing_mode_row_is_caught(self):
        bad = copy.deepcopy(TRAIN)
        bad["results"].pop()  # drop the DSE-candidate arm
        assert any("missing" in e for e in _errors(bad, TRAIN))

    def test_missing_and_extra_rows_fail(self):
        missing = copy.deepcopy(KERNEL)
        del missing["results"][0]
        assert any("missing" in e for e in _errors(missing, KERNEL))
        extra = copy.deepcopy(DSE)
        extra["results"].append(dict(DSE["results"][0], border=15))
        assert any("new sweep point" in e for e in _errors(extra, DSE))

    def test_run_config_mismatch_fails(self):
        bad = copy.deepcopy(DSE)
        bad["samples"] = {"4": 2048}
        assert any("samples" in e for e in _errors(bad, DSE))

    def test_frontier_flip_is_caught(self):
        bad = copy.deepcopy(DSE)
        bad["results"][0]["frontier"] = False
        assert any("frontier" in e for e in _errors(bad, DSE))


class TestInjectArtifact:
    def test_identical_passes(self):
        assert _errors(copy.deepcopy(INJECT), INJECT) == []

    def test_oracle_mismatch_is_caught_per_impl(self):
        """Any replay implementation drifting off the LUT oracle — even by
        one integer — must fail the gate."""
        for i in range(len(INJECT["results"])):
            bad = copy.deepcopy(INJECT)
            bad["results"][i]["bit_exact_vs_lut"] = False
            bad["results"][i]["max_abs_diff"] = 1.0
            errs = _errors(bad, INJECT)
            assert any("bit_exact_vs_lut" in e for e in errs), i
            assert any("max_abs_diff" in e for e in errs), i

    def test_timing_drift_is_advisory(self):
        slow = copy.deepcopy(INJECT)
        slow["results"][0]["us_per_call"] *= 10
        errs, advisories = check_bench.compare_artifacts(slow, INJECT, "t")
        assert errs == [] and any("us_per_call" in a for a in advisories)

    def test_missing_impl_row_is_caught(self):
        bad = copy.deepcopy(INJECT)
        bad["results"].pop()  # drop the pallas/dse arm
        assert any("missing" in e for e in _errors(bad, INJECT))


class TestServeArtifact:
    def test_identical_passes(self):
        assert _errors(copy.deepcopy(SERVE), SERVE) == []

    def test_batching_exactness_flip_is_caught(self):
        """Slot-batched decode drifting off solo decode — even one ulp of
        logit difference — must fail the gate, per numerics mode."""
        for i in (2, 3):  # both bit_exact rows
            bad = copy.deepcopy(SERVE)
            bad["results"][i]["bit_exact"] = False
            bad["results"][i]["max_abs_diff"] = 1e-7
            errs = _errors(bad, SERVE)
            assert any("bit_exact" in e for e in errs), i
            assert any("max_abs_diff" in e for e in errs), i

    def test_token_stream_mismatch_is_caught(self):
        bad = copy.deepcopy(SERVE)
        bad["results"][3]["tokens_match"] = False
        assert any("tokens_match" in e for e in _errors(bad, SERVE))

    def test_incomplete_serving_is_caught(self):
        bad = copy.deepcopy(SERVE)
        bad["results"][1]["complete"] = False
        bad["results"][1]["tokens"] = 12
        errs = _errors(bad, SERVE)
        assert any("complete" in e for e in errs)
        assert any("tokens" in e for e in errs)

    def test_latency_and_throughput_are_advisory(self):
        slow = copy.deepcopy(SERVE)
        slow["results"][0]["p99_latency_ms"] *= 10
        slow["results"][0]["steady_tokens_per_s"] /= 10
        errs, advisories = check_bench.compare_artifacts(slow, SERVE, "t")
        assert errs == []
        assert any("p99_latency_ms" in a for a in advisories)
        assert any("steady_tokens_per_s" in a for a in advisories)

    def test_missing_concurrency_row_is_caught(self):
        bad = copy.deepcopy(SERVE)
        del bad["results"][1]
        assert any("missing" in e for e in _errors(bad, SERVE))

    def test_run_config_mismatch_fails(self):
        bad = copy.deepcopy(SERVE)
        bad["gen"] = 8
        assert any("gen" in e for e in _errors(bad, SERVE))


class TestMatrixArtifact:
    def test_identical_passes(self):
        assert _errors(copy.deepcopy(MATRIX), MATRIX) == []

    def test_inject_bit_identity_flip_is_caught(self):
        """The tentpole invariant: inject-vs-LUT-oracle grid-step agreement
        is integer-derived, so even a one-grid-step drift fails."""
        bad = copy.deepcopy(MATRIX)
        bad["results"][1]["bit_exact"] = False
        bad["results"][1]["max_abs_diff"] = 1.0
        errs = _errors(bad, MATRIX)
        assert any("bit_exact" in e for e in errs)
        assert any("max_abs_diff" in e for e in errs)

    def test_train_invariant_flips_are_caught(self):
        for field in ("loss_finite", "grad_finite", "nondegenerate"):
            bad = copy.deepcopy(MATRIX)
            bad["results"][0][field] = False
            assert any(field in e for e in _errors(bad, MATRIX)), field

    def test_decode_parity_flip_is_caught(self):
        bad = copy.deepcopy(MATRIX)
        bad["results"][2]["within_tol"] = False
        bad["results"][2]["parity_diff"] = 3.0
        assert any("within_tol" in e for e in _errors(bad, MATRIX))

    def test_restart_regression_is_caught(self):
        for field in ("bit_exact", "tmp_cleaned"):
            bad = copy.deepcopy(MATRIX)
            bad["results"][4][field] = False
            assert any(field in e for e in _errors(bad, MATRIX)), field
        early = copy.deepcopy(MATRIX)
        early["results"][4]["resumed_from"] = 0  # silently started over
        assert any("resumed_from" in e for e in _errors(early, MATRIX))

    def test_loss_and_parity_drift_are_advisory(self):
        drift = copy.deepcopy(MATRIX)
        drift["results"][0]["final_loss"] *= 1.5
        drift["results"][2]["parity_diff"] *= 3
        errs, advisories = check_bench.compare_artifacts(drift, MATRIX, "t")
        assert errs == []
        assert any("final_loss" in a for a in advisories)
        assert any("parity_diff" in a for a in advisories)

    def test_missing_arm_is_caught(self):
        bad = copy.deepcopy(MATRIX)
        del bad["results"][3]
        assert any("missing" in e for e in _errors(bad, MATRIX))


class TestPolicyArtifact:
    def test_identical_passes(self):
        assert _errors(copy.deepcopy(POLICY), POLICY) == []

    def test_uniform_parity_flip_is_caught(self):
        """UniformPolicy drifting off the bare AMRNumerics trace — even one
        ulp — must fail, per mode."""
        for i in (0, 1):
            bad = copy.deepcopy(POLICY)
            bad["results"][i]["bit_exact"] = False
            bad["results"][i]["max_abs_diff"] = 1e-7
            errs = _errors(bad, POLICY)
            assert any("bit_exact" in e for e in errs), i
            assert any("max_abs_diff" in e for e in errs), i

    def test_token_stream_mismatch_is_caught(self):
        bad = copy.deepcopy(POLICY)
        bad["results"][1]["tokens_match"] = False
        assert any("tokens_match" in e for e in _errors(bad, POLICY))

    def test_frontier_drift_is_caught(self):
        """Frontier energies are literal cell counts and errs come from a
        seeded integer-replay MC: both are deterministic, gated exactly."""
        for field in ("energy_per_mac", "err"):
            bad = copy.deepcopy(POLICY)
            bad["results"][2][field] *= 1 + 1e-3
            assert any(field in e for e in _errors(bad, POLICY)), field

    def test_uniform_energy_and_feasibility_are_gated(self):
        bad = copy.deepcopy(POLICY)
        bad["results"][3]["energy"] *= 2
        bad["results"][3]["feasible"] = False
        errs = _errors(bad, POLICY)
        assert any("energy" in e for e in errs)
        assert any("feasible" in e for e in errs)

    def test_searched_domination_flip_is_caught(self):
        """The headline claim: the searched policy strictly dominates the
        best uniform one. Losing it fails the gate."""
        bad = copy.deepcopy(POLICY)
        bad["results"][4]["dominates_best_uniform"] = False
        assert any("dominates_best_uniform" in e for e in _errors(bad, POLICY))

    def test_fidelity_loss_and_moves_drift_are_advisory(self):
        """Float training fidelity and the accepted move set may vary across
        platforms; they inform, they don't gate."""
        drift = copy.deepcopy(POLICY)
        drift["results"][3]["fidelity"] *= 2
        drift["results"][3]["loss"] *= 1.5
        drift["results"][4]["moves"] += 3
        errs, advisories = check_bench.compare_artifacts(drift, POLICY, "t")
        assert errs == []
        assert any("fidelity" in a for a in advisories)
        assert any("loss" in a for a in advisories)
        assert any("moves" in a for a in advisories)

    def test_missing_searched_row_is_caught(self):
        bad = copy.deepcopy(POLICY)
        del bad["results"][4]
        assert any("missing" in e for e in _errors(bad, POLICY))


class TestAttnArtifact:
    def test_identical_passes(self):
        assert _errors(copy.deepcopy(ATTN), ATTN) == []

    def test_bit_exact_flip_fails(self):
        bad = copy.deepcopy(ATTN)
        bad["results"][1]["bit_exact"] = False
        bad["results"][1]["max_abs_diff"] = 3.05e-05
        errs = _errors(bad, ATTN)
        assert any("bit_exact" in e for e in errs)
        assert any("max_abs_diff" in e for e in errs)

    def test_diff_must_be_exactly_zero(self):
        # fused-vs-seam agreement is integer-derived: even a last-ulp
        # float drift is a regression, never tolerance-absorbed
        bad = copy.deepcopy(ATTN)
        bad["results"][0]["max_abs_diff"] = 1e-12
        assert any("max_abs_diff" in e for e in _errors(bad, ATTN))

    def test_timing_drift_is_advisory(self):
        noisy = copy.deepcopy(ATTN)
        noisy["results"][0]["us_per_call"] *= 3.0
        noisy["results"][1]["ref_us_per_call"] *= 0.2
        assert _errors(noisy, ATTN) == []

    def test_missing_sweep_point_fails(self):
        short = copy.deepcopy(ATTN)
        short["results"].pop()
        assert any("missing" in e for e in _errors(short, ATTN))


class TestMain:
    @pytest.fixture()
    def dirs(self, tmp_path):
        fresh = tmp_path / "fresh"
        base = tmp_path / "base"
        fresh.mkdir()
        base.mkdir()
        for d in (fresh, base):
            (d / "BENCH_kernel.json").write_text(json.dumps(KERNEL))
            (d / "BENCH_dse.json").write_text(json.dumps(DSE))
            (d / "BENCH_train.json").write_text(json.dumps(TRAIN))
            (d / "BENCH_inject.json").write_text(json.dumps(INJECT))
            (d / "BENCH_serve.json").write_text(json.dumps(SERVE))
            (d / "BENCH_matrix.json").write_text(json.dumps(MATRIX))
            (d / "BENCH_policy.json").write_text(json.dumps(POLICY))
            (d / "BENCH_attn.json").write_text(json.dumps(ATTN))
        return fresh, base

    def test_main_clean(self, dirs):
        fresh, base = dirs
        assert check_bench.main(
            ["--fresh-dir", str(fresh), "--baseline-dir", str(base)]) == 0

    def test_main_fails_on_perturbation(self, dirs):
        fresh, base = dirs
        bad = copy.deepcopy(DSE)
        bad["results"][0]["mared"] *= 2
        (fresh / "BENCH_dse.json").write_text(json.dumps(bad))
        assert check_bench.main(
            ["--fresh-dir", str(fresh), "--baseline-dir", str(base)]) == 1

    def test_main_fails_on_missing_baseline(self, dirs):
        fresh, base = dirs
        (base / "BENCH_dse.json").unlink()
        assert check_bench.main(
            ["--fresh-dir", str(fresh), "--baseline-dir", str(base)]) == 1

    def test_committed_baselines_exist_and_parse(self):
        root = Path(__file__).resolve().parents[1]
        for name in check_bench.DEFAULT_ARTIFACTS:
            p = root / "benchmarks" / "baselines" / name
            art = json.loads(p.read_text())
            assert art["schema"].startswith(
                ("BENCH_kernel/", "BENCH_dse/", "BENCH_train/",
                 "BENCH_inject/", "BENCH_serve/", "BENCH_matrix/",
                 "BENCH_policy/", "BENCH_attn/"))
            assert art["results"], f"{name} baseline has no rows"

"""Activation-operand path through the injection seam.

The B side of QK^T / PV / grouped-expert matmuls is a traced ACTIVATION:
the identity-keyed ``WEIGHT_PACKS`` cache is structurally invalid for it
(tracers have no stable object identity across traces), so the seam must
lane-pack in-trace and the cache must refuse tracers loudly rather than
serve one trace's garbage to the next.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.numerics import AMRNumerics, injection
from repro.numerics.approx_matmul import approx_matmul, matmul_amr_lut


def _ops(g=3, m=4, k=16, n=8, seed=0):
    rng = np.random.default_rng(seed)
    ia = jnp.asarray(rng.integers(0, 256, (g, m, k)), jnp.int32)
    ib = jnp.asarray(rng.integers(0, 256, (g, k, n)), jnp.int32)
    return ia, ib


class TestWeightPackCacheRejectsTracers:
    def test_cache_get_raises_inside_jit(self):
        inj = engine.get_injector(2, 8)

        @jax.jit
        def f(ib):
            return injection.WEIGHT_PACKS.get(inj, ib)

        with pytest.raises(TypeError, match="[Tt]raced"):
            f(jnp.zeros((8, 16), jnp.int32))

    def test_cache_get_raises_for_numpy(self):
        # non-jax.Array concrete operands are also refused by the cache
        # itself (packed_weights routes them around it)
        inj = engine.get_injector(2, 8)
        with pytest.raises(TypeError, match="jax.Array"):
            injection.WEIGHT_PACKS.get(inj, np.zeros((8, 16), np.int32))

    def test_packed_weights_bypasses_cache_in_trace(self):
        inj = engine.get_injector(2, 8)
        injection.WEIGHT_PACKS.clear()
        ib = jnp.asarray(np.random.default_rng(1).integers(0, 256, (8, 16)))
        want = np.asarray(inj.pack_weights(ib))
        got = np.asarray(jax.jit(lambda y: injection.packed_weights(inj, y))(ib))
        np.testing.assert_array_equal(got, want)
        assert len(injection.WEIGHT_PACKS) == 0  # nothing cached in-trace


class TestInjectedMatmulGrouped:
    def setup_method(self):
        self.inj = engine.get_injector(2, 8)

    def test_jitted_activation_operand_matches_per_group(self):
        """The load-bearing satellite case: a JITTED (traced) activation B
        operand through the grouped path is bit-identical to stacking the
        unbatched weight-path replay per group."""
        ia, ib = _ops()
        got = np.asarray(jax.jit(
            lambda x, y: injection.injected_matmul_grouped(self.inj, x, y))(ia, ib))
        want = np.stack([
            np.asarray(injection.injected_matmul_int(self.inj, ia[g], ib[g]))
            for g in range(ia.shape[0])])
        np.testing.assert_array_equal(got, want)

    def test_pallas_impl_matches_xla(self):
        ia, ib = _ops(seed=2)
        f = jax.jit(lambda x, y: injection.injected_matmul_grouped(
            self.inj, x, y, impl="pallas"))
        g = jax.jit(lambda x, y: injection.injected_matmul_grouped(
            self.inj, x, y, impl="xla"))
        np.testing.assert_array_equal(np.asarray(f(ia, ib)),
                                      np.asarray(g(ia, ib)))

    def test_grouped_call_leaves_cache_empty(self):
        injection.WEIGHT_PACKS.clear()
        ia, ib = _ops(seed=3)
        jax.jit(lambda x, y: injection.injected_matmul_grouped(
            self.inj, x, y))(ia, ib).block_until_ready()
        assert len(injection.WEIGHT_PACKS) == 0

    def test_shape_validation(self):
        ia, ib = _ops()
        with pytest.raises(ValueError, match="matching G"):
            injection.injected_matmul_grouped(self.inj, ia, ib[:-1])
        with pytest.raises(ValueError, match=r"\(G, M, K\)"):
            injection.injected_matmul_grouped(self.inj, ia[0], ib[0])


class TestApproxMatmulActivationPath:
    """approx_matmul with a batched (per-group) B operand — the seam form
    the attention/MoE/SSD call sites use — jitted, against the LUT oracle
    applied per group (also jitted: jit-vs-jit comparisons only)."""

    def setup_method(self):
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        self.a = jax.random.normal(ks[0], (3, 4, 16), jnp.float32)
        self.b = jax.random.normal(ks[1], (3, 16, 8), jnp.float32)

    def test_inject_batched_b_bit_identical_to_lut(self):
        nm = AMRNumerics("amr_inject", border=8)
        got = np.asarray(jax.jit(
            lambda a, b: approx_matmul(a, b, nm, site="attn.qk"))(self.a, self.b))
        want = np.asarray(jax.jit(
            lambda a, b: matmul_amr_lut(a, b, border=8))(self.a, self.b))
        np.testing.assert_array_equal(got, want)

    def test_inject_gqa_fold_matches_stacked_groups(self):
        """Batched call == stacked per-group calls (the GQA fold in
        models/attention.py relies on this being bitwise)."""
        nm = AMRNumerics("amr_inject", border=8)
        batched = np.asarray(jax.jit(
            lambda a, b: approx_matmul(a, b, nm))(self.a, self.b))
        per_group = np.stack([np.asarray(jax.jit(
            lambda a, b: approx_matmul(a, b, nm))(self.a[g], self.b[g]))
            for g in range(3)])
        np.testing.assert_array_equal(batched, per_group)

"""Fused-attention kernel vs the unfused activation-seam composition.

The bar is BIT-identity (kernels/attn_fused/kernel.py documents why it
holds): both sides run jitted on the same backend — the fused Pallas call
(interpreter on CPU) against the jitted XLA seam composition
(``fused_attention_reference``, literally the models/attention.py chain on
pre-folded operands).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.amr_matmul.tiling import head_dim_bucket, pick_attn_tile
from repro.kernels.attn_fused import (fused_attention,
                                      fused_attention_reference)


def _case(g=3, m=8, d=16, t=32, p=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (g, m, d), jnp.float32)
    kt = jax.random.normal(ks[1], (g, d, t), jnp.float32)
    v = jax.random.normal(ks[2], (g, t, p), jnp.float32)
    # ragged decode-style validity: row (g, i) sees lengths[g, i] slots
    lengths = jax.random.randint(ks[3], (g, m), 1, t + 1)
    mask = jnp.arange(t)[None, None, :] < lengths[:, :, None]
    return q, kt, v, mask


def _pair(method, **kw):
    fused = jax.jit(lambda q, kt, v, mask: fused_attention(
        q, kt, v, mask, method=method, **kw))
    ref = jax.jit(lambda q, kt, v, mask: fused_attention_reference(
        q, kt, v, mask, method=method, **kw))
    return fused, ref


@pytest.mark.parametrize("border", [2, 8])
def test_lut_bit_identical_to_seam(border):
    ops = _case()
    fused, ref = _pair("lut", border=border)
    out, want = fused(*ops), ref(*ops)
    assert out.shape == want.shape == (3, 8, 16)
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_inject_bit_identical_to_seam():
    ops = _case(g=2, m=4, d=8, t=32, p=16, seed=1)
    fused, ref = _pair("inject", border=8)
    out, want = fused(*ops), ref(*ops)
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_inject_word_padded_t_and_p():
    """T and P that are not lane-word multiples: the replayed score block
    is sliced before the softmax, PV pad columns after the kernel."""
    ops = _case(g=2, m=4, d=8, t=40, p=24, seed=2)
    fused, ref = _pair("inject", border=8)
    out, want = fused(*ops), ref(*ops)
    assert out.shape == (2, 4, 24)
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_inject_custom_schedule():
    from repro.core import reduction
    from repro.numerics import injection

    handle = injection.register_schedule(reduction.get_schedule(2, 6),
                                         name="attnfused:b6")
    ops = _case(g=2, m=4, d=8, t=32, p=16, seed=3)
    fused, ref = _pair("inject", border=6, schedule_ref=handle)
    out, want = fused(*ops), ref(*ops)
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_lut_row_tile_invariance():
    """The softmax is per query row, so the bm tiling cannot change the
    result — any row-tile split is bitwise the same output."""
    ops = _case(m=8)
    outs = [jax.jit(lambda q, kt, v, mask, b=b: fused_attention(
        q, kt, v, mask, method="lut", bm=b))(*ops) for b in (2, 4, 8)]
    for o in outs[1:]:
        assert np.array_equal(np.asarray(outs[0]), np.asarray(o))


def test_explicit_scale_matches():
    ops = _case(g=2, m=4, d=16, t=16, p=8, seed=4)
    fused, ref = _pair("lut", scale=7.5)
    assert np.array_equal(np.asarray(fused(*ops)), np.asarray(ref(*ops)))


def test_shape_and_method_validation():
    q, kt, v, mask = _case()
    with pytest.raises(ValueError, match="method"):
        fused_attention(q, kt, v, mask, method="nope")
    with pytest.raises(ValueError, match="schedule_ref"):
        fused_attention(q, kt, v, mask, method="lut", schedule_ref="x")
    with pytest.raises(ValueError, match="shapes disagree"):
        fused_attention(q, kt[:, :-1], v, mask)
    with pytest.raises(ValueError, match="mask"):
        fused_attention(q, kt, v, mask[:, :, :-1])


def test_head_dim_bucketing():
    assert head_dim_bucket(8) == 64
    assert head_dim_bucket(64) == 64
    assert head_dim_bucket(65) == 128
    assert head_dim_bucket(128) == 128
    assert head_dim_bucket(129) == 256
    assert head_dim_bucket(512) == 256


def test_pick_attn_tile_divisors():
    # cpu table prefers 128 for the 64-bucket: clamped to a divisor of m
    assert pick_attn_tile(48, 64, backend="cpu") == 48
    assert pick_attn_tile(256, 64, backend="cpu") == 128
    assert pick_attn_tile(256, 200, backend="cpu") == 64  # 256-bucket row
    assert pick_attn_tile(48, 64, backend="cpu", bm=6) == 6
    with pytest.raises(ValueError, match="bm=5"):
        pick_attn_tile(48, 64, backend="cpu", bm=5)

"""Prefill -> decode cache handoff: one-shot prefill must agree with both
the full forward pass and subsequent decode steps, for every mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import decode_step, forward, init_params
from repro.models.model import _encoder_forward, prefill_with_cache

# Single-device consistency checks — run on legacy jax too (no meshes).

FAMILIES = ["gemma-2b", "mamba2-370m", "zamba2-1.2b", "gemma3-1b",
            "whisper-small", "dbrx-132b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_handoff_matches_forward(arch):
    cfg = get_reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, S)), jnp.int32)
    extra = None
    enc_out = None
    if cfg.encoder_layers:
        extra = jnp.asarray(rng.normal(size=(2, cfg.encoder_frames, cfg.d_model)),
                            jnp.dtype(cfg.dtype))
        enc_out = _encoder_forward(cfg, params, extra, cfg.numerics)

    ref, _ = forward(cfg, params, toks, extra)
    # prefill S-1 tokens, then decode token S-1: logits must match forward's
    logits_pre, cache = prefill_with_cache(cfg, params, toks[:, : S - 1],
                                           capacity=S, extra_embeddings=extra)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(ref[:, S - 2], np.float32), rtol=0.15, atol=0.15)
    lg, cache = decode_step(cfg, params, toks[:, S - 1 : S], cache, enc_out)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(ref[:, -1], np.float32), rtol=0.15, atol=0.15)


def test_swa_ring_handoff_long_prompt():
    """Sliding-window cache handoff with prompt longer than the window."""
    cfg = get_reduced_config("gemma3-1b")  # window 8 in reduced config
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    S = 24  # > window
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, S)), jnp.int32)
    ref, _ = forward(cfg, params, toks)
    _, cache = prefill_with_cache(cfg, params, toks[:, : S - 1], capacity=S)
    lg, _ = decode_step(cfg, params, toks[:, S - 1 : S], cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32), np.asarray(ref[:, -1], np.float32),
        rtol=0.15, atol=0.15)

"""End-to-end integration: real launcher path on a tiny model (CPU).

Covers: loss decreases on learnable synthetic data; checkpoint resume
continues mid-stream; AMR numerics trains without divergence.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import SyntheticLM
from repro.numerics import AMRNumerics
from repro.runtime import FaultTolerantLoop
from repro.train.steps import make_train_state, make_train_step

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=128, mlp_act="swiglu",
    tie_embeddings=True, remat="none")


def _train(cfg, steps, batch=8, seq=32, seed=0):
    data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, batch=batch, seed=seed,
                       noise=0.02)
    state = make_train_state(cfg, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, peak_lr=5e-3, warmup=5, total_steps=steps),
                   donate_argnums=(0,))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return losses


class TestTraining:
    def test_loss_decreases(self):
        losses = _train(TINY, steps=30)
        assert losses[-1] < losses[0] - 0.5, losses[::6]

    def test_amr_numerics_trains(self):
        cfg = dataclasses.replace(
            TINY, numerics=AMRNumerics("amr_lowrank", border=6, rank=8))
        losses = _train(cfg, steps=30)
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0] - 0.3, losses[::6]

    def test_microbatch_must_divide_batch(self):
        """B=8 with microbatch=3 used to die inside reshape with a cryptic
        error (or silently mis-shape); now it names both numbers up front."""
        import pytest

        data = SyntheticLM(vocab=TINY.vocab, seq_len=32, batch=8, seed=0)
        b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        state = make_train_state(TINY, jax.random.PRNGKey(0))
        step = make_train_step(TINY, microbatch=3)
        with pytest.raises(ValueError, match=r"8 is not divisible by microbatch=3"):
            step(state, b)

    def test_microbatched_matches_unbatched_shape(self):
        data = SyntheticLM(vocab=TINY.vocab, seq_len=32, batch=8, seed=0)
        b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        s1 = make_train_state(TINY, jax.random.PRNGKey(0))
        s2 = make_train_state(TINY, jax.random.PRNGKey(0))
        st1 = jax.jit(make_train_step(TINY))
        st2 = jax.jit(make_train_step(TINY, microbatch=4))
        (n1, m1) = st1(s1, b)
        (n2, m2) = st2(s2, b)
        # same data, same init: microbatched loss == mean of micro losses and
        # the resulting params should be very close (identical grads averaged)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=0.05)
        a1 = np.asarray(jax.tree.leaves(n1.params)[0], np.float32)
        a2 = np.asarray(jax.tree.leaves(n2.params)[0], np.float32)
        np.testing.assert_allclose(a1, a2, atol=5e-3)


class TestResume:
    def test_checkpoint_resume_continues(self, tmp_path):
        data = SyntheticLM(vocab=TINY.vocab, seq_len=32, batch=4, seed=1)
        step = jax.jit(make_train_step(TINY, peak_lr=1e-3, total_steps=100))

        def make_state():
            return make_train_state(TINY, jax.random.PRNGKey(1))

        def step_fn(state, batch):
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            return step(state, b)

        loop1 = FaultTolerantLoop(ckpt_dir=tmp_path, make_state=make_state,
                                  step_fn=step_fn, batch_at=data.batch_at,
                                  ckpt_every=5)
        r1 = loop1.run(10, log=lambda *_: None)
        assert r1.steps_done == 10

        loop2 = FaultTolerantLoop(ckpt_dir=tmp_path, make_state=make_state,
                                  step_fn=step_fn, batch_at=data.batch_at,
                                  ckpt_every=5)
        r2 = loop2.run(15, log=lambda *_: None)
        assert r2.steps_done == 15
        assert int(r2.final_state.step) == 15  # resumed, not restarted

"""kernels/inject_replay: the Pallas bit-sliced injection-replay kernel.

The contract chain under test (docs/kernels.md):
  Pallas replay == CompiledInjector.products accumulation
                == injection.injected_matmul_int (XLA outer-product path)
                == the 256x256 LUT-gather oracle,
bit for bit, for the default design point AND a raw DSE candidate
schedule; plus the inject_impl policy resolution and the weight-side
bit-pack cache (hit / refresh-on-update / GC eviction).

All Pallas calls pin ``interpret=True`` — the kernel contract is identical
under compiled Mosaic lowering on real TPUs.
"""
import gc

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import engine, lut  # noqa: E402
from repro.core.dse import lut_from_schedule, materialize, search_assignments  # noqa: E402
from repro.kernels import pallas_config  # noqa: E402
from repro.kernels.inject_replay import inject_replay_matmul  # noqa: E402
from repro.numerics import AMRNumerics, approx_matmul, injection  # noqa: E402
from repro.numerics.approx_matmul import matmul_amr_lut  # noqa: E402


def _oracle(table, ia, ib):
    return table[np.asarray(ia)[..., :, None],
                 np.asarray(ib)[..., None, :, :]].sum(axis=-2)


class TestInjectReplayKernel:
    @pytest.mark.parametrize("m,k,n", [
        (8, 16, 12),     # n smaller than one 32-lane word
        (32, 48, 64),    # multi-word, multi-block
        (4, 13, 45),     # prime K, ragged N: clamped tiles
        (64, 8, 96),
    ])
    def test_bitexact_vs_lut_oracle(self, m, k, n):
        inj = engine.get_injector(2, 8)
        table = lut.build_int8_lut(8).astype(np.int64)
        rng = np.random.default_rng(m + k + n)
        ia = jnp.asarray(rng.integers(0, 256, (m, k)))
        ib = jnp.asarray(rng.integers(0, 256, (k, n)))
        got = np.asarray(inject_replay_matmul(inj, ia, ib, interpret=True))
        np.testing.assert_array_equal(got.astype(np.int64), _oracle(table, ia, ib))

    def test_bitexact_vs_injector_products(self):
        """Kernel == pairwise CompiledInjector.products accumulation."""
        inj = engine.get_injector(2, 6)
        rng = np.random.default_rng(1)
        ia = jnp.asarray(rng.integers(0, 256, (6, 10)))
        ib = jnp.asarray(rng.integers(0, 256, (10, 37)))
        pa = jnp.broadcast_to(ia[:, :, None], (6, 10, 37))
        pb = jnp.broadcast_to(ib[None, :, :], (6, 10, 37))
        want = np.asarray(inj.products(pa, pb)).sum(axis=1)
        got = np.asarray(inject_replay_matmul(inj, ia, ib, interpret=True))
        np.testing.assert_array_equal(got, want)

    def test_bitexact_vs_xla_outer_path(self):
        inj = engine.get_injector(2, 8)
        rng = np.random.default_rng(2)
        ia = jnp.asarray(rng.integers(0, 256, (2, 5, 24)))  # lead batch dim
        ib = jnp.asarray(rng.integers(0, 256, (24, 40)))
        got = np.asarray(inject_replay_matmul(inj, ia, ib, interpret=True))
        want = np.asarray(injection.injected_matmul_int(inj, ia, ib))
        np.testing.assert_array_equal(got, want)

    def test_explicit_tiles_and_word_alignment(self):
        inj = engine.get_injector(2, 8)
        rng = np.random.default_rng(3)
        ia = jnp.asarray(rng.integers(0, 256, (6, 16)))
        ib = jnp.asarray(rng.integers(0, 256, (16, 64)))
        table = lut.build_int8_lut(8).astype(np.int64)
        got = np.asarray(inject_replay_matmul(inj, ia, ib, bm=3, bn=32, bk=4,
                                              interpret=True))
        np.testing.assert_array_equal(got.astype(np.int64), _oracle(table, ia, ib))
        # bn=16 divides the 64-column padded width but is NOT word-aligned
        with pytest.raises(ValueError, match="lane words"):
            inject_replay_matmul(inj, ia, ib, bn=16, interpret=True)

    def test_saturation_guard(self):
        inj = engine.get_injector(2, 8)
        k_bad = 2**31 // inj.max_abs_product + 1
        ia = jnp.zeros((1, k_bad), jnp.int32)
        ib = jnp.zeros((k_bad, 1), jnp.int32)
        with pytest.raises(ValueError, match="saturate") as ei:
            inject_replay_matmul(inj, ia, ib, interpret=True)
        assert str(k_bad) in str(ei.value)                    # names K
        assert str(inj.max_abs_product) in str(ei.value)      # and the bound


class TestInjectReplayDSECandidate:
    def _candidate(self):
        cands = search_assignments(2, 8, k=1, beam_width=8, branch_cap=4,
                                   max_nodes=2000)
        return materialize(cands[0])

    def test_kernel_matches_candidate_lut_export(self):
        sched = self._candidate()
        inj = engine.compile_injector(sched)
        table = lut_from_schedule(sched).astype(np.int64)
        rng = np.random.default_rng(4)
        ia = jnp.asarray(rng.integers(0, 256, (8, 12)))
        ib = jnp.asarray(rng.integers(0, 256, (12, 33)))
        got = np.asarray(inject_replay_matmul(inj, ia, ib, interpret=True))
        np.testing.assert_array_equal(got.astype(np.int64), _oracle(table, ia, ib))

    def test_policy_impls_agree_via_schedule_ref(self):
        """amr_inject through the registry: pallas impl == xla impl, bitwise,
        inside jit — the numerics-level form of the kernel contract."""
        handle = injection.register_schedule(self._candidate(),
                                             name="test:replay-cand")
        a = jax.random.normal(jax.random.PRNGKey(5), (4, 16), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(6), (16, 8), jnp.float32)
        outs = {}
        for impl in ("xla", "pallas"):
            nm = AMRNumerics("amr_inject", border=8, schedule_ref=handle,
                             inject_impl=impl)
            outs[impl] = np.asarray(jax.jit(
                lambda a, b, nm=nm: approx_matmul(a, b, nm))(a, b))
        np.testing.assert_array_equal(outs["pallas"], outs["xla"])

    def test_default_schedule_policy_matches_oracle(self):
        # both sides jitted: the bit-identity contract is per execution
        # regime (eager-vs-jit XLA fusion can flip the last rescale ulp on
        # unlucky operands, for the LUT oracle itself too)
        a = jax.random.normal(jax.random.PRNGKey(7), (4, 16), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(8), (16, 8), jnp.float32)
        want = np.asarray(jax.jit(lambda a, b: matmul_amr_lut(a, b, 8))(a, b))
        nm = AMRNumerics("amr_inject", border=8, inject_impl="pallas")
        got = np.asarray(jax.jit(lambda a, b: approx_matmul(a, b, nm))(a, b))
        np.testing.assert_array_equal(got, want)


class TestWeightPackCache:
    def test_hit_refresh_and_eviction(self):
        inj = engine.get_injector(2, 8)
        injection.WEIGHT_PACKS.clear()
        rng = np.random.default_rng(9)
        ib1 = jnp.asarray(rng.integers(0, 256, (8, 16)))
        p1 = injection.packed_weights(inj, ib1)
        assert injection.packed_weights(inj, ib1) is p1  # cache hit
        assert len(injection.WEIGHT_PACKS) == 1

        # "weights updated" = a NEW array object (jax arrays are immutable):
        # the pack must be refreshed, never served stale
        ib2 = jnp.asarray(rng.integers(0, 256, (8, 16)))
        p2 = injection.packed_weights(inj, ib2)
        assert p2 is not p1
        np.testing.assert_array_equal(np.asarray(p2),
                                      np.asarray(inj.pack_weights(ib2)))

        # and the matmul result reflects the NEW weights
        table = lut.build_int8_lut(8).astype(np.int64)
        ia = jnp.asarray(rng.integers(0, 256, (4, 8)))
        got = np.asarray(injection.injected_matmul_int(inj, ia, ib2))
        np.testing.assert_array_equal(got.astype(np.int64), _oracle(table, ia, ib2))

        # dead source arrays evict their entries (no stale id aliasing)
        assert len(injection.WEIGHT_PACKS) == 2
        del ib1, ib2, p1, p2
        gc.collect()
        assert len(injection.WEIGHT_PACKS) == 0

    def test_mutable_numpy_weights_never_cached(self):
        """An in-place update of a numpy weight array keeps its identity, so
        caching it would serve a stale pack — numpy operands must repack
        every call and always reflect the current values."""
        inj = engine.get_injector(2, 8)
        injection.WEIGHT_PACKS.clear()
        rng = np.random.default_rng(11)
        table = lut.build_int8_lut(8).astype(np.int64)
        ia = jnp.asarray(rng.integers(0, 256, (4, 8)))
        ib = np.ascontiguousarray(rng.integers(0, 256, (8, 16)))
        before = np.asarray(injection.injected_matmul_int(inj, ia, ib))
        assert len(injection.WEIGHT_PACKS) == 0  # numpy: never cached
        np.testing.assert_array_equal(before.astype(np.int64), _oracle(table, ia, ib))
        ib[:] = rng.integers(0, 256, (8, 16))  # mutate IN PLACE, same object
        after = np.asarray(injection.injected_matmul_int(inj, ia, ib))
        np.testing.assert_array_equal(after.astype(np.int64), _oracle(table, ia, ib))
        assert not np.array_equal(before, after)  # stale pack would reuse it

    def test_kernel_and_xla_share_the_cache(self):
        inj = engine.get_injector(2, 8)
        injection.WEIGHT_PACKS.clear()
        rng = np.random.default_rng(10)
        ia = jnp.asarray(rng.integers(0, 256, (4, 8)))
        ib = jnp.asarray(rng.integers(0, 256, (8, 16)))
        a = np.asarray(injection.injected_matmul_int(inj, ia, ib))
        assert len(injection.WEIGHT_PACKS) == 1
        b = np.asarray(inject_replay_matmul(inj, ia, ib, interpret=True))
        assert len(injection.WEIGHT_PACKS) == 1  # second impl reused the pack
        np.testing.assert_array_equal(a, b)
        injection.WEIGHT_PACKS.clear()


class TestInjectImplPolicy:
    def test_autodetect_per_backend(self, monkeypatch):
        monkeypatch.delenv(pallas_config.INJECT_IMPL_ENV, raising=False)
        for backend, impl in (("tpu", "pallas"), ("gpu", "xla"), ("cpu", "xla")):
            monkeypatch.setattr(pallas_config, "backend_kind", lambda b=backend: b)
            assert pallas_config.default_inject_impl() == impl, backend
            assert pallas_config.resolve_inject_impl(None) == impl, backend

    def test_env_override_wins(self, monkeypatch):
        monkeypatch.setenv(pallas_config.INJECT_IMPL_ENV, "pallas")
        assert pallas_config.default_inject_impl() == "pallas"
        monkeypatch.setenv(pallas_config.INJECT_IMPL_ENV, "xla")
        assert pallas_config.default_inject_impl() == "xla"
        monkeypatch.setenv(pallas_config.INJECT_IMPL_ENV, "bogus")
        with pytest.raises(ValueError):
            pallas_config.default_inject_impl()

    def test_explicit_impl_beats_env(self, monkeypatch):
        monkeypatch.setenv(pallas_config.INJECT_IMPL_ENV, "pallas")
        assert pallas_config.resolve_inject_impl("xla") == "xla"
        with pytest.raises(ValueError, match="inject_impl"):
            pallas_config.resolve_inject_impl("mosaic")

    def test_policy_field_stays_hashable(self):
        nm = AMRNumerics("amr_inject", border=8, inject_impl="pallas")
        assert hash(nm) != hash(AMRNumerics("amr_inject", border=8))

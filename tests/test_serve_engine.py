"""Continuous-batching serve engine: slot allocator properties, FIFO
fairness, prefill->slot handoff parity, and the core invariant — batched
slot-decode is bit-identical to decoding each request alone, across the
numerics modes and mixed request lengths."""
import json

import numpy as np
import pytest

import jax

from _hyp import given, settings, st
from _trace_utils import assert_single_trace
from repro.configs.base import ModelConfig
from repro.models import decode_step, init_params, prefill_with_cache
from repro.numerics import AMRNumerics
from repro.runtime.fault import Heartbeat, StragglerMonitor
from repro.serve import Request, RequestQueue, ServeEngine, SlotAllocator

CAP = 24
PROMPTS = [(5, 9, 2, 7), (3, 11, 4, 1, 8, 6), (13, 2), (9, 7, 9, 1, 2)]


def tiny_cfg(numerics=None):
    return ModelConfig(
        name="serve-test", family="dense", vocab=61, d_model=32, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
        numerics=numerics or AMRNumerics("exact"))


@pytest.fixture(scope="module")
def exact_setup():
    cfg = tiny_cfg()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------- allocator
class TestSlotAllocator:
    def test_basic_lifecycle(self):
        al = SlotAllocator(2)
        a, b = al.allocate(), al.allocate()
        assert {a, b} == {0, 1}
        assert al.allocate() is None  # full
        al.free(a)
        assert al.allocate() == a  # freed capacity is reusable

    def test_double_free_rejected(self):
        al = SlotAllocator(2)
        s = al.allocate()
        al.free(s)
        with pytest.raises(ValueError):
            al.free(s)

    def test_free_unallocated_rejected(self):
        with pytest.raises(ValueError):
            SlotAllocator(2).free(0)

    def test_zero_slots_rejected(self):
        with pytest.raises(ValueError):
            SlotAllocator(0)

    @given(st.lists(st.booleans(), max_size=60), st.integers(1, 5))
    @settings(max_examples=50)
    def test_never_double_allocates_and_frees_restore_capacity(self, ops, n):
        al = SlotAllocator(n)
        held = []
        for want_alloc in ops:
            if want_alloc:
                s = al.allocate()
                if len(held) == n:
                    assert s is None  # full allocator must refuse
                else:
                    assert s is not None and s not in held
                    held.append(s)
            elif held:
                al.free(held.pop(0))
            assert al.in_use == set(held)
            assert al.n_free == n - len(held)


# -------------------------------------------------------------------- queue
class TestRequestQueue:
    def test_fifo_order_and_uids(self):
        q = RequestQueue()
        uids = [q.submit(Request(prompt=(1,), max_new_tokens=1))
                for _ in range(5)]
        assert uids == sorted(uids)
        assert [q.pop().uid for _ in range(5)] == uids

    def test_request_validation(self):
        with pytest.raises(ValueError):
            Request(prompt=(), max_new_tokens=1)
        with pytest.raises(ValueError):
            Request(prompt=(1,), max_new_tokens=0)


# ------------------------------------------------------------------- engine
class TestServeEngine:
    def test_capacity_guard_rejects_oversized_request(self, exact_setup):
        cfg, params = exact_setup
        eng = ServeEngine(cfg, params, n_slots=1, capacity=8)
        with pytest.raises(ValueError, match="capacity"):
            eng.submit(Request(prompt=(1, 2, 3, 4), max_new_tokens=5))
        eng.submit(Request(prompt=(1, 2, 3, 4), max_new_tokens=4))  # fits

    def test_prefill_handoff_matches_manual_decode_loop(self, exact_setup):
        """Engine (1 slot) == hand-rolled prefill + scalar-cache decode."""
        cfg, params = exact_setup
        prompt, gen = PROMPTS[1], 5
        logits, cache = prefill_with_cache(
            cfg, params, jax.numpy.asarray(prompt, jax.numpy.int32)[None, :], CAP)
        tok = int(np.argmax(np.asarray(logits[:, -1])[0]))
        want = [tok]
        for _ in range(gen - 1):
            logits, cache = decode_step(
                cfg, params, jax.numpy.asarray([[tok]], jax.numpy.int32), cache)
            tok = int(np.argmax(np.asarray(logits[:, -1])[0]))
            want.append(tok)

        eng = ServeEngine(cfg, params, n_slots=1, capacity=CAP)
        eng.submit(Request(prompt=prompt, max_new_tokens=gen))
        [done] = eng.run()
        assert list(done.tokens) == want

    def test_fifo_admission_fairness(self, exact_setup):
        """With 1 slot, requests are admitted (and finish) in submit order."""
        cfg, params = exact_setup
        eng = ServeEngine(cfg, params, n_slots=1, capacity=CAP)
        uids = [eng.submit(Request(prompt=p, max_new_tokens=2))
                for p in PROMPTS]
        done = eng.run()
        assert [c.uid for c in done] == uids
        admits = sorted((c.t_admit, c.uid) for c in done)
        assert [u for _, u in admits] == uids  # admitted strictly in order

    def test_eviction_frees_slots_for_readmission(self, exact_setup):
        """More requests than slots: finished slots are reused, all complete."""
        cfg, params = exact_setup
        eng = ServeEngine(cfg, params, n_slots=2, capacity=CAP)
        for i, p in enumerate(PROMPTS * 2):
            eng.submit(Request(prompt=p, max_new_tokens=2 + i % 3))
        done = eng.run()
        assert len(done) == len(PROMPTS) * 2
        assert eng.slots.n_free == 2 and not eng.queue
        assert all(c.finish_reason == "length" for c in done)

    def test_eos_finishes_early(self, exact_setup):
        cfg, params = exact_setup
        eng = ServeEngine(cfg, params, n_slots=1, capacity=CAP)
        eng.submit(Request(prompt=PROMPTS[0], max_new_tokens=8))
        [ref] = eng.run()
        eos = ref.tokens[2]  # force EOS at the third generated token
        eng2 = ServeEngine(cfg, params, n_slots=1, capacity=CAP)
        eng2.submit(Request(prompt=PROMPTS[0], max_new_tokens=8, eos_id=eos))
        [done] = eng2.run()
        assert done.finish_reason == "eos"
        assert done.tokens == ref.tokens[:3]

    def test_no_recompile_across_admit_evict_patterns(self, exact_setup):
        """The masked decode step traces ONCE no matter which slots are live."""
        cfg, params = exact_setup
        eng = ServeEngine(cfg, params, n_slots=3, capacity=CAP)
        for i, p in enumerate(PROMPTS * 2):  # staggered finishes + readmits
            eng.submit(Request(prompt=p, max_new_tokens=1 + i % 4))
        eng.run()
        assert_single_trace(eng._decode, "masked decode step")

    def test_heartbeat_and_straggler_wiring(self, exact_setup, tmp_path):
        cfg, params = exact_setup
        hb = Heartbeat(tmp_path / "hb.json", interval_s=60.0)
        mon = StragglerMonitor(window=10, threshold=2.5)
        eng = ServeEngine(cfg, params, n_slots=2, capacity=CAP,
                          heartbeat=hb, straggler=mon)
        for p in PROMPTS:
            eng.submit(Request(prompt=p, max_new_tokens=3))
        done = eng.run()
        payload = json.loads((tmp_path / "hb.json").read_text())
        assert payload["completed"] == len(done)
        assert payload["queued"] == 0 and payload["active_slots"] == 0
        assert payload["step"] == eng.steps_done
        # every decode step was observed by the straggler monitor
        assert len(mon.times) == min(eng.steps_done, 10)


# ------------------------------------------------- batched-vs-solo exactness
def _serve_all(cfg, params, n_slots, gens):
    eng = ServeEngine(cfg, params, n_slots=n_slots, capacity=CAP,
                      record_logits=True)
    for p, g in zip(PROMPTS, gens):
        eng.submit(Request(prompt=p, max_new_tokens=g))
    return eng.run()


@pytest.mark.parametrize("numerics", [
    AMRNumerics("exact"),
    AMRNumerics("amr_lut", border=2),
    AMRNumerics("amr_inject", border=2),
    AMRNumerics("amr_kernel", border=2, rank=0),
], ids=lambda nm: nm.mode)
def test_batched_decode_bit_identical_to_solo(numerics):
    """THE serving invariant: a request decoded in a busy engine produces
    the same tokens AND bitwise-identical logits as the same request served
    alone — mixed prompt lengths, staggered finishes, slot reuse."""
    cfg = tiny_cfg(numerics)
    params = init_params(cfg, jax.random.PRNGKey(0))
    gens = [3, 5, 4, 3]
    batched = _serve_all(cfg, params, 3, gens)
    solo = _serve_all(cfg, params, 1, gens)
    assert len(batched) == len(solo) == len(PROMPTS)
    for b, s in zip(batched, solo):
        assert b.tokens == s.tokens
        for lb, ls in zip(b.logits, s.logits):
            assert float(np.max(np.abs(lb - ls))) == 0.0

"""Branch-and-bound DSE tests (paper Fig. 3): optimality + bound admissibility."""
from fractions import Fraction

from _hyp import given, settings, st
from repro.core import dse
from repro.core.cells import CELLS


class TestOptimality:
    @settings(max_examples=60, deadline=None)
    @given(
        st.integers(min_value=0, max_value=12),
        st.integers(min_value=0, max_value=6),
        st.integers(min_value=-8, max_value=8),
        st.booleans(),
    )
    def test_matches_brute_force(self, pos, neg, err4, exact_fa):
        """Bounds never prune the optimum (paper: 'do not prevent ... best')."""
        err_in = Fraction(err4, 4)
        res = dse.assign_column(pos, neg, err_in, allow_exact_fa=exact_fa)
        ref = dse.brute_force_column(pos, neg, err_in, allow_exact_fa=exact_fa)
        assert abs(res.err) == ref

    def test_consumption_accounting(self):
        res = dse.assign_column(7, 4, 0)
        used_p = sum(p for _, p, _ in res.cells)
        used_n = sum(n for _, _, n in res.cells)
        assert used_p <= 7 and used_n <= 4
        assert len(res.cells) == (7 + 4) // 3

    def test_zero_bits(self):
        res = dse.assign_column(0, 0, Fraction(1, 2))
        assert res.cells == [] and res.err == Fraction(1, 2)


class TestCompensation:
    def test_positive_error_compensated(self):
        """With a positive running error the DSE picks negative-error cells."""
        res = dse.assign_column(2, 1, Fraction(1, 2))
        # one FA consuming 2 pos + 1 neg: FA_PN2 (-0.5) is the unique optimum
        assert res.cells == [("FA_PN2", 2, 1)]
        assert res.err == 0

    def test_negative_error_compensated(self):
        res = dse.assign_column(1, 2, Fraction(-1, 2))
        assert res.cells == [("FA_NP2", 1, 2)]
        assert res.err == 0

    def test_all_posibits_forced(self):
        """Only posibits -> all FA_PP (+0.25 each), error fully determined."""
        res = dse.assign_column(9, 0, 0)
        assert all(c[0] == "FA_PP" for c in res.cells)
        assert res.err == Fraction(3, 4)

    def test_exact_fa_used_when_it_wins(self):
        """Border column: exact FA gives 0 error when approximates cannot."""
        res = dse.assign_column(3, 0, 0, allow_exact_fa=True)
        assert res.cells == [("FA", 3, 0)]
        assert res.err == 0

    def test_pruning_happens(self):
        """B&B visits far fewer nodes than brute force on a tall column."""
        res = dse.assign_column(24, 6, 0)
        # brute force would be ~6^10 ~ 6e7 nodes; bounded search must be tiny
        assert res.nodes < 50_000


class TestColumnProfile:
    """The exact DP oracle: brute-force parity, then scaling far beyond it."""

    @settings(max_examples=40, deadline=None)
    @given(
        st.integers(min_value=0, max_value=9),
        st.integers(min_value=0, max_value=6),
        st.booleans(),
    )
    def test_profile_minimum_matches_brute_force(self, pos, neg, exact_fa):
        prof = dse.column_profile(pos, neg, exact_fa)
        assert min(abs(s) for s in prof) == dse.brute_force_column(
            pos, neg, 0, allow_exact_fa=exact_fa)

    @settings(max_examples=40, deadline=None)
    @given(
        st.integers(min_value=0, max_value=9),
        st.integers(min_value=0, max_value=6),
    )
    def test_profile_representatives_are_consistent(self, pos, neg):
        """Each representative's cell errors sum to its profile key and its
        consumption fits the column."""
        for s, cells in dse.column_profile(pos, neg, False).items():
            total = sum(
                (Fraction(CELLS[name].avg_err).limit_denominator(4)
                 for name, _, _ in cells), Fraction(0))
            assert total == s
            assert sum(dp for _, dp, _ in cells) <= pos
            assert sum(dn for _, _, dn in cells) <= neg
            assert len(cells) == (pos + neg) // 3

    @settings(max_examples=60, deadline=None)
    @given(
        st.integers(min_value=0, max_value=36),
        st.integers(min_value=0, max_value=18),
        st.integers(min_value=-16, max_value=16),
        st.booleans(),
    )
    def test_assign_column_optimal_on_wide_grids(self, pos, neg, err4, exact_fa):
        """Admissibility at paper scale: the Fig. 3 B&B still finds the exact
        optimum on columns far too tall for ``brute_force_column`` (6^18
        leaves) — the DP profile is the tractable exhaustive oracle."""
        err_in = Fraction(err4, 4)
        res = dse.assign_column(pos, neg, err_in, allow_exact_fa=exact_fa)
        prof = dse.column_profile(pos, neg, exact_fa)
        assert abs(res.err) == min(abs(err_in + s) for s in prof)

    def test_topk_head_matches_optimum(self):
        for pos, neg, err in [(7, 4, 0), (12, 3, Fraction(1, 2)), (5, 5, -1)]:
            top = dse.assign_column_topk(pos, neg, err, k=3)
            best = dse.assign_column(pos, neg, err)
            assert abs(top[0].err) == abs(best.err)
            # ranked: non-decreasing |final error|, pairwise distinct cells
            errs = [abs(t.err) for t in top]
            assert errs == sorted(errs)
            assert len({t.err for t in top}) == len(top)

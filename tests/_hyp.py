"""Optional-hypothesis shim for property tests.

Re-exports the real ``given`` / ``settings`` / ``strategies`` API when
hypothesis is installed.  When it is not (e.g. the CI no-hypothesis job or
an offline checkout), provides stand-ins that mark the decorated tests as
skipped, so the remainder of the suite still collects and runs.

Usage in test modules::

    from _hyp import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)

        return deco

    def settings(*_args, **_kwargs):  # decorator-factory form only
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Any strategy constructor returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

"""Pallas kernel tests: shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut as lut_lib
from repro.kernels.amr_matmul.kernel import amr_matmul_int8
from repro.kernels.amr_matmul.ops import amr_matmul, lut_factors
from repro.kernels.amr_matmul.ref import ref_bitexact_int8, ref_lowrank_int8
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ref_ssd


class TestAMRMatmulKernel:
    @pytest.mark.parametrize("m,n,k,bm,bn,bk", [
        (128, 128, 128, 128, 128, 128),
        (256, 128, 256, 128, 128, 128),
        (128, 256, 384, 128, 128, 128),
        (256, 256, 256, 128, 256, 64),
    ])
    def test_matches_ref_lowrank(self, m, n, k, bm, bn, bk):
        rng = np.random.default_rng(m + n + k)
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        u, v = lut_factors(border=8, rank=8)
        got = amr_matmul_int8(a, b, u, v, bm=bm, bn=bn, bk=bk, interpret=True)
        want = ref_lowrank_int8(a, b, u, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2.0)

    @pytest.mark.parametrize("rank", [2, 16])
    def test_rank_sweep(self, rank):
        rng = np.random.default_rng(rank)
        a = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        u, v = lut_factors(border=8, rank=rank)
        got = amr_matmul_int8(a, b, u, v, interpret=True)
        want = ref_lowrank_int8(a, b, u, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2.0)

    def test_rank256_bitexact(self):
        """Full-rank kernel == bit-accurate AMR-MUL LUT accumulation."""
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        u, v = lut_factors(border=8, rank=256)
        got = np.asarray(amr_matmul_int8(a, b, u, v, interpret=True))
        want = ref_bitexact_int8(np.asarray(a), np.asarray(b), border=8)
        # fp32 accumulation of ~1e4-magnitude products over K=128: tiny rounding
        np.testing.assert_allclose(got, want.astype(np.float64), rtol=1e-5, atol=8.0)

    def test_float_wrapper(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        out = amr_matmul(a, b, border=8, rank=8, interpret=True)
        exact = a @ b
        rel = np.abs(np.asarray(out - exact)) / (np.abs(np.asarray(exact)) + 1e-2)
        assert np.median(rel) < 0.25  # border-8 approximate semantics

    def test_exact_border_is_exact_quantized(self):
        """border=None factors encode E=0: kernel == plain int8 matmul."""
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        u, v = lut_factors(border=None, rank=8)
        got = amr_matmul_int8(a, b, u, v, interpret=True)
        want = a.astype(jnp.float32) @ b.astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1.0)


class TestSSDKernel:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (1, 128, 2, 64, 64, 64),
        (2, 256, 4, 32, 16, 128),
        (1, 512, 1, 64, 128, 256),
        (2, 128, 8, 16, 32, 32),
    ])
    def test_matches_ref(self, B, S, H, P, N, chunk):
        rng = np.random.default_rng(B * S + H)
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
        a_log = jnp.asarray(rng.uniform(0.0, 1.5, (H,)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
        got = ssd_scan(x, dt, a_log, b, c, chunk, interpret=True)
        want = ref_ssd(x, dt, a_log, b, c, chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_dtype_bf16_inputs(self):
        rng = np.random.default_rng(9)
        B, S, H, P, N, chunk = 1, 128, 2, 32, 32, 64
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.bfloat16)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
        a_log = jnp.asarray(rng.uniform(0.0, 1.5, (H,)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.bfloat16)
        c = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.bfloat16)
        got = ssd_scan(x, dt, a_log, b, c, chunk, interpret=True)
        want = ref_ssd(x.astype(jnp.float32), dt, a_log, b.astype(jnp.float32),
                       c.astype(jnp.float32), chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0.05, atol=0.05)

    def test_state_carries_across_chunks(self):
        """A single impulse at t=0 must influence outputs in later chunks."""
        B, S, H, P, N, chunk = 1, 256, 1, 8, 8, 64
        x = jnp.zeros((B, S, H, P)).at[0, 0, 0, :].set(1.0)
        dt = jnp.full((B, S, H), 0.05, jnp.float32)
        a_log = jnp.asarray([0.1], jnp.float32)
        b = jnp.ones((B, S, H, N), jnp.float32)
        c = jnp.ones((B, S, H, N), jnp.float32)
        y = np.asarray(ssd_scan(x, dt, a_log, b, c, chunk, interpret=True))
        assert np.abs(y[0, chunk + 5]).sum() > 0  # crossed the chunk boundary

"""Pallas kernel tests: shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut as lut_lib
from repro.kernels import pallas_config
from repro.kernels.amr_matmul.kernel import amr_matmul_int8, amr_matmul_int8_lut
from repro.kernels.amr_matmul.ops import amr_matmul, lut_factors
from repro.kernels.amr_matmul.ref import ref_bitexact_int8, ref_lowrank_int8
from repro.kernels.amr_matmul.tiling import TileConfig, pick_tiles
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ref_ssd


class TestAMRMatmulKernel:
    @pytest.mark.parametrize("m,n,k,bm,bn,bk", [
        (128, 128, 128, 128, 128, 128),
        (256, 128, 256, 128, 128, 128),
        (128, 256, 384, 128, 128, 128),
        (256, 256, 256, 128, 256, 64),
    ])
    def test_matches_ref_lowrank(self, m, n, k, bm, bn, bk):
        rng = np.random.default_rng(m + n + k)
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        u, v = lut_factors(border=8, rank=8)
        got = amr_matmul_int8(a, b, u, v, bm=bm, bn=bn, bk=bk, interpret=True)
        want = ref_lowrank_int8(a, b, u, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2.0)

    @pytest.mark.parametrize("rank", [2, 16])
    def test_rank_sweep(self, rank):
        rng = np.random.default_rng(rank)
        a = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        u, v = lut_factors(border=8, rank=rank)
        got = amr_matmul_int8(a, b, u, v, interpret=True)
        want = ref_lowrank_int8(a, b, u, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2.0)

    def test_rank256_bitexact(self):
        """Full-rank kernel == bit-accurate AMR-MUL LUT accumulation."""
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        u, v = lut_factors(border=8, rank=256)
        got = np.asarray(amr_matmul_int8(a, b, u, v, interpret=True))
        want = ref_bitexact_int8(np.asarray(a), np.asarray(b), border=8)
        # fp32 accumulation of ~1e4-magnitude products over K=128: tiny rounding
        np.testing.assert_allclose(got, want.astype(np.float64), rtol=1e-5, atol=8.0)

    def test_float_wrapper(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        out = amr_matmul(a, b, border=8, rank=8, interpret=True)
        exact = a @ b
        rel = np.abs(np.asarray(out - exact)) / (np.abs(np.asarray(exact)) + 1e-2)
        assert np.median(rel) < 0.25  # border-8 approximate semantics

    def test_exact_border_is_exact_quantized(self):
        """border=None factors encode E=0: kernel == plain int8 matmul."""
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        u, v = lut_factors(border=None, rank=8)
        got = amr_matmul_int8(a, b, u, v, interpret=True)
        want = a.astype(jnp.float32) @ b.astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1.0)


class TestAMRMatmulLUTKernel:
    """Full-table LUT-gather variant: bit-exact AMR products."""

    @pytest.mark.parametrize("m,n,k,bm,bn,bk", [
        (128, 128, 128, 128, 128, 128),
        (256, 128, 256, 128, 128, 64),
        (128, 256, 384, 64, 128, 128),
    ])
    def test_bitexact_vs_ref(self, m, n, k, bm, bn, bk):
        """int32 kernel output == int64 per-element LUT accumulation, exactly."""
        rng = np.random.default_rng(m + n + k + 1)
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        table = lut_lib.table_array(8)
        got = np.asarray(amr_matmul_int8_lut(a, b, table, bm=bm, bn=bn, bk=bk,
                                             interpret=True))
        want = ref_bitexact_int8(np.asarray(a), np.asarray(b), border=8)
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_bitexact_vs_engine_replay(self):
        """Kernel products == the compiled schedule engine's replay, with the
        per-element products evaluated by the engine directly (not via the
        table), then accumulated host-side."""
        from repro.core.amrmul import AMRMultiplier

        m_, n_, k_ = 8, 8, 64
        rng = np.random.default_rng(5)
        a = rng.integers(-128, 128, (m_, k_))
        b = rng.integers(-128, 128, (k_, n_))
        mult = AMRMultiplier(2, border=8, engine="jax")
        aa = np.repeat(a[:, :, None], n_, axis=2)          # (M, K, N)
        bb = np.repeat(b.T[None, :, :], m_, axis=0).transpose(0, 2, 1)
        prods = mult.multiply_values(aa.reshape(-1), bb.reshape(-1))
        want = prods.reshape(m_, k_, n_).sum(axis=1).astype(np.int64)
        got = np.asarray(amr_matmul_int8_lut(
            jnp.asarray(a, jnp.int8), jnp.asarray(b, jnp.int8),
            lut_lib.table_array(8), bm=8, bn=8, bk=64, interpret=True))
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_exact_border_matches_int_matmul(self):
        rng = np.random.default_rng(6)
        a = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (128, 128)), jnp.int8)
        got = np.asarray(amr_matmul_int8_lut(a, b, lut_lib.table_array(None),
                                             interpret=True))
        want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_float_wrapper_method_lut(self):
        """method='lut' through the float wrapper == the jnp LUT-gather mode."""
        from repro.numerics.approx_matmul import matmul_amr_lut

        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        got = np.asarray(amr_matmul(a, b, border=8, method="lut", interpret=True))
        want = np.asarray(matmul_amr_lut(a, b, border=8))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


class TestPallasPolicy:
    """Interpret autodetection, env override, shared tiling table."""

    def test_cpu_autodetects_interpret(self, monkeypatch):
        if pallas_config.backend_kind() != "cpu":
            pytest.skip("autodetect assertions are for CPU-backed runs")
        monkeypatch.delenv(pallas_config.ENV_VAR, raising=False)
        assert pallas_config.default_interpret() is True
        assert pallas_config.resolve_interpret(None) is True

    def test_only_tpu_compiles_by_default(self, monkeypatch):
        monkeypatch.delenv(pallas_config.ENV_VAR, raising=False)
        for backend, interp in (("tpu", False), ("gpu", True), ("cpu", True)):
            monkeypatch.setattr(pallas_config, "backend_kind", lambda b=backend: b)
            assert pallas_config.default_interpret() is interp, backend

    def test_env_override_wins(self, monkeypatch):
        monkeypatch.setenv(pallas_config.ENV_VAR, "0")
        assert pallas_config.default_interpret() is False
        monkeypatch.setenv(pallas_config.ENV_VAR, "true")
        assert pallas_config.default_interpret() is True
        if pallas_config.backend_kind() == "cpu":
            monkeypatch.setenv(pallas_config.ENV_VAR, "auto")
            assert pallas_config.default_interpret() is True  # cpu fallback
        monkeypatch.setenv(pallas_config.ENV_VAR, "bogus")
        with pytest.raises(ValueError):
            pallas_config.default_interpret()

    def test_explicit_interpret_beats_env(self, monkeypatch):
        monkeypatch.setenv(pallas_config.ENV_VAR, "0")
        assert pallas_config.resolve_interpret(True) is True

    def test_pick_tiles_divides_shapes(self):
        for variant in ("lowrank", "lut", "inject_replay"):
            for (m, n, k) in [(128, 128, 128), (96, 64, 160), (100, 12, 7)]:
                t = pick_tiles(m, n, k, variant=variant)
                assert m % t.bm == 0 and n % t.bn == 0 and k % t.bk == 0

    def test_pick_tiles_overrides_and_backends(self):
        t = pick_tiles(256, 256, 256, variant="lut", backend="tpu")
        assert t == TileConfig(128, 128, 32)  # autotune entry, no clamping
        t = pick_tiles(256, 256, 256, variant="lut", backend="tpu", bk=256)
        assert t.bk == 256  # explicit override wins over the table
        t = pick_tiles(256, 256, 256, variant="inject_replay", backend="tpu")
        assert t == TileConfig(32, 128, 8)  # third-variant autotune entry

    def test_pick_tiles_rejects_non_divisor_overrides(self):
        """Regression: a bm/bn/bk override that does not divide the problem
        shape produced a grid missing a partial tile; now a clear error."""
        for variant in ("lowrank", "lut", "inject_replay"):
            for kwargs in ({"bm": 96}, {"bn": 100}, {"bk": 5}, {"bm": 0}):
                with pytest.raises(ValueError, match="does not tile"):
                    pick_tiles(128, 128, 128, variant=variant, **kwargs)
        # exact divisors still pass
        t = pick_tiles(128, 128, 128, variant="inject_replay", bm=64, bn=32, bk=2)
        assert t == TileConfig(64, 32, 2)


class TestSSDKernel:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (1, 128, 2, 64, 64, 64),
        (2, 256, 4, 32, 16, 128),
        (1, 512, 1, 64, 128, 256),
        (2, 128, 8, 16, 32, 32),
    ])
    def test_matches_ref(self, B, S, H, P, N, chunk):
        rng = np.random.default_rng(B * S + H)
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
        a_log = jnp.asarray(rng.uniform(0.0, 1.5, (H,)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
        got = ssd_scan(x, dt, a_log, b, c, chunk, interpret=True)
        want = ref_ssd(x, dt, a_log, b, c, chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_dtype_bf16_inputs(self):
        rng = np.random.default_rng(9)
        B, S, H, P, N, chunk = 1, 128, 2, 32, 32, 64
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.bfloat16)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
        a_log = jnp.asarray(rng.uniform(0.0, 1.5, (H,)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.bfloat16)
        c = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.bfloat16)
        got = ssd_scan(x, dt, a_log, b, c, chunk, interpret=True)
        want = ref_ssd(x.astype(jnp.float32), dt, a_log, b.astype(jnp.float32),
                       c.astype(jnp.float32), chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0.05, atol=0.05)

    def test_state_carries_across_chunks(self):
        """A single impulse at t=0 must influence outputs in later chunks."""
        B, S, H, P, N, chunk = 1, 256, 1, 8, 8, 64
        x = jnp.zeros((B, S, H, P)).at[0, 0, 0, :].set(1.0)
        dt = jnp.full((B, S, H), 0.05, jnp.float32)
        a_log = jnp.asarray([0.1], jnp.float32)
        b = jnp.ones((B, S, H, N), jnp.float32)
        c = jnp.ones((B, S, H, N), jnp.float32)
        y = np.asarray(ssd_scan(x, dt, a_log, b, c, chunk, interpret=True))
        assert np.abs(y[0, chunk + 5]).sum() > 0  # crossed the chunk boundary

"""repro.analysis: lint rules (fixture per rule), allowlist semantics, the
committed tree linting clean, and the jaxpr trace contracts on both a
retrace-hazardous toy step (flagged) and the real serve decode step
(passes), plus the int32-saturation proof's registry coverage."""
import numpy as np
import pytest

from repro.analysis.lint import Finding, load_allowlist, run_lint
from repro.analysis.trace_contract import (
    check_donation,
    check_prng_provenance,
    check_retrace_stability,
    count_random_prims,
    saturation_report,
)

REPO_ROOT = __import__("pathlib").Path(__file__).resolve().parents[1]


# --------------------------------------------------------------------------
# lint: one fixture per rule, asserting the stable ID and the span
# --------------------------------------------------------------------------

def _lint(tmp_path, rel, source):
    f = tmp_path / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(source)
    findings, _, _ = run_lint(tmp_path)
    # fixtures from earlier calls in the same tmp root stay on disk —
    # report only the file just written
    return [x for x in findings if x.path == rel.replace("\\", "/")]


def test_rpl001_mode_string_comparison(tmp_path):
    src = (
        "def pick(mode):\n"
        "    if mode == 'amr_inject':\n"
        "        return 1\n"
        "    return mode in ('exact', 'amr_lut')\n"
    )
    found = _lint(tmp_path, "src/repro/launch/pick.py", src)
    assert [(f.rule, f.line) for f in found] == [("RPL001", 2), ("RPL001", 4)]
    assert found[0].qualname == "pick"
    # the registry module itself is allowed to name its modes
    assert not _lint(tmp_path, "src/repro/numerics/reg.py", src)


def test_rpl001_exact_needs_mode_ident(tmp_path):
    # 'exact' against a non-mode identifier is not a mode comparison
    src = "def f(variant):\n    return variant == 'exact'\n"
    assert not _lint(tmp_path, "src/repro/launch/v.py", src)


def test_rpl002_raw_prngkey(tmp_path):
    src = ("import jax\n\n"
           "def mk(seed):\n"
           "    return jax.random.PRNGKey(seed)\n")
    found = _lint(tmp_path, "src/repro/serve/keys.py", src)
    assert [(f.rule, f.line, f.qualname) for f in found] == \
        [("RPL002", 4, "mk")]
    # the blessed chokepoint is exempt; split/fold_in derivation is fine
    assert not _lint(tmp_path, "src/repro/numerics/context.py", src)
    assert not _lint(tmp_path, "src/repro/serve/derive.py",
                     "import jax\n\ndef d(k):\n"
                     "    return jax.random.fold_in(k, 3)\n")


def test_rpl003_unlabeled_site(tmp_path):
    src = ("from repro.numerics import approx_matmul, dense\n\n"
           "def f(p, x, nm):\n"
           "    h = dense(x, p['w'], nm)\n"
           "    h = dense(h, p['o'], nm, 'mlp.out')\n"
           "    return approx_matmul(h, p['v'], nm, site='head')\n")
    found = _lint(tmp_path, "src/repro/models/blk.py", src)
    assert [(f.rule, f.line) for f in found] == [("RPL003", 4)]


def test_rpl003_raw_matmul_in_models(tmp_path):
    src = ("import jax.numpy as jnp\n"
           "from jax import lax\n\n"
           "def scores(q, k, probs, v):\n"
           "    s = jnp.einsum('bsd,btd->bst', q, k)\n"
           "    o = jnp.matmul(probs, v)\n"
           "    return s, lax.dot_general(o, v, (((1,), (0,)), ((), ())))\n")
    found = _lint(tmp_path, "src/repro/models/raw.py", src)
    assert [(f.rule, f.line) for f in found] == \
        [("RPL003", 5), ("RPL003", 6), ("RPL003", 7)]
    assert "bypasses the numerics seam" in found[0].message
    # raw matmuls OUTSIDE models/ are other layers' business (kernels,
    # optimizer, conformance harness) — only the model layer must route
    # its contractions through the seam
    assert not _lint(tmp_path, "src/repro/kernels/raw.py", src)
    # a bare-name einsum (no module root) is not attributable: skipped
    assert not _lint(tmp_path, "src/repro/models/bare.py",
                     "def f(a, b, einsum):\n    return einsum('ij,jk', a, b)\n")


def test_rpl004_pallas_captured_const(tmp_path):
    src = ("import jax.numpy as jnp\n"
           "from jax.experimental import pallas as pl\n\n"
           "LUT = jnp.arange(16)\n\n"
           "def make_kernel():\n"
           "    def kernel(x_ref, o_ref):\n"
           "        o_ref[...] = LUT[x_ref[...]]\n"
           "    return kernel\n")
    found = _lint(tmp_path, "src/repro/kernels/lutk.py", src)
    assert [(f.rule, f.line) for f in found] == [("RPL004", 7)]
    assert "LUT" in found[0].message
    # same shape with the table passed as a ref: clean
    ok = ("from jax.experimental import pallas as pl\n\n"
          "def make_kernel():\n"
          "    def kernel(x_ref, lut_ref, o_ref):\n"
          "        o_ref[...] = lut_ref[x_ref[...]]\n"
          "    return kernel\n")
    assert not _lint(tmp_path, "src/repro/kernels/okk.py", ok)


def test_rpl005_lru_cache_on_arrays(tmp_path):
    src = ("import functools\n\n"
           "@functools.lru_cache(maxsize=8)\n"
           "def pack(a, n: int):\n"
           "    return a * n\n")
    found = _lint(tmp_path, "src/repro/numerics/pack.py", src)
    # the finding anchors at the def line (decorators sit above it)
    assert [(f.rule, f.line, f.qualname) for f in found] == \
        [("RPL005", 4, "pack")]
    # static-metadata caching (ints / registry handles) is the sanctioned use
    ok = ("import functools\n\n"
          "@functools.lru_cache\n"
          "def injector(n_digits: int, border: int):\n"
          "    return n_digits + border\n")
    assert not _lint(tmp_path, "src/repro/numerics/okcache.py", ok)


def test_rpl006_nonatomic_write(tmp_path):
    src = ("import json\n\n"
           "def save(path, obj):\n"
           "    with open(path, 'w') as f:\n"
           "        json.dump(obj, f)\n")
    found = _lint(tmp_path, "src/repro/runtime/bad_save.py", src)
    assert [(f.rule, f.line, f.qualname) for f in found] == \
        [("RPL006", 4, "save")]
    ok = ("import json, os\n\n"
          "def save(path, obj):\n"
          "    with open(str(path) + '.tmp', 'w') as f:\n"
          "        json.dump(obj, f)\n"
          "    os.replace(str(path) + '.tmp', path)\n")
    assert not _lint(tmp_path, "src/repro/runtime/ok_save.py", ok)
    # the checkpoint module IS the protocol — exempt
    assert not _lint(tmp_path, "src/repro/ckpt/checkpoint.py", src)


def test_tests_dir_never_scanned(tmp_path):
    src = "def f(mode):\n    return mode == 'amr_inject'\n"
    assert not _lint(tmp_path, "tests/test_x.py", src)


# --------------------------------------------------------------------------
# allowlist semantics
# --------------------------------------------------------------------------

def test_allowlist_suppresses_and_goes_stale(tmp_path):
    f = tmp_path / "src/repro/serve/keys.py"
    f.parent.mkdir(parents=True)
    f.write_text("import jax\n\ndef mk(s):\n"
                 "    return jax.random.PRNGKey(s)\n")
    allow = tmp_path / ".analysis-allowlist"
    allow.write_text("# reviewed exception\n"
                     "RPL002 src/repro/serve/keys.py mk\n"
                     "RPL006 src/repro/gone.py save\n")
    entries = load_allowlist(allow)
    findings, suppressed, stale = run_lint(tmp_path, allowlist=entries)
    assert not findings
    assert [s.key() for s in suppressed] == \
        [("RPL002", "src/repro/serve/keys.py", "mk")]
    assert stale == ["RPL006 src/repro/gone.py save"]


def test_allowlist_rejects_malformed(tmp_path):
    bad = tmp_path / "al"
    bad.write_text("RPL002 only-two-fields\n")
    with pytest.raises(ValueError, match="malformed"):
        load_allowlist(bad)


def test_committed_tree_lints_clean():
    """The acceptance gate: the repo's own sources produce zero findings
    with the committed allowlist (RPL003 entries naming each reviewed
    deliberate-exact contraction in models/) — what CI's analysis job
    runs."""
    entries = load_allowlist(REPO_ROOT / ".analysis-allowlist")
    findings, _, stale = run_lint(REPO_ROOT, allowlist=entries)
    assert not findings, "\n".join(f.render() for f in findings)
    assert not stale


# --------------------------------------------------------------------------
# trace contracts: toy hazard flagged, the real decode step passes
# --------------------------------------------------------------------------

class _RebuiltTable:
    """Toy retrace hazard: rebuilds its gather table at every trace — the
    fresh numpy data is baked into the jaxpr as a const, so each distinct
    build recompiles (the rebuilt-lookup-table bug class)."""

    def __init__(self):
        self.version = 0

    def __call__(self, x):
        import jax.numpy as jnp
        self.version += 1
        table = np.arange(4, dtype=np.float32) * self.version
        return x + jnp.asarray(table)


def test_toy_retrace_hazard_flagged():
    import jax.numpy as jnp

    x = jnp.zeros((4,), jnp.float32)
    found = check_retrace_stability(_RebuiltTable(), (x,), (x,), "toy")
    assert len(found) == 1
    assert found[0].contract == "retrace"
    assert "const" in found[0].message


def test_well_behaved_step_passes():
    import jax.numpy as jnp

    def step(x, y):
        return x * 2.0 + y

    a = (jnp.ones((4,)), jnp.zeros((4,)))
    b = (jnp.full((4,), 7.0), jnp.full((4,), 3.0))
    assert check_retrace_stability(step, a, b, "ok") == []


def _serve_pieces(mode):
    import jax
    import jax.numpy as jnp

    from repro.conformance.matrix import tiny_config
    from repro.launch.specs import abstract_params
    from repro.models import init_cache
    from repro.train.steps import make_serve_step

    cfg = tiny_config("gemma3-1b", mode)
    params = abstract_params(cfg)
    cache = jax.eval_shape(lambda: init_cache(cfg, 2, 16, per_slot=True))

    def batch(seed):
        rng = np.random.default_rng(seed)
        return {"token": jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)),
                                     jnp.int32),
                "active": jnp.asarray(rng.integers(0, 2, (2,)) > 0)}

    return make_serve_step(cfg), params, cache, batch


def test_real_serve_decode_contracts():
    """The real serve decode step: jaxpr invariant to token/mask values
    (the structural _cache_size()==1 property) and the cache donation
    actually aliased in the lowering."""
    step, params, cache, batch = _serve_pieces("exact")
    assert check_retrace_stability(
        step, (params, cache, batch(0)), (params, cache, batch(1)),
        "serve") == []
    assert check_donation(step, (1,), (params, cache, batch(0)), "serve") == []


def test_prng_provenance_amr_noise():
    """The noise mode's decode step must draw PRNG bits AND every draw
    must derive through the blessed numerics key chain."""
    import jax

    step, params, cache, batch = _serve_pieces("amr_noise")
    jaxpr = jax.make_jaxpr(step)(params, cache, batch(0))
    assert count_random_prims(jaxpr) > 0
    assert check_prng_provenance(jaxpr, "serve", require_random=True) == []


def test_prng_provenance_flags_foreign_key():
    """A step drawing from a key made outside the numerics chain is
    caught: no blessed frame in the primitive's traceback."""
    import jax

    def rogue(x):
        key = jax.random.PRNGKey(0)  # test-only: the pattern under test
        return x + jax.random.normal(key, x.shape)

    jaxpr = jax.make_jaxpr(rogue)(np.zeros((3,), np.float32))
    found = check_prng_provenance(jaxpr, "rogue")
    assert found and all(f.contract == "prng" for f in found)


# --------------------------------------------------------------------------
# saturation proof: registry coverage, soundness, guard agreement
# --------------------------------------------------------------------------

def test_saturation_report_covers_registry():
    from repro.core import reduction
    from repro.numerics import injection

    handle = injection.register_schedule(reduction.get_schedule(2, 6),
                                         name="analysis-test:b6")
    try:
        findings, report = saturation_report(["gemma3-1b"], borders=(8,))
    finally:
        injection._SCHEDULES.pop(handle, None)
        injection._INJECTORS.pop(handle, None)
    assert findings == []
    assert handle in report["registered_handles"]
    labels = [r["schedule"] for r in report["schedules"]]
    assert handle in labels
    assert "default(n_digits=2, border=8)" in labels
    assert report["max_site_k"] > 0 and report["sites"]
    # the dense rep's activation×activation sites are probed too: their K
    # is a runtime quantity (attended length), broken out so deployments
    # can read max_safe_k_exact as a context-length bound
    assert {"attn.qk", "attn.pv"} <= set(report["activation_sites"])
    assert set(report["activation_sites"]) <= set(report["sites"])
    assert 0 < report["max_activation_k"] <= report["max_site_k"]
    for row in report["schedules"]:
        # soundness: the bit-weight bound dominates the exact bound, and
        # the proof agrees with the runtime guard's threshold
        assert row["symbolic_bound"] >= row["exact_bound"]
        assert row["max_safe_k_exact"] == (2**31 - 1) // row["exact_bound"]
        assert row["proved"] == (
            report["max_site_k"] * row["exact_bound"] < 2**31)
    assert report["all_proved"]


def test_saturation_probe_covers_activation_sites():
    """Every family's activation×activation seam sites reach the shape
    probe (QK^T/PV, grouped expert matmuls, the SSD state readout) — the
    proof covers activation-side Ks, not just weight-matmul Ks."""
    from repro.analysis.trace_contract import collect_site_ks
    from repro.conformance import ACTIVATION_SITES, REPRESENTATIVE

    for family in ("ssm", "moe"):
        ks = collect_site_ks([REPRESENTATIVE[family]])
        missing = ACTIVATION_SITES[family] - set(ks)
        assert not missing, (family, sorted(missing), sorted(ks))
        assert all(ks[s] > 0 for s in ACTIVATION_SITES[family])


def test_saturation_guard_message_names_schedule():
    """The runtime guard and the analyzer key their reports on the SAME
    schedule label (satellite: error message names the schedule handle)."""
    from repro.core import engine
    from repro.numerics.injection import check_accumulation_bound, schedule_label

    inj = engine.get_injector(2, 8)
    label = schedule_label(inj)
    assert label == "default(n_digits=2, border=8)"
    k_bad = (2**31 - 1) // inj.max_abs_product + 1
    with pytest.raises(ValueError, match="saturate") as ei:
        check_accumulation_bound(inj, k_bad)
    assert label in str(ei.value)
    with pytest.raises(ValueError) as ei:
        check_accumulation_bound(inj, k_bad, schedule="custom:demo")
    assert "custom:demo" in str(ei.value)

"""Substrate tests: checkpointing, data pipeline, fault tolerance, optimizers."""
import jax
import jax.numpy as jnp
import numpy as np

from _markers import requires_modern_jax
from repro.ckpt import CheckpointManager, restore_tree, save_tree
from repro.ckpt.checkpoint import latest_step
from repro.data import SyntheticLM
from repro.optim import adafactor_init, adafactor_update, adamw_init, adamw_update
from repro.runtime import FaultTolerantLoop, StragglerMonitor


class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(6.0).reshape(2, 3) + k,
                "nested": {"b": jnp.ones((4,), jnp.int32) * k}}

    def test_roundtrip(self, tmp_path):
        t = self._tree(3)
        path = save_tree(tmp_path, t, step=7)
        back = restore_tree(path, jax.eval_shape(lambda: t))
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial(self, tmp_path):
        save_tree(tmp_path, self._tree(), step=1)
        assert not list(tmp_path.glob(".tmp-*"))
        assert latest_step(tmp_path) == 1

    def test_manager_retention_and_latest(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=2)
        for s in (0, 10, 20, 30):
            m.save(self._tree(s), s)
        steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
        assert steps == [0, 20, 30]  # step 0 always kept
        got, step = m.restore_latest(jax.eval_shape(lambda: self._tree()))
        assert step == 30
        assert float(np.asarray(got["a"])[0, 0]) == 30.0

    def test_async_save(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save_async(self._tree(5), 5)
        m.wait()
        assert latest_step(tmp_path) == 5


class TestData:
    def test_deterministic_and_resumable(self):
        d = SyntheticLM(vocab=97, seq_len=16, batch=4, seed=3)
        b1 = d.batch_at(12)
        b2 = d.batch_at(12)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].shape == (4, 16)

    def test_targets_shifted(self):
        d = SyntheticLM(vocab=97, seq_len=16, batch=2)
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])

    def test_learnable_structure(self):
        """Next token is an affine function of current most of the time."""
        d = SyntheticLM(vocab=97, seq_len=64, batch=8, seed=0, noise=0.05)
        b = d.batch_at(0)
        a = 6364136223846793005 % 97 or 5
        c = 1442695040888963407 % 97 or 7
        pred = (a * b["tokens"].astype(np.int64) + c) % 97
        agree = (pred == b["targets"]).mean()
        assert agree > 0.85


class TestOptim:
    def _quad_problem(self, update, init):
        w = {"w": jnp.array([3.0, -2.0])}
        state = init(w)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
            w, state = update(g, state, w, 0.05, weight_decay=0.0)
        return float(jnp.abs(w["w"]).max())

    def test_adamw_converges(self):
        assert self._quad_problem(adamw_update, adamw_init) < 0.05

    def test_adafactor_converges(self):
        assert self._quad_problem(adafactor_update, adafactor_init) < 0.1

    def test_adamw_grad_clip(self):
        w = {"w": jnp.ones((3,))}
        st = adamw_init(w)
        g = {"w": jnp.full((3,), 1e9)}
        w2, _ = adamw_update(g, st, w, 0.1)
        assert np.isfinite(np.asarray(w2["w"], np.float32)).all()


class TestFaultTolerance:
    def test_straggler_monitor(self):
        m = StragglerMonitor(window=20, threshold=2.0)
        for i in range(10):
            m.observe(i, 1.0)
        assert m.observe(10, 5.0) is True
        assert m.observe(11, 1.1) is False
        assert len(m.flagged) == 1

    def test_loop_retries_from_checkpoint(self, tmp_path):
        """A transient step failure restarts from the last checkpoint."""
        calls = {"n": 0}

        def make_state():
            return {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

        def step_fn(state, batch):
            calls["n"] += 1
            if calls["n"] == 7:  # injected node failure
                raise RuntimeError("simulated device loss")
            x = state["x"] + batch["v"]
            return {"x": x, "step": state["step"] + 1}, {"loss": x}

        loop = FaultTolerantLoop(
            ckpt_dir=tmp_path, make_state=make_state, step_fn=step_fn,
            batch_at=lambda i: {"v": jnp.asarray(1.0)}, ckpt_every=2,
            max_retries=2)
        res = loop.run(total_steps=10, log=lambda *_: None)
        assert res.steps_done == 10
        assert res.restarts == 1
        assert float(res.final_state["x"]) == 10.0  # deterministic despite retry

    def test_elastic_remesh_hook_called(self, tmp_path):
        seen = {"n": 0}

        def make_state():
            return {"x": jnp.zeros(())}

        def remesh(state):
            seen["n"] += 1
            return state

        loop = FaultTolerantLoop(
            ckpt_dir=tmp_path, make_state=make_state,
            step_fn=lambda s, b: ({"x": s["x"] + 1}, {}),
            batch_at=lambda i: None, ckpt_every=2, remesh=remesh)
        loop.run(total_steps=4, log=lambda *_: None)
        # second run restores from ckpt -> remesh must fire (elastic restart)
        loop2 = FaultTolerantLoop(
            ckpt_dir=tmp_path, make_state=make_state,
            step_fn=lambda s, b: ({"x": s["x"] + 1}, {}),
            batch_at=lambda i: None, ckpt_every=2, remesh=remesh)
        res = loop2.run(total_steps=6, log=lambda *_: None)
        assert seen["n"] >= 1
        assert res.steps_done == 6


@requires_modern_jax
class TestCompressedCollective:
    def test_quant_psum_single_axis(self):
        """int8-compressed psum matches exact within quantization error."""
        from repro.parallel.collectives import compressed_psum_tree
        mesh = jax.make_mesh((1,), ("dp",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        g = {"w": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)}
        f = shard_map(lambda t: compressed_psum_tree(t, "dp"), mesh=mesh,
                      in_specs=(jax.tree.map(lambda _: P(), g),),
                      out_specs=jax.tree.map(lambda _: P(), g), check_rep=False)
        out = f(g)
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   atol=2.0 / 127)

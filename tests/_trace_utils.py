"""Shared trace-count helpers for single-trace (no-recompile) assertions.

The serving engine's core compile property — the masked decode step traces
ONCE no matter which slots are live or which per-layer policy resolves
inside it — is asserted from several test modules.  The probe lives here so
the `_cache_size` attribute poke (a private jax jit API that may be absent
on some versions) is written exactly once.

The STRUCTURAL form of the same property (jaxpr identical across operand
bindings, proven without running the engine) lives in
``repro.analysis.trace_contract``; this helper is the cheap empirical check
tests use after driving a real engine.
"""
from __future__ import annotations


def trace_count(jitted) -> int | None:
    """Number of traces a ``jax.jit`` callable has accumulated, or None when
    this jax version does not expose ``_cache_size``."""
    probe = getattr(jitted, "_cache_size", None)
    return None if probe is None else probe()


def assert_single_trace(jitted, what: str = "jitted callable") -> None:
    """Assert the callable was traced exactly once (skip silently when the
    jax version has no cache-size probe)."""
    n = trace_count(jitted)
    if n is not None:
        assert n == 1, f"{what}: expected exactly one trace, got {n}"

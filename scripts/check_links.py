"""Docs link checker: every internal markdown link must resolve (no fetches).

Scans README.md and docs/**/*.md for inline links/images. External schemes
(http/https/mailto) and pure-anchor links are skipped — CI must not touch
the network; links that escape the repo root (e.g. the CI badge's
``../../actions/...`` GitHub-relative path) are skipped too. Everything
else must exist on disk relative to the file that links it.

  python scripts/check_links.py            # from the repo root
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# inline links [text](target) and images ![alt](target); reference-style not used
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def iter_links(md: Path):
    in_fence = False
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            yield lineno, m.group(1)


def check(md: Path) -> list[str]:
    errors = []
    for lineno, target in iter_links(md):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        resolved = (md.parent / path).resolve()
        if ROOT not in resolved.parents and resolved != ROOT:
            continue  # escapes the repo (GitHub-relative badge links etc.)
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}:{lineno}: broken link -> {target}")
    return errors


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("**/*.md"))]
    errors = []
    n_links = 0
    for md in files:
        if not md.exists():
            errors.append(f"missing expected file: {md.relative_to(ROOT)}")
            continue
        n_links += sum(1 for _ in iter_links(md))
        errors.extend(check(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files, {n_links} links, {len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())

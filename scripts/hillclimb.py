"""§Perf hillclimb variants — each produces a tagged dry-run artifact.

Cells (chosen per the assignment from the baseline table):
  P — paper-technique: gemma-2b train_4k under AMR-MUL numerics
      (amr_lowrank rank sweep; the faithful LUT-gather form is analysed
      analytically in EXPERIMENTS.md — it cannot be materialised at shape).
  W — worst roofline fraction: mamba2-370m train_4k (SSD chunk-size sweep —
      intra-chunk quadratic work/traffic scales linearly with Q).
  C — most collective-bound: dbrx-132b train_4k (MoE dispatch sharding:
      replicate -> batch-local -> expert-parallel; microbatch count sweep).
  D — DSE-in-the-loop: like P, but the numerics border is *chosen by the
      measured Pareto sweep* (repro.core.dse.select_border) under an
      accuracy budget instead of being hard-coded.

  PYTHONPATH=src python scripts/hillclimb.py --variant P.r16
  PYTHONPATH=src python scripts/hillclimb.py --variant D.tight
  PYTHONPATH=src python scripts/hillclimb.py --list
"""
import argparse
import dataclasses
import os
import sys
from pathlib import Path

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402
from repro.numerics import AMRNumerics  # noqa: E402


def _gemma_amr(rank):
    cfg = get_config("gemma-2b")
    return dataclasses.replace(cfg, numerics=AMRNumerics("amr_lowrank", border=8, rank=rank))


def _gemma_amr_dse(max_mared, rank=16):
    """Pick the cheapest int8 (2-digit) border meeting the accuracy budget.

    The DSE Pareto sweep measures each candidate border's Monte-Carlo MARED
    through the fused engine dispatch and returns the lowest-energy design
    under ``max_mared`` — the hillclimb then dry-runs gemma-2b with that
    border's low-rank numerics.
    """
    from repro.core.dse import select_border

    border = select_border(
        2, (5, 6, 7, 8, 9, 10), max_err=max_mared, err_key="mared",
        n_samples=20000, beam_width=16, branch_cap=4, max_nodes=8000)
    print(f"# DSE picked border={border} for mared<={max_mared}")
    cfg = get_config("gemma-2b")
    return dataclasses.replace(
        cfg, numerics=AMRNumerics("amr_lowrank", border=border, rank=rank))


def _mamba_chunk(q):
    cfg = get_config("mamba2-370m")
    return dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=q))


def _dbrx_dispatch(mode):
    cfg = get_config("dbrx-132b")
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch_shard=mode))


def _moonshot_dispatch(mode):
    cfg = get_config("moonshot-v1-16b-a3b")
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch_shard=mode))


VARIANTS = {
    # --- P: the paper's technique as a matmul numerics policy
    "P.r64": ("gemma-2b", "train_4k", lambda: _gemma_amr(64), {}),
    "P.r16": ("gemma-2b", "train_4k", lambda: _gemma_amr(16), {}),
    "P.r8": ("gemma-2b", "train_4k", lambda: _gemma_amr(8), {}),
    "P.r4": ("gemma-2b", "train_4k", lambda: _gemma_amr(4), {}),
    # marginal-cost probe: 2 microbatches lowered in ONE graph — XLA hoists
    # the loop-invariant augmented-weight prep; step = base + 16 x marginal
    "P.r16_m2": ("gemma-2b", "train_4k_x2", lambda: _gemma_amr(16),
                 {"microbatch": "1"}),
    "P.exact_m2": ("gemma-2b", "train_4k_x2", lambda: get_config("gemma-2b"),
                   {"microbatch": "1"}),
    # --- W: SSD chunk sweep
    "W.q256": ("mamba2-370m", "train_4k", lambda: _mamba_chunk(256), {}),
    "W.q128": ("mamba2-370m", "train_4k", lambda: _mamba_chunk(128), {}),
    "W.q64": ("mamba2-370m", "train_4k", lambda: _mamba_chunk(64), {}),
    "W.q32": ("mamba2-370m", "train_4k", lambda: _mamba_chunk(32), {}),
    # --- C: MoE dispatch sharding + microbatch count (moonshot: the most
    # collective-bound baseline cell; dbrx variants cross-check)
    "C.replicate": ("moonshot-v1-16b-a3b", "train_4k",
                    lambda: _moonshot_dispatch("replicate"), {}),
    "C.batch": ("moonshot-v1-16b-a3b", "train_4k",
                lambda: _moonshot_dispatch("batch"), {}),
    "C.expert": ("moonshot-v1-16b-a3b", "train_4k",
                 lambda: _moonshot_dispatch("expert"), {}),
    "C.batch_mb4": ("moonshot-v1-16b-a3b", "train_4k",
                    lambda: _moonshot_dispatch("batch"), {"microbatch": "4"}),
    "C.local": ("moonshot-v1-16b-a3b", "train_4k",
                lambda: _moonshot_dispatch("local"), {}),
    "C.local_mb4": ("moonshot-v1-16b-a3b", "train_4k",
                    lambda: _moonshot_dispatch("local"), {"microbatch": "4"}),
    "C.dbrx_batch": ("dbrx-132b", "train_4k", lambda: _dbrx_dispatch("batch"), {}),
    "C.dbrx_local": ("dbrx-132b", "train_4k", lambda: _dbrx_dispatch("local"), {}),
    # --- D: numerics border chosen by the measured-Pareto DSE
    "D.tight": ("gemma-2b", "train_4k", lambda: _gemma_amr_dse(2e-2), {}),
    "D.loose": ("gemma-2b", "train_4k", lambda: _gemma_amr_dse(1e-1), {}),
    # gemma-2b exact baseline with fewer microbatches (FSDP re-gather tax)
    "G.mb4": ("gemma-2b", "train_4k", lambda: get_config("gemma-2b"),
              {"microbatch": "4"}),
    "G.mb1": ("gemma-2b", "train_4k", lambda: get_config("gemma-2b"),
              {"microbatch": "1"}),
}


def _zero1(arch, **extra):
    cfg = get_config(arch)
    return dataclasses.replace(cfg, param_shard="zero1", **extra)


VARIANTS.update({
    "W.zero1": ("mamba2-370m", "train_4k", lambda: _zero1("mamba2-370m"), {}),
    "G.zero1": ("gemma-2b", "train_4k", lambda: _zero1("gemma-2b"), {}),
    "P.r16_zero1": ("gemma-2b", "train_4k",
                    lambda: dataclasses.replace(_gemma_amr(16), param_shard="zero1"),
                    {}),
})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    if args.list or not args.variant:
        for k, (a, s, _, kw) in VARIANTS.items():
            print(f"{k}: {a} x {s} {kw}")
        return
    arch, shape, cfg_fn, kw = VARIANTS[args.variant]
    run_cell(arch, shape, False, Path(args.out),
             microbatch=kw.get("microbatch", "auto"),
             cfg_override=cfg_fn(), tag_suffix=f"__{args.variant}")


if __name__ == "__main__":
    main()
"""Model-level numerics policy search — per-layer (site, border) assignment.

Reworks the hillclimb "D" arm from *one global border for the whole model*
into a heterogeneous per-(layer, site) assignment searched end to end
(docs/dse.md#model-level-search):

  1. multiplier-level Pareto sweep (``core.dse.pareto_sweep``) measures the
     border family and ``frontier_choices`` turns the frontier into
     assignable design points with registered injection schedules;
  2. a short real training run produces non-degenerate activations;
  3. ``measure_sensitivity`` scores every (site, layer) coordinate with the
     exact-error audit in ONE instrumented forward/backward;
  4. ``search_model_policy`` hill-climbs assignments under a per-token
     energy budget and must strictly dominate the best feasible uniform;
  5. the winning policy is saved as a JSON artifact every launcher loads
     via ``--policy-file`` (docs/numerics.md#policy-files).

  PYTHONPATH=src python scripts/policy_search.py --arch gemma-2b \
      --n-layers 4 --train-steps 20 --budget-tier 2 \
      --out experiments/policy_gemma.json

``--variance-scored`` additionally routes the multiplier search itself
through the measured-variance score hook (``pareto.measured_score_hook``)
instead of the analytic literal count.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--n-layers", type=int, default=0,
                    help="override the reduced config's layer count (0 = keep)")
    ap.add_argument("--borders", default="4,5,6,7,8,9,10",
                    help="comma list of candidate borders for the 2-digit sweep")
    ap.add_argument("--samples", type=int, default=4000,
                    help="Monte-Carlo samples per sweep candidate")
    ap.add_argument("--variance-scored", action="store_true",
                    help="rank multiplier candidates by measured std_ed "
                         "instead of the analytic literal proxy")
    ap.add_argument("--train-steps", type=int, default=20,
                    help="short training run before sensitivity scoring")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-tier", type=int, default=-1,
                    help="pin the energy budget at this frontier tier's "
                         "uniform energy (index into the energy-sorted "
                         "choices; -1 = use --budget-frac)")
    ap.add_argument("--budget-frac", type=float, default=0.7,
                    help="budget as a fraction of the all-exact energy "
                         "(only when --budget-tier is -1)")
    ap.add_argument("--max-moves", type=int, default=8)
    ap.add_argument("--beam", type=int, default=3)
    ap.add_argument("--out", default="experiments/policy_search.json")
    return ap.parse_args(argv)


def main(argv=None) -> None:
    args = parse_args(argv)
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_reduced_config
    from repro.core.dse import pareto
    from repro.core.dse.model_policy import (frontier_choices,
                                             measure_sensitivity,
                                             policy_energy,
                                             search_model_policy,
                                             site_mac_counts)
    from repro.data import SyntheticLM
    from repro.launch.cli import policy_label
    from repro.numerics import save_policy
    from repro.train.steps import make_train_state, make_train_step

    borders = tuple(int(b) for b in args.borders.split(",") if b.strip())

    # 1. multiplier-level sweep -> assignable frontier tiers
    t0 = time.time()
    sweep_kwargs = dict(k=1, n_samples=args.samples, beam_width=8,
                        branch_cap=3, max_nodes=2000)
    if args.variance_scored:
        sweep_kwargs["score_hook"] = pareto.measured_score_hook(
            n_samples=args.samples)
    points = pareto.pareto_sweep(2, borders, **sweep_kwargs)
    choices = frontier_choices(points)
    print(f"[policy-search] sweep: {len(points)} candidates -> "
          f"{len(choices)} frontier tiers in {time.time() - t0:.0f}s")
    for c in choices:
        print(f"  {c.label:14s} energy/mac {c.energy_per_mac:8.4f} "
              f"err {c.err:.4g}")

    # 2. short real training run (non-degenerate activations for scoring)
    cfg = get_reduced_config(args.arch)
    if args.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=args.n_layers)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                       seed=args.seed)
    state = make_train_state(cfg, jax.random.PRNGKey(args.seed))
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=5,
                                   total_steps=max(args.train_steps, 1)),
                   donate_argnums=(0,))
    t0 = time.time()
    for i in range(args.train_steps):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, b)
    if args.train_steps:
        print(f"[policy-search] trained {args.train_steps} steps "
              f"(loss {float(metrics['loss']):.4f}) in {time.time() - t0:.0f}s")
    params = state.params
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    # 3. per-(site, layer) audit sensitivity, one forward/backward
    t0 = time.time()
    sens = measure_sensitivity(cfg, params, batch)
    print(f"[policy-search] sensitivity: {len(sens.coords)} coords, "
          f"probe loss {sens.loss:.4f} in {time.time() - t0:.0f}s")

    # 4. budget + assignment hill-climb
    unit_macs = [m for sites in site_mac_counts(cfg) for _, m in sites]
    budget = None
    if args.budget_tier >= 0:
        budget = policy_energy(unit_macs, [args.budget_tier] * len(unit_macs),
                               choices)
        print(f"[policy-search] budget pinned at uniform "
              f"{choices[args.budget_tier].label}: {budget:.4g}")
    t0 = time.time()
    result = search_model_policy(
        cfg, params, batch, choices, budget=budget,
        budget_frac=args.budget_frac, sensitivity=sens,
        max_moves=args.max_moves, beam=args.beam)
    best_u = result.best_uniform
    dominates = (result.energy <= best_u["energy"]
                 and result.fidelity < best_u["fidelity"])
    print(f"[policy-search] search: {len(result.history)} accepted moves "
          f"in {time.time() - t0:.0f}s")
    for mv in result.history:
        print(f"  + {mv['move']:32s} energy {mv['energy']:.4g} "
              f"fidelity {mv['fidelity']:.4g}")
    print(f"[policy-search] searched {policy_label(result.policy)}: "
          f"energy {result.energy:.4g} fidelity {result.fidelity:.4g}")
    print(f"[policy-search] best uniform {best_u['label']}: "
          f"energy {best_u['energy']:.4g} fidelity {best_u['fidelity']:.4g}")
    print(f"[policy-search] strictly dominates best uniform: {dominates}")

    # 5. the saved artifact is what --policy-file loads
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    save_policy(result.policy, out, meta={
        "arch": args.arch, "n_layers": cfg.n_layers,
        "budget": result.budget, "energy": result.energy,
        "fidelity": result.fidelity, "loss": result.loss,
        "exact_energy": result.exact_energy,
        "dominates_best_uniform": dominates,
        "best_uniform": best_u,
        "uniform": result.uniform,
        "history": result.history,
        "choices": [c.label for c in result.choices],
    })
    print(f"[policy-search] wrote {out}")
    print(json.dumps({"energy": result.energy, "fidelity": result.fidelity,
                      "dominates": dominates}, indent=1))


if __name__ == "__main__":
    main()

"""Inject the §Roofline table (from dry-run artifacts) into EXPERIMENTS.md."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.roofline import table  # noqa: E402

REPO = Path(__file__).resolve().parents[1]


def build_table() -> str:
    rows = table(REPO / "experiments/dryrun", mesh_filter=None)
    singles = [r for r in rows if r["cell"].endswith("single")]
    multis = [r for r in rows if r["cell"].endswith("multi")]

    out = ["### Single-pod (16x16) — full roofline",
           "",
           "| cell | t_comp s | t_mem s | t_coll s | bottleneck | useful "
           "| roofline_frac | peak GB | fits |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(singles, key=lambda r: r["cell"]):
        if "t_compute_s" in r:
            out.append(
                f"| {r['cell'][:-8]} | {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
                f"| {r['t_collective_s']:.2e} | {r['bottleneck']} | {r['useful_ratio']:.2f} "
                f"| **{r['roofline_fraction']:.3f}** | {r['peak_gb']:.1f} | "
                f"{'yes' if r['fits'] else 'NO'} |")
        else:
            out.append(f"| {r['cell'][:-8]} | skip | | | | | | | ({r.get('reason','')[:60]}) |")

    n_ok = sum('t_compute_s' in r for r in multis)
    n_fit = sum(r.get('fits') is True for r in multis if 't_compute_s' in r)
    n_skip = sum(r.get('status') == 'skipped' for r in multis)
    out += ["", "### Multi-pod (2x16x16) — deployment-compile proof",
            "",
            f"All runnable cells compile with the `pod` axis sharded: "
            f"**{n_ok} ok / {n_skip} documented skips / 0 errors**; "
            f"{n_fit}/{n_ok} fit 16 GB HBM "
            f"(the exceptions are listed per cell in `experiments/dryrun/`).",
            "",
            "| cell | peak GB | fits |", "|---|---|---|"]
    import json
    for f in sorted((REPO / "experiments/dryrun").glob("*__multi.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            out.append(f"| {r['cell'][:-7]} | {r['memory']['peak_bytes']/2**30:.1f} | "
                       f"{'yes' if r.get('fits') else 'NO'} |")
    return "\n".join(out)


def main() -> None:
    md = (REPO / "EXPERIMENTS.md").read_text()
    marker = "<!-- ROOFLINE_TABLE -->"
    start = md.index(marker)
    end = md.index("## §Perf")
    md = md[:start] + marker + "\n\n" + build_table() + "\n\n" + md[end:]
    (REPO / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md §Roofline updated")


if __name__ == "__main__":
    main()

"""CI bench-regression gate: diff fresh BENCH_*.json against baselines.

Accuracy fields of the benchmark artifacts are *deterministic* — they come
from bit-exact integer replays over seeded operand streams — so any drift
is a real numerics regression, not noise.  This script compares a freshly
produced ``BENCH_kernel.json`` / ``BENCH_dse.json`` / ``BENCH_train.json``
/ ``BENCH_inject.json`` against the committed baselines under
``benchmarks/baselines/`` and fails the build on:

  * schema or row-set mismatches (missing/extra sweep points),
  * any change in an error field (``max_abs_err_vs_amr``, ``mred``/``mared``/
    ``nmed``, ``expected_error``) or exactness flag (``bit_exact_vs_amr``,
    ``replay_match``, ``frontier``, ``complete``) — float-path kernel rows
    (low-rank, not bit-exact) compare within ``FLOAT_RTOL`` to tolerate
    BLAS/SVD last-ulp variation across platforms; integer-exact rows must
    match exactly,
  * for the train artifact: any flip of the bit-consistency fields
    (``bit_exact``, ``max_abs_diff`` — the amr_inject-vs-amr_lut oracle
    agreement is integer-derived, so it must be EXACTLY 0.0) or of the
    ``loss_finite`` / ``grad_finite`` flags,
  * for the inject artifact: any flip of ``bit_exact_vs_lut`` /
    ``max_abs_diff`` on any replay implementation row — every impl
    (pairs / xla / xla_cached / pallas) must agree with the LUT-gather
    oracle bit for bit,
  * for the matrix artifact (cross-architecture conformance): any flip of
    the per-arm invariants — train finiteness/non-degeneracy, inject-vs-LUT
    bit-identity (``max_abs_diff`` is in integer grid-step units, so it
    must be EXACTLY 0.0), decode-parity ``within_tol``, amr_noise
    reproducibility/decorrelation, restart loss-stream ``bit_exact`` and
    ``tmp_cleaned``; losses and parity diffs are advisory,
  * for the serve artifact: any flip of the continuous-batching exactness
    fields (``bit_exact`` / ``tokens_match`` / ``max_abs_diff`` — slot-
    batched decode must equal solo decode bitwise) or of ``complete`` /
    ``requests`` / ``tokens`` on the throughput rows; serve latency and
    tokens/s are advisory,
  * for the attn artifact (fused-attention kernel): any flip of
    ``bit_exact`` / ``max_abs_diff`` on any (method, shape, border) row —
    the fused Pallas kernel replays the SAME quantized operands the
    unfused seam sees, so fused-vs-seam agreement must stay exactly 0.0,
  * for the policy artifact (model-level numerics-policy search): any flip
    of a ``uniform_parity`` row (``UniformPolicy`` must trace bit-for-bit
    what the bare ``AMRNumerics`` traces), any drift of the frontier tiers
    or uniform energies (literal-count + seeded integer-replay derived),
    or the ``searched`` row's ``dominates_best_uniform`` flag dropping —
    per-policy fidelities/losses ride on float matmuls and are advisory.

Timings (``us_per_call``, ``s_per_step``, ``wall_clock_s``), energy-model
outputs (``energy_pj``), search-effort counters (``nodes``) and train LOSS
trajectories (``first_loss``/``final_loss`` ride on float matmuls whose
last ulp is platform/BLAS dependent) are ADVISORY: drift is reported but
never fails the gate.

  PYTHONPATH=src python scripts/check_bench.py                 # all artifacts
  python scripts/check_bench.py BENCH_dse.json                 # just one
  python scripts/check_bench.py --fresh-dir . --baseline-dir benchmarks/baselines

Exit status: 0 clean, 1 regression, 2 usage/IO error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_ARTIFACTS = ("BENCH_kernel.json", "BENCH_dse.json", "BENCH_train.json",
                     "BENCH_inject.json", "BENCH_serve.json",
                     "BENCH_matrix.json", "BENCH_policy.json",
                     "BENCH_attn.json")
FLOAT_RTOL = 1e-6  # float-path (non-bit-exact) kernel error rows only


def _row_key(schema: str, row: dict) -> tuple:
    if schema.startswith("BENCH_kernel/"):
        return (row["variant"], row["border"], row["rank"],
                row["m"], row["n"], row["k"])
    if schema.startswith("BENCH_dse/"):
        return (row["n_digits"], row["border"], row["candidate"])
    if schema.startswith("BENCH_train/"):
        return (row["mode"], row.get("case"), row.get("schedule"),
                row.get("border"))
    if schema.startswith("BENCH_inject/"):
        return (row["impl"], row["schedule"], row["m"], row["n"], row["k"])
    if schema.startswith("BENCH_serve/"):
        return (row["kind"], row["mode"], row["concurrency"])
    if schema.startswith("BENCH_matrix/"):
        return (row["kind"], row.get("arch"), row.get("mode"),
                row.get("schedule"))
    if schema.startswith("BENCH_policy/"):
        return (row["kind"], row.get("mode") or row.get("label"))
    if schema.startswith("BENCH_attn/"):
        return (row["method"], row["border"],
                row["g"], row["m"], row["d"], row["t"], row["p"])
    raise ValueError(f"unknown artifact schema {schema!r}")


def _gated_fields(schema: str, row: dict) -> list[tuple[str, bool]]:
    """(field, exact) pairs the gate enforces for one row."""
    if schema.startswith("BENCH_kernel/"):
        integer_exact = row["variant"] in ("exact", "lut") or row["bit_exact_vs_amr"]
        return [("bit_exact_vs_amr", True),
                ("max_abs_err_vs_amr", integer_exact)]
    if schema.startswith("BENCH_train/"):
        if row.get("mode") == "consistency":
            # integer-derived oracle agreement: exactly equal or regressed
            return [("bit_exact", True), ("max_abs_diff", True)]
        return [("loss_finite", True), ("grad_finite", True),
                ("params_finite", True)]
    if schema.startswith("BENCH_inject/"):
        # integer-derived oracle agreement: exactly equal or regressed
        return [("bit_exact_vs_lut", True), ("max_abs_diff", True)]
    if schema.startswith("BENCH_matrix/"):
        kind = row.get("kind")
        if kind == "train":
            return [("loss_finite", True), ("grad_finite", True),
                    ("nondegenerate", True)]
        if kind == "inject_audit":
            # grid-step units (integer-derived): exactly 0.0 or regressed
            return [("bit_exact", True), ("max_abs_diff", True),
                    ("sites", True)]
        if kind == "decode_parity":
            return [("applicable", True), ("within_tol", True)]
        if kind == "noise_decorrelation":
            return [("reproducible", True), ("steps_decorrelated", True)]
        # restart: float32 loss streams must stay bitwise equal across the
        # kill/resume boundary, and restore must sweep .tmp debris
        return [("bit_exact", True), ("max_abs_diff", True),
                ("tmp_cleaned", True), ("resumed_from", True)]
    if schema.startswith("BENCH_serve/"):
        if row.get("kind") == "bit_exact":
            # batched-vs-solo decode agreement is integer/bit-derived:
            # token streams AND logit streams must match exactly
            return [("bit_exact", True), ("tokens_match", True),
                    ("max_abs_diff", True)]
        return [("complete", True), ("requests", True), ("tokens", True)]
    if schema.startswith("BENCH_policy/"):
        kind = row.get("kind")
        if kind == "uniform_parity":
            # the policy indirection may NEVER change numerics: UniformPolicy
            # must trace bit-for-bit what the bare AMRNumerics traces
            return [("bit_exact", True), ("tokens_match", True),
                    ("max_abs_diff", True)]
        if kind == "frontier":
            # literal-count energies + seeded integer-replay MC: deterministic
            return [("energy_per_mac", True), ("err", True)]
        if kind == "uniform":
            return [("energy", True), ("feasible", True)]
        # searched: the per-layer assignment may differ across platforms
        # (fidelity evals ride on float matmuls) but it must always beat the
        # best feasible uniform point on fidelity at no more energy
        return [("dominates_best_uniform", True)]
    if schema.startswith("BENCH_attn/"):
        # fused-kernel-vs-seam agreement is integer/bit-derived (the fused
        # kernel replays the SAME quantized operands the seam sees): the
        # diff must stay EXACTLY 0.0 on every backend
        return [("bit_exact", True), ("max_abs_diff", True)]
    return [("expected_error", True), ("mred", True), ("mared", True),
            ("nmed", True), ("replay_match", True), ("frontier", True),
            ("complete", True)]


def _advisory_fields(schema: str) -> list[str]:
    if schema.startswith("BENCH_kernel/"):
        return ["us_per_call"]
    if schema.startswith("BENCH_train/"):
        return ["first_loss", "final_loss", "s_per_step"]
    if schema.startswith("BENCH_inject/"):
        return ["us_per_call"]
    if schema.startswith("BENCH_serve/"):
        return ["p50_latency_ms", "p99_latency_ms", "tokens_per_s",
                "steady_tokens_per_s"]
    if schema.startswith("BENCH_matrix/"):
        return ["first_loss", "final_loss", "parity_diff"]
    if schema.startswith("BENCH_policy/"):
        return ["fidelity", "loss", "moves"]
    if schema.startswith("BENCH_attn/"):
        return ["us_per_call", "ref_us_per_call"]
    return ["energy_pj", "nodes"]


def _close(a, b) -> bool:
    if a == b:
        return True
    try:
        return abs(a - b) <= FLOAT_RTOL * max(abs(a), abs(b))
    except TypeError:
        return False


def compare_artifacts(fresh: dict, baseline: dict, name: str) -> tuple[list[str], list[str]]:
    """Returns (errors, advisories) for one fresh/baseline artifact pair."""
    errors: list[str] = []
    advisories: list[str] = []
    schema = baseline.get("schema", "")
    if fresh.get("schema") != schema:
        return [f"{name}: schema {fresh.get('schema')!r} != baseline {schema!r}"], []
    for meta in ("samples", "quick", "engine", "steps", "border", "config",
                 "gen", "capacity"):
        if meta in baseline and fresh.get(meta) != baseline[meta]:
            errors.append(f"{name}: run config {meta}={fresh.get(meta)!r} "
                          f"!= baseline {baseline[meta]!r}")

    fresh_rows = {_row_key(schema, r): r for r in fresh.get("results", [])}
    base_rows = {_row_key(schema, r): r for r in baseline.get("results", [])}
    for key in sorted(base_rows.keys() - fresh_rows.keys(), key=repr):
        errors.append(f"{name}: sweep point {key} missing from fresh run")
    for key in sorted(fresh_rows.keys() - base_rows.keys(), key=repr):
        errors.append(f"{name}: unexpected new sweep point {key} "
                      f"(refresh the baseline deliberately)")

    for key in sorted(fresh_rows.keys() & base_rows.keys(), key=repr):
        got, want = fresh_rows[key], base_rows[key]
        for field, exact in _gated_fields(schema, want):
            g, w = got.get(field), want.get(field)
            ok = (g == w) if exact else _close(g, w)
            if not ok:
                errors.append(f"{name}: {key} {field} drifted: "
                              f"{g!r} != baseline {w!r}")
        for field in _advisory_fields(schema):
            g, w = got.get(field), want.get(field)
            if isinstance(g, (int, float)) and isinstance(w, (int, float)) \
                    and w and abs(g - w) / abs(w) > 0.25:
                advisories.append(f"{name}: {key} {field} {w} -> {g} "
                                  f"({(g - w) / w:+.0%}, advisory)")
    return errors, advisories


def check_pair(fresh_path: Path, baseline_path: Path) -> tuple[list[str], list[str]]:
    if not baseline_path.exists():
        return [f"baseline {baseline_path} missing — commit one "
                f"(run the bench and copy the artifact)"], []
    if not fresh_path.exists():
        return [f"fresh artifact {fresh_path} missing — did the bench run?"], []
    fresh = json.loads(fresh_path.read_text())
    baseline = json.loads(baseline_path.read_text())
    return compare_artifacts(fresh, baseline, fresh_path.name)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="*", default=None,
                    help=f"artifact file names (default: {', '.join(DEFAULT_ARTIFACTS)})")
    ap.add_argument("--fresh-dir", default=".", help="directory of fresh artifacts")
    ap.add_argument("--baseline-dir", default=str(ROOT / "benchmarks" / "baselines"))
    args = ap.parse_args(argv)

    names = args.artifacts or list(DEFAULT_ARTIFACTS)
    all_errors: list[str] = []
    for artifact in names:
        errors, advisories = check_pair(
            Path(args.fresh_dir) / artifact, Path(args.baseline_dir) / artifact)
        for line in advisories:
            print(f"  note: {line}")
        for line in errors:
            print(f"FAIL: {line}", file=sys.stderr)
        if not errors:
            print(f"ok: {artifact} matches baseline")
        all_errors.extend(errors)
    return 1 if all_errors else 0


if __name__ == "__main__":
    raise SystemExit(main())

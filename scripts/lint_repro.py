#!/usr/bin/env python
"""Thin wrapper over the numerics-invariant lint pass (repro.analysis.lint).

Exists so the pass runs without an installed package or PYTHONPATH:

  python scripts/lint_repro.py [paths...] [--rules RPL002,RPL006]

Equivalent to ``PYTHONPATH=src python -m repro.analysis`` and to the
``repro-lint`` console script of an installed checkout.  docs/analysis.md
has the rule catalog and allowlist format.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())

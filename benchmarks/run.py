"""Benchmark harness — one module per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]
                                          [--engine jax|numpy]

``--engine`` selects the bit-accurate replay backend for modules that
support it (table1/fig6): the compiled jax engine or the numpy host path.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

from . import (attn_bench, dryrun_summary, dse_bench, fig4_comparison,
               fig5_fa_usage, fig6_error_dist, inject_bench, kernel_bench,
               lowrank_fidelity, matrix_bench, policy_bench, serve_bench,
               table1_accuracy, table2_energy, train_numerics_bench)

MODULES = {
    "table1": table1_accuracy,
    "table2": table2_energy,
    "fig4": fig4_comparison,
    "fig5": fig5_fa_usage,
    "fig6": fig6_error_dist,
    "lowrank": lowrank_fidelity,
    "kernels": kernel_bench,
    "attn": attn_bench,
    "dse": dse_bench,
    "train": train_numerics_bench,
    "inject": inject_bench,
    "serve": serve_bench,
    "matrix": matrix_bench,
    "policy": policy_bench,
    "dryrun": dryrun_summary,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--engine", choices=["jax", "numpy"], default="jax")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            kwargs = {"quick": args.quick}
            if "engine" in inspect.signature(MODULES[name].run).parameters:
                kwargs["engine"] = args.engine
            for row in MODULES[name].run(**kwargs):
                print(row)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Roofline summary over the dry-run artifacts (deliverables e+g)."""
from __future__ import annotations

from pathlib import Path

from repro.launch.roofline import table


def run(quick: bool = False) -> list[str]:
    d = Path("experiments/dryrun")
    if not d.exists():
        return ["dryrun_summary,0,missing (run scripts/run_campaign.sh)"]
    rows = []
    ok = skipped = err = deploy_ok = 0
    for r in table(d):
        if "t_compute_s" in r:
            ok += 1
            rows.append(
                f"roofline_{r['cell']},0,"
                f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f};"
                f"useful={r['useful_ratio']:.2f};fits={r['fits']}")
        elif r.get("status") == "skipped":
            skipped += 1
        elif r.get("status") == "ok":
            deploy_ok += 1  # multi-pod cells: deployment compile only (no cost)
        else:
            err += 1
    rows.insert(0, f"dryrun_campaign,0,roofline_ok={ok};deploy_only_ok={deploy_ok};"
                   f"skipped={skipped};errors={err}")
    return rows

"""amr_inject replay sweep: {pairs, xla, xla_cached, pallas} vs the LUT oracle.

The throughput benchmark behind the inject tentpole (ROADMAP "amr_inject
throughput"): every implementation of the injected integer matmul is
timed AND bit-checked against the 256x256 LUT-gather oracle in one run —

  * ``pairs``      — the PR 4 pairwise replay (every (row, k, col) operand
                     pair gathered + lane-packed individually), kept as the
                     reference baseline the refactor is measured against;
  * ``xla``        — the outer-product replay (weight side lane-packed once
                     per call inside the executable, activations broadcast
                     as full-word masks);
  * ``xla_cached`` — the same path fed a PRE-PACKED weight operand (the
                     cross-step weight-pack cache shape: frozen/once-per-
                     optimizer-step weights packed once, many calls), so
                     per-call work is pure replay;
  * ``pallas``     — the kernels/inject_replay Pallas kernel (compiled on
                     real TPU; interpreter mode on CPU, where its timing is
                     correctness-path only).

Every impl is timed as a jitted executable — how the paths actually run
inside train/serve steps — over the same operand-index batch.

Bit-consistency fields (``bit_exact_vs_lut``, ``max_abs_diff``) must be
exact — ``scripts/check_bench.py`` gates them against the committed
``benchmarks/baselines/BENCH_inject.json`` and this run fails on any
mismatch; timings are ADVISORY (platform-dependent).

  PYTHONPATH=src python -m benchmarks.inject_bench --quick --out BENCH_inject.json

JSON schema (``BENCH_inject.json``)::

  {"schema": "BENCH_inject/v1", "backend": str, "interpret": bool,
   "quick": bool, "border": int,
   "results": [{"impl": "pairs|xla|xla_cached|pallas",
                "schedule": "default"|"dse_c0", "m": int, "n": int, "k": int,
                "bit_exact_vs_lut": bool, "max_abs_diff": float,
                "us_per_call": float}],
   "wall_clock_s": float}
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

BORDER = 8
SIZES = {False: [(32, 64, 48), (64, 128, 96)], True: [(32, 64, 48)]}


def _time(fn, *args, reps=9):
    import jax

    for _ in range(2):
        jax.block_until_ready(fn(*args))  # compile / warm caches
    samples = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        samples.append(time.time() - t0)
    return float(np.min(samples)) * 1e6  # best-of: robust to CI-box noise


def _impl_call(inj, impl, ib):
    """Jitted ``(ia, ib) -> int32 matmul`` for one impl (ib closed over
    where pre-packing applies)."""
    import jax

    from repro.kernels.inject_replay import inject_replay_matmul
    from repro.numerics import injection

    if impl == "pairs":
        return jax.jit(lambda a, b: injection._injected_matmul_pairs(inj, a, b))
    if impl == "xla":
        return jax.jit(lambda a, b: injection.injected_matmul_int(inj, a, b))
    if impl == "xla_cached":
        yw = injection.packed_weights(inj, ib)  # packed ONCE, outside the timed
        # executable — the weight-pack cache's steady state
        fn = jax.jit(lambda a, b, y: injection.injected_matmul_int(
            inj, a, b, packed_ib=y))
        return lambda a, b: fn(a, b, yw)
    if impl == "pallas":
        return lambda a, b: inject_replay_matmul(inj, a, b)  # jits internally
    raise ValueError(impl)


def _sweep_point(inj, table, impl, schedule_tag, ia, ib) -> dict:
    call = _impl_call(inj, impl, ib)
    got = np.asarray(call(ia, ib)).astype(np.int64)
    us = _time(call, ia, ib)
    ia_np, ib_np = np.asarray(ia), np.asarray(ib)
    want = table[ia_np[:, :, None], ib_np[None, :, :]].sum(axis=1)
    diff = int(np.abs(got - want).max())
    m, k = ia_np.shape
    return {
        "impl": impl, "schedule": schedule_tag, "m": m, "n": ib_np.shape[1], "k": k,
        "bit_exact_vs_lut": bool(diff == 0), "max_abs_diff": float(diff),
        "us_per_call": round(us, 1),
    }


def run(quick: bool = False, out: str | None = None) -> list[str]:
    import jax.numpy as jnp

    from repro.core import engine, lut
    from repro.core.dse import lut_from_schedule, materialize, search_assignments
    from repro.kernels.pallas_config import backend_kind, default_interpret
    from repro.numerics import injection

    t0 = time.time()
    rng = np.random.default_rng(0)
    rows: list[str] = []
    results: list[dict] = []

    inj = engine.get_injector(2, BORDER)
    table = lut.build_int8_lut(BORDER).astype(np.int64)

    cands = search_assignments(2, BORDER, k=1, beam_width=8, branch_cap=4,
                               max_nodes=2000)
    dse_sched = materialize(cands[0])
    dse_inj = engine.compile_injector(dse_sched)
    dse_table = lut_from_schedule(dse_sched).astype(np.int64)

    for (m, n, k) in SIZES[quick]:
        ia = jnp.asarray(rng.integers(0, 256, (m, k)))
        ib = jnp.asarray(rng.integers(0, 256, (k, n)))
        for impl in ("pairs", "xla", "xla_cached", "pallas"):
            r = _sweep_point(inj, table, impl, "default", ia, ib)
            results.append(r)
            rows.append(
                f"inject_{impl}_{m}x{n}x{k},{r['us_per_call']:.0f},"
                f"bit_exact={r['bit_exact_vs_lut']}")
        # raw DSE candidate (no registry: the injector is compiled directly)
        # through both production impls at the first size only
        if (m, n, k) == SIZES[quick][0]:
            for impl in ("xla", "pallas"):
                r = _sweep_point(dse_inj, dse_table, impl, "dse_c0", ia, ib)
                results.append(r)
                rows.append(
                    f"inject_{impl}_dse_{m}x{n}x{k},{r['us_per_call']:.0f},"
                    f"bit_exact={r['bit_exact_vs_lut']}")

    artifact = {
        "schema": "BENCH_inject/v1",
        "backend": backend_kind(),
        "interpret": default_interpret(),
        "quick": quick,
        "border": BORDER,
        "results": results,
        "wall_clock_s": round(time.time() - t0, 2),
    }
    out = out or os.environ.get("REPRO_BENCH_INJECT_OUT", "BENCH_inject.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"inject_bench_artifact,0,{out}:{len(results)}_results")

    bad = [(r["impl"], r["schedule"], r["m"], r["n"], r["k"]) for r in results
           if not r["bit_exact_vs_lut"] or r["max_abs_diff"] != 0.0]
    if bad:
        raise RuntimeError(f"injected replay disagrees with the LUT oracle: {bad}")
    injection.WEIGHT_PACKS.clear()  # leave no bench arrays pinned
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="artifact path (BENCH_inject.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, out=args.out):
        print(row)


if __name__ == "__main__":
    main()

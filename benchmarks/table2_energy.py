"""Paper Table II: delay/power/energy/area model, calibrated + validated.

Calibration protocol (DESIGN.md §2): fit the linear component model on HALF
the paper's design points (exact + every other border, per width), predict
the held-out half, report per-metric mean relative error and the headline
8-digit energy-reduction ratio.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AMRMultiplier
from repro.core.energy import DesignFeatures, fit, predict

from .paper_data import HEADLINE, TABLE2


def _designs():
    out = []
    for digits, ref in TABLE2.items():
        for i, border in enumerate(ref["borders"]):
            out.append((digits, border, ref["area_um2"][i], ref["energy_pj"][i],
                        ref["delay_ns"][i]))
    return out


def run(quick: bool = False) -> list[str]:
    t0 = time.time()
    designs = _designs()
    mults = {(d, b): AMRMultiplier(d, border=b) for d, b, *_ in designs}
    feats = [DesignFeatures.from_multiplier(mults[(d, b)]) for d, b, *_ in designs]
    area = np.array([a for *_, a, _, _ in designs], float)
    energy = np.array([e for *_, e, _ in designs], float)
    delay = np.array([dl for *_, dl in designs], float)

    train_idx = list(range(0, len(designs), 2))
    test_idx = list(range(1, len(designs), 2))
    model = fit([feats[i] for i in train_idx], area[train_idx],
                energy[train_idx], delay[train_idx])

    rows = []
    rel = {"area": [], "energy": [], "delay": []}
    for i in test_idx:
        d, b, *_ = designs[i]
        p = predict(model, mults[(d, b)])
        rel["area"].append(abs(p["area_um2"] - area[i]) / area[i])
        rel["energy"].append(abs(p["energy_pj"] - energy[i]) / energy[i])
        rel["delay"].append(abs(p["delay_ns"] - delay[i]) / delay[i])
    us = (time.time() - t0) * 1e6
    rows.append(f"table2_holdout_fit,{us:.0f},"
                + ";".join(f"{k}_relerr={np.mean(v):.3f}" for k, v in rel.items()))

    # headline: 8-digit border-50 energy reduction (paper: ~7.1x @ MARED 1.6e-2)
    full = fit(feats, area, energy, delay)
    e_exact = predict(full, mults[(8, None)])["energy_pj"]
    e_b50 = predict(full, mults[(8, 50)])["energy_pj"]
    rows.append(f"table2_headline_8d_b50,{(time.time()-t0)*1e6:.0f},"
                f"model_energy_reduction={e_exact / e_b50:.2f}x;"
                f"paper={HEADLINE['energy_reduction_8digit_b50']:.2f}x")
    return rows

"""The paper's published numbers (Tables I & II) — calibration + comparison
references for the benchmarks. Source: AMR-MUL paper §IV."""

# Table I: accuracy vs approximate border column
TABLE1 = {
    2: {"borders": [6, 7, 8, 9, 10],
        "mred": [1.29e-2, -2.12e-3, 2.03e-3, 5.70e-4, -4.57e-2],
        "mared": [2.98e-2, 4.37e-2, 1.06e-1, 2.68e-1, 5.97e-1],
        "nmed": [4.00e-4, 5.98e-4, 1.25e-3, 3.34e-3, 7.34e-3]},
    4: {"borders": [12, 15, 18, 21, 24],
        "mred": [1.31e-4, 2.35e-3, 1.18e-2, 6.90e-2, 1.76e-1],
        "mared": [2.71e-4, 3.88e-3, 2.50e-2, 1.51e-1, 5.33e-1],
        "nmed": [-1.00e-6, -7.00e-6, -7.70e-5, -2.76e-4, -3.43e-3]},
    8: {"borders": [45, 48, 50, 53, 55],
        "mred": [1.06e-4, 5.52e-4, 2.71e-3, 3.90e-2, -1.97e-2],
        "mared": [9.29e-4, 7.09e-3, 1.61e-2, 1.58e-1, 5.18e-1],
        "nmed": [3.00e-6, 1.50e-5, 5.60e-5, 4.34e-4, 2.36e-3]},
}

# Table II: design parameters vs border (NanGate45, Synopsys DC @ max freq)
TABLE2 = {
    2: {"borders": [None, 6, 7, 8, 9, 10],
        "delay_ns": [0.73, 0.72, 0.71, 0.71, 0.71, 0.69],
        "power_mw": [0.87, 0.84, 0.75, 0.59, 0.50, 0.37],
        "energy_pj": [0.63, 0.61, 0.54, 0.42, 0.36, 0.25],
        "area_um2": [1263, 1297, 1145, 972, 844, 764]},
    4: {"borders": [None, 12, 15, 18, 21, 24],
        "delay_ns": [1.04, 1.03, 1.00, 0.94, 0.91, 0.73],
        "power_mw": [4.67, 3.41, 2.85, 2.32, 1.49, 1.03],
        "energy_pj": [4.85, 3.51, 2.85, 2.18, 1.36, 0.75],
        "area_um2": [5408, 4120, 3617, 3243, 2358, 2167]},
    8: {"borders": [None, 45, 48, 50, 53, 55],
        "delay_ns": [1.23, 1.11, 1.05, 1.00, 0.95, 0.95],
        "power_mw": [16.91, 4.07, 3.23, 2.93, 2.07, 1.52],
        "energy_pj": [20.80, 4.51, 3.39, 2.93, 1.96, 1.44],
        "area_um2": [18330, 6815, 6207, 5794, 5085, 4583]},
}

# §IV.B: exact BNS multiplier references
EXACT_BNS = {8: {"delay_ns": 0.89, "energy_pj": 0.24},
             16: {"delay_ns": 1.22, "energy_pj": 2.6},
             32: {"delay_ns": 1.65, "energy_pj": 17.5}}

HEADLINE = {"energy_reduction_8digit_b50": 20.80 / 2.93,   # ~7.1x
            "mared_8digit_b50": 1.61e-2}                    # ~1.6% accuracy loss

"""Model-level numerics-policy search benchmark (BENCH_policy.json).

Two halves, one artifact:

  * uniform_parity — the API-redesign safety net: for every registered
    mode, ``UniformPolicy(nm)`` must trace the SAME computation as the
    legacy bare ``AMRNumerics`` — training logits bitwise equal AND served
    token/logit streams identical.  Gated exactly by
    ``scripts/check_bench.py`` (any flip means the policy indirection
    changed numerics, which it never may).
  * model-level search — the payoff: run the real pipeline
    (``pareto_sweep`` -> ``frontier_choices`` -> short training ->
    ``measure_sensitivity`` -> ``search_model_policy``) on a reduced
    config and record the searched per-layer policy against every uniform
    point at the same budget.  The ``searched`` row's
    ``dominates_best_uniform`` flag is gated True: the heterogeneous
    assignment must beat the best feasible uniform policy on fidelity at
    no more energy.  Frontier tiers and uniform energies are
    integer/seeded-MC derived and gated exactly; fidelities/losses ride on
    float matmuls and stay advisory.

  PYTHONPATH=src python -m benchmarks.policy_bench --quick \
      --out BENCH_policy.json

JSON schema (``BENCH_policy/v1``)::

  {"schema": "BENCH_policy/v1", "quick": bool, "samples": int,
   "results": [
     {"kind": "uniform_parity", "mode": str, "bit_exact": bool,
      "tokens_match": bool, "max_abs_diff": float},
     {"kind": "frontier", "label": str, "energy_per_mac": float,
      "err": float},
     {"kind": "uniform", "label": str, "energy": float, "feasible": bool,
      "fidelity": float, "loss": float},
     {"kind": "searched", "label": "searched", "policy": str,
      "energy": float, "fidelity": float, "moves": int,
      "dominates_best_uniform": bool}],
   "wall_clock_s": float}
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

SAMPLES = 4000
BORDERS = (4, 5, 6, 7, 8, 9, 10)


def _parity_modes():
    # registry defaults supply each mode's rank (lowrank=4, kernel=0) — no
    # mode-name matching here (lint rule RPL001); parity compares each nm
    # against UniformPolicy(nm), so the exact design point is irrelevant.
    from repro.numerics import default_policy, mode_names

    return [default_policy(m, border=2) for m in mode_names()]


def _tiny_cfg(numerics):
    from repro.configs.base import ModelConfig

    return ModelConfig(
        name="policy-bench", family="dense", vocab=61, d_model=32, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, numerics=numerics)


def _uniform_parity(nm) -> dict:
    """Bare AMRNumerics vs UniformPolicy(nm): train logits + served streams."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import init_params
    from repro.numerics import UniformPolicy
    from repro.serve import Request, ServeEngine
    from repro.train.steps import loss_fn

    prompts = [(5, 9, 2, 7), (3, 11, 4, 1, 8, 6), (13, 2)]
    max_diff = 0.0
    tokens_match = True
    outs = []
    for numerics in (nm, UniformPolicy(nm)):
        cfg = _tiny_cfg(numerics)
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
        _, (_, logits) = loss_fn(cfg, params, toks[:, :-1], toks[:, 1:],
                                 step=jnp.zeros((), jnp.int32),
                                 with_logits=True)
        eng = ServeEngine(cfg, params, n_slots=2, capacity=16,
                          record_logits=True)
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=3))
        outs.append((np.asarray(logits, np.float32), eng.run()))
    (lg_a, done_a), (lg_b, done_b) = outs
    max_diff = float(np.max(np.abs(lg_a - lg_b)))
    for a, b in zip(done_a, done_b):
        tokens_match &= a.tokens == b.tokens
        for la, lb in zip(a.logits, b.logits):
            max_diff = max(max_diff, float(np.max(np.abs(
                np.asarray(la) - np.asarray(lb)))))
    return {"kind": "uniform_parity", "mode": nm.mode,
            "bit_exact": max_diff == 0.0, "tokens_match": bool(tokens_match),
            "max_abs_diff": max_diff}


def _search_arm(quick: bool, samples: int) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_reduced_config
    from repro.core.dse import pareto
    from repro.core.dse.model_policy import (frontier_choices,
                                             measure_sensitivity,
                                             policy_energy,
                                             search_model_policy,
                                             site_mac_counts)
    from repro.data import SyntheticLM
    from repro.launch.cli import policy_label
    from repro.train.steps import make_train_state, make_train_step

    points = pareto.pareto_sweep(2, BORDERS, k=1, n_samples=samples,
                                 beam_width=8, branch_cap=3, max_nodes=2000)
    choices = frontier_choices(points)
    results = [{"kind": "frontier", "label": c.label,
                "energy_per_mac": c.energy_per_mac, "err": c.err}
               for c in choices]

    cfg = dataclasses.replace(get_reduced_config("gemma-2b"), n_layers=4)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=5,
                                   total_steps=20), donate_argnums=(0,))
    for i in range(20):
        state, _ = step(state, {k: jnp.asarray(v)
                                for k, v in data.batch_at(i).items()})
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    sens = measure_sensitivity(cfg, state.params, batch)

    # pin the budget at the mid frontier tier's uniform energy: the search
    # starts AT the best uniform point and must leave it strictly behind
    unit_macs = [m for sites in site_mac_counts(cfg) for _, m in sites]
    mid = len(choices) // 2
    budget = policy_energy(unit_macs, [mid] * len(unit_macs), choices)
    result = search_model_policy(
        cfg, state.params, batch, choices, budget=budget, sensitivity=sens,
        max_moves=2 if quick else 8, beam=3 if quick else 4)

    for u in result.uniform.values():
        results.append({"kind": "uniform", "label": u["label"],
                        "energy": u["energy"], "feasible": u["feasible"],
                        "fidelity": u["fidelity"], "loss": u["loss"]})
    best = result.best_uniform
    dominates = (result.energy <= best["energy"]
                 and result.fidelity < best["fidelity"])
    results.append({"kind": "searched", "label": "searched",
                    "policy": policy_label(result.policy),
                    "energy": result.energy, "fidelity": result.fidelity,
                    "moves": len(result.history),
                    "dominates_best_uniform": dominates})
    return results


def run(quick: bool = False, out: str | None = None) -> list[str]:
    t0 = time.time()
    samples = SAMPLES if quick else 4 * SAMPLES
    rows: list[str] = []
    results: list[dict] = []

    for nm in _parity_modes():
        r = _uniform_parity(nm)
        results.append(r)
        rows.append(f"policy_parity_{r['mode']},0,bit_exact={r['bit_exact']};"
                    f"tokens_match={r['tokens_match']};"
                    f"max_abs_diff={r['max_abs_diff']:.4g}")

    t_arm = time.time()
    search_rows = _search_arm(quick, samples)
    results.extend(search_rows)
    for r in search_rows:
        if r["kind"] == "uniform":
            rows.append(f"policy_uniform_{r['label']},0,"
                        f"energy={r['energy']:.4g};feasible={r['feasible']};"
                        f"fidelity={r['fidelity']:.4g}")
        elif r["kind"] == "searched":
            rows.append(f"policy_searched,0,{r['policy']};"
                        f"energy={r['energy']:.4g};"
                        f"fidelity={r['fidelity']:.4g};"
                        f"dominates={r['dominates_best_uniform']};"
                        f"wall={time.time() - t_arm:.1f}s")

    artifact = {
        "schema": "BENCH_policy/v1",
        "quick": quick,
        "samples": samples,
        "results": results,
        "wall_clock_s": round(time.time() - t0, 2),
    }
    out = out or os.environ.get("REPRO_BENCH_POLICY_OUT", "BENCH_policy.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"policy_bench_artifact,0,{out}:{len(results)}_results")

    # Hard gates — a broken invariant fails the bench, not just the diff.
    broken = [r["mode"] for r in results if r["kind"] == "uniform_parity"
              and not (r["bit_exact"] and r["tokens_match"])]
    if broken:
        raise RuntimeError(
            f"UniformPolicy is not bit-identical to bare AMRNumerics: {broken}")
    searched = [r for r in results if r["kind"] == "searched"]
    if not all(r["dominates_best_uniform"] for r in searched):
        raise RuntimeError(
            "searched per-layer policy failed to dominate the best uniform")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="artifact path (BENCH_policy.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, out=args.out):
        print(row)


if __name__ == "__main__":
    main()

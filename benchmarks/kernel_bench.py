"""Kernel micro-benchmarks (CPU interpret mode: correctness-path timing only —
TPU wall times come from the roofline analysis, not this box)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.amr_matmul.ops import amr_matmul
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ref_ssd
from repro.numerics import AMRNumerics, approx_matmul


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
    us_k = _time(lambda x, y: amr_matmul(x, y, border=8, rank=8, interpret=True), a, b)
    us_r = _time(lambda x, y: approx_matmul(x, y, AMRNumerics("amr_lowrank", border=8, rank=8)), a, b)
    us_lut = _time(lambda x, y: approx_matmul(x, y, AMRNumerics("amr_lut", border=8)), a, b)
    rows.append(f"kernel_amr_matmul_128_interp,{us_k:.0f},jnp_lowrank={us_r:.0f}us;jnp_lut_gather={us_lut:.0f}us")

    B, S, H, P, N, chunk = 1, 512, 4, 64, 64, 128
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    al = jnp.asarray(rng.uniform(0, 1.5, (H,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    us_k = _time(lambda *t: ssd_scan(*t, chunk, interpret=True), x, dt, al, bb, cc)
    us_r = _time(lambda *t: ref_ssd(*t, chunk), x, dt, al, bb, cc)
    rows.append(f"kernel_ssd_scan_512_interp,{us_k:.0f},jnp_ref={us_r:.0f}us")
    return rows

"""amr_matmul kernel sweep: {low-rank, full-LUT, exact XLA} x borders x sizes.

Times each variant AND measures its max-abs-error against the schedule
engine's exact AMR replay (``ref_bitexact_int8`` — per-element products
from the engine-built table), so accuracy and speed land in one run, and
writes the ``BENCH_kernel.json`` artifact (schema below; CI uploads it
from the tier-1 job).  On CPU the Pallas kernels run in interpreter mode
(backend autodetect — timings are correctness-path only; real wall times
come from TPU runs of the same sweep); the full-LUT variant must be
bit-exact vs the replay on every backend.

  PYTHONPATH=src python -m benchmarks.kernel_bench --quick --out BENCH_kernel.json

JSON schema (``BENCH_kernel.json``)::

  {"schema": "BENCH_kernel/v1", "backend": str, "interpret": bool,
   "engine": str,
   "results": [{"variant": "lowrank|lut|exact", "border": int|null,
                "rank": int|null, "m": int, "n": int, "k": int,
                "bm": int, "bn": int, "bk": int,
                "us_per_call": float, "max_abs_err_vs_amr": float,
                "bit_exact_vs_amr": bool}]}
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.amr_matmul.kernel import amr_matmul_int8, amr_matmul_int8_lut
from repro.kernels.amr_matmul.ops import lut_factors
from repro.kernels.amr_matmul.ref import ref_bitexact_int8
from repro.kernels.amr_matmul.tiling import pick_tiles
from repro.kernels.pallas_config import backend_kind, default_interpret
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ref_ssd
from repro.core import lut as lut_lib

RANK = 8  # low-rank variant's rank in the sweep


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _sweep_point(a8, b8, want, border: int | None, variant: str, engine: str) -> dict:
    m, k = a8.shape
    n = b8.shape[1]
    rank = None
    if variant == "exact":
        bm = bn = bk = 0  # XLA picks its own tiling
        fn = jax.jit(lambda x, y: jnp.matmul(
            x.astype(jnp.float32), y.astype(jnp.float32)))
        got = np.asarray(fn(a8, b8)).astype(np.float64)
        us = _time(fn, a8, b8)
    elif variant == "lowrank":
        rank = RANK
        t = pick_tiles(m, n, k, variant="lowrank")
        bm, bn, bk = t.bm, t.bn, t.bk
        u, v = lut_factors(border, RANK, engine)
        fn = lambda x, y: amr_matmul_int8(x, y, u, v, bm=bm, bn=bn, bk=bk)  # noqa: E731
        got = np.asarray(fn(a8, b8)).astype(np.float64)
        us = _time(fn, a8, b8)
    elif variant == "lut":
        t = pick_tiles(m, n, k, variant="lut")
        bm, bn, bk = t.bm, t.bn, t.bk
        table = lut_lib.table_array(border, engine)
        fn = lambda x, y: amr_matmul_int8_lut(x, y, table, bm=bm, bn=bn, bk=bk)  # noqa: E731
        got = np.asarray(fn(a8, b8)).astype(np.float64)
        us = _time(fn, a8, b8)
    else:
        raise ValueError(variant)
    err = float(np.abs(got - want).max())
    return {
        "variant": variant, "border": border, "rank": rank,
        "m": m, "n": n, "k": k, "bm": bm, "bn": bn, "bk": bk,
        "us_per_call": round(us, 1),
        "max_abs_err_vs_amr": err,
        "bit_exact_vs_amr": bool(err == 0.0),
    }


def run(quick: bool = False, engine: str = "jax", out: str | None = None) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    sizes = [(128, 128, 128)] if quick else [(128, 128, 128), (256, 256, 256)]
    borders = (4, 8) if quick else (None, 4, 8)
    # one fused engine call builds every border's table up front
    lut_lib.build_int8_luts(borders, engine=engine)

    results = []
    for (m, n, k) in sizes:
        a8 = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        for border in borders:
            # one oracle per (size, border), shared by all three variants
            want = ref_bitexact_int8(
                np.asarray(a8), np.asarray(b8), border=border).astype(np.float64)
            for variant in ("exact", "lowrank", "lut"):
                r = _sweep_point(a8, b8, want, border, variant, engine)
                results.append(r)
                btag = "exact" if border is None else f"b{border}"
                rows.append(
                    f"kernel_amr_{variant}_{m}x{n}x{k}_{btag},{r['us_per_call']:.0f},"
                    f"max_abs_err={r['max_abs_err_vs_amr']:.3g};"
                    f"bit_exact={r['bit_exact_vs_amr']}")

    artifact = {
        "schema": "BENCH_kernel/v1",
        "backend": backend_kind(),
        "interpret": default_interpret(),
        "engine": engine,
        "results": results,
    }
    out = out or os.environ.get("REPRO_BENCH_OUT", "BENCH_kernel.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"kernel_bench_artifact,0,{out}:{len(results)}_results")

    # ssd_scan timing kept for continuity with the pre-sweep bench
    B, S, H, P, N, chunk = 1, 512, 4, 64, 64, 128
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    al = jnp.asarray(rng.uniform(0, 1.5, (H,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    us_k = _time(lambda *t: ssd_scan(*t, chunk, interpret=True), x, dt, al, bb, cc)
    us_r = _time(lambda *t: ref_ssd(*t, chunk), x, dt, al, bb, cc)
    rows.append(f"kernel_ssd_scan_512_interp,{us_k:.0f},jnp_ref={us_r:.0f}us")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engine", choices=["jax", "numpy"], default="jax")
    ap.add_argument("--out", default=None, help="artifact path (BENCH_kernel.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, engine=args.engine, out=args.out):
        print(row)


if __name__ == "__main__":
    main()

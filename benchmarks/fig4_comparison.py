"""Paper Fig. 4: AMR-MUL vs approximate BNS multipliers (accuracy axis).

We implement the BNS baselines functionally (DRUM, truncation/LETAM-class,
exact) and compare MARED at 8/16-bit-equivalent operand widths. Energy for
BNS designs is reported from the paper's own reference values where given
(exact BNS) — cost-model extrapolations for approximate BNS designs are
labeled as estimates.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AMRMultiplier
from repro.core.baselines import drum, exact_mul, mared, trunc_mul

from .paper_data import EXACT_BNS


def run(quick: bool = False) -> list[str]:
    n = 20_000 if quick else 100_000
    rng = np.random.default_rng(1)
    rows = []
    for width, digits, borders in [(8, 2, (6, 8, 10)), (16, 4, (15, 18, 21))]:
        t0 = time.time()
        lo, hi = -(2 ** (width - 1)), 2 ** (width - 1)
        x = rng.integers(lo, hi, n)
        y = rng.integers(lo, hi, n)
        ex = exact_mul(x, y)
        for k in (3, 4, 6):
            rows.append(f"fig4_drum{k}_{width}b,{(time.time()-t0)*1e6:.0f},"
                        f"mared={mared(drum(x, y, k), ex):.3e}")
        for t in (width // 2, width // 2 + 2):
            rows.append(f"fig4_trunc{t}_{width}b,{(time.time()-t0)*1e6:.0f},"
                        f"mared={mared(trunc_mul(x, y, width, t), ex):.3e}")
        for b in borders:
            m = AMRMultiplier(digits, border=b)
            r = m.monte_carlo(n if not quick else n // 2, seed=2)
            rows.append(f"fig4_amr_{digits}d_b{b},{(time.time()-t0)*1e6:.0f},"
                        f"mared={r['mared']:.3e}")
        rows.append(f"fig4_exact_bns_{width}b,0,"
                    f"delay={EXACT_BNS[width]['delay_ns']}ns;"
                    f"energy={EXACT_BNS[width]['energy_pj']}pJ (paper ref)")
    return rows

"""Continuous-batching serving bench: latency/throughput + batching exactness.

Two row kinds over the tiny LM (same scale as train_numerics_bench):

  * ``throughput`` — serve a fixed request set through ``ServeEngine`` at
    several concurrency levels (slot counts) and record p50/p99 request
    latency, end-to-end tokens/s and steady-state decode tokens/s (decode
    steps only — compile and prefill excluded; a warmup cycle runs first).
  * ``bit_exact`` — the continuous-batching correctness gate: the same
    mixed-length request set is served batched (3 slots) and solo (1 slot,
    identical code path) under each numerics mode; token streams must match
    and the recorded per-token logit streams must agree BITWISE
    (``max_abs_diff`` exactly 0.0). This covers the integer AMR modes
    (amr_lut / amr_inject / amr_kernel-rank0) and exact.

  PYTHONPATH=src python -m benchmarks.serve_bench --quick --out BENCH_serve.json

JSON schema (``BENCH_serve/v1``)::

  {"schema": "BENCH_serve/v1", "engine": "jax", "quick": bool,
   "gen": int, "capacity": int, "border": int,
   "config": {"d_model": int, "d_ff": int, "vocab": int, "n_layers": int},
   "results": [{"kind": "throughput", "mode": str, "concurrency": int,
                "requests": int, "tokens": int, "complete": bool,
                "p50_latency_ms": float, "p99_latency_ms": float,
                "tokens_per_s": float, "steady_tokens_per_s": float},
               {"kind": "bit_exact", "mode": str, "concurrency": int,
                "requests": int, "bit_exact": bool, "tokens_match": bool,
                "max_abs_diff": float}],
   "wall_clock_s": float}

``scripts/check_bench.py`` gates ``complete`` / ``bit_exact`` /
``tokens_match`` / ``max_abs_diff`` exactly against
``benchmarks/baselines/BENCH_serve.json``; the latency/throughput numbers
are advisory (host-speed dependent).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

BORDER = 8
CFG = dict(d_model=32, d_ff=64, vocab=64, n_layers=2)
CONCURRENCIES = (1, 2, 4)
BATCHED_SLOTS = 3
# mixed prompt lengths on purpose: slots decode at different cache depths
PROMPT_LENS = (4, 6, 2, 5, 7, 3)


def _tiny_config(numerics):
    from repro.configs.base import ModelConfig

    return ModelConfig(
        name="serve-bench-tiny", family="dense", n_layers=CFG["n_layers"],
        d_model=CFG["d_model"], n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=CFG["d_ff"], vocab=CFG["vocab"], mlp_act="swiglu",
        tie_embeddings=True, remat="none", numerics=numerics)


def _requests(n, gen, vocab):
    from repro.serve import Request

    rng = np.random.default_rng(0)
    out = []
    for i in range(n):
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        prompt = tuple(int(t) for t in rng.integers(0, vocab, plen))
        out.append(Request(prompt=prompt, max_new_tokens=gen))
    return out


def _serve(cfg, params, requests, n_slots, capacity, *, record_logits,
           warmup=True):
    from repro.serve import Request, ServeEngine

    engine = ServeEngine(cfg, params, n_slots=n_slots, capacity=capacity,
                         record_logits=record_logits)
    if warmup:
        for r in requests:  # compile every distinct prompt length + decode
            engine.submit(Request(prompt=r.prompt, max_new_tokens=2))
        engine.run()
        engine.completions.clear()
        engine.steps_done = 0
        engine.decode_seconds = 0.0
        engine.decode_tokens = 0
    for r in requests:
        engine.submit(Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                              eos_id=r.eos_id))
    t0 = time.monotonic()
    done = engine.run()
    wall = time.monotonic() - t0
    return engine, done, wall


def _throughput_row(cfg, params, concurrency, gen, capacity, n_requests):
    reqs = _requests(n_requests, gen, cfg.vocab)
    engine, done, wall = _serve(cfg, params, reqs, concurrency, capacity,
                                record_logits=False)
    lat = sorted(c.total_s for c in done)
    total_tokens = sum(len(c.tokens) for c in done)
    complete = (len(done) == n_requests
                and all(len(c.tokens) == gen for c in done))
    steady = (engine.decode_tokens / engine.decode_seconds
              if engine.decode_seconds > 0 else 0.0)
    return {
        "kind": "throughput", "mode": cfg.numerics.mode,
        "concurrency": concurrency, "requests": n_requests,
        "tokens": total_tokens, "complete": bool(complete),
        "p50_latency_ms": round(lat[len(lat) // 2] * 1e3, 3),
        "p99_latency_ms": round(lat[min(int(len(lat) * 0.99), len(lat) - 1)] * 1e3, 3),
        "tokens_per_s": round(total_tokens / wall, 1),
        "steady_tokens_per_s": round(steady, 1),
    }


def _bit_exact_row(make_cfg, gen, capacity, n_requests):
    """Batched (3 slots) vs solo (1 slot) token+logit streams, one mode."""
    import jax

    from repro.models import init_params

    cfg = make_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(n_requests, gen, cfg.vocab)
    _, batched, _ = _serve(cfg, params, reqs, BATCHED_SLOTS, capacity,
                           record_logits=True, warmup=False)
    _, solo, _ = _serve(cfg, params, reqs, 1, capacity,
                        record_logits=True, warmup=False)
    tokens_match = all(b.tokens == s.tokens for b, s in zip(batched, solo))
    max_diff = 0.0
    for b, s in zip(batched, solo):
        for lb, ls in zip(b.logits, s.logits):
            max_diff = max(max_diff, float(np.max(np.abs(lb - ls))))
    return {
        "kind": "bit_exact", "mode": cfg.numerics.mode,
        "concurrency": BATCHED_SLOTS, "requests": n_requests,
        "bit_exact": bool(tokens_match and max_diff == 0.0),
        "tokens_match": bool(tokens_match),
        "max_abs_diff": max_diff,
    }


def run(quick: bool = False, out: str | None = None) -> list[str]:
    import jax

    from repro.models import init_params
    from repro.numerics import AMRNumerics

    t0 = time.time()
    gen = 4 if quick else 8
    n_requests = 4 if quick else 6
    capacity = max(PROMPT_LENS) + gen
    rows: list[str] = []
    results: list[dict] = []

    # -- latency / throughput at several concurrency levels (exact mode) ----
    cfg = _tiny_config(AMRNumerics("exact"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    for conc in CONCURRENCIES:
        r = _throughput_row(cfg, params, conc, gen, capacity, n_requests)
        results.append(r)
        rows.append(f"serve_throughput_c{conc},0,"
                    f"p50={r['p50_latency_ms']}ms;p99={r['p99_latency_ms']}ms;"
                    f"steady={r['steady_tokens_per_s']}tok/s")

    # -- batched-vs-solo exactness per numerics mode -------------------------
    policies = [
        lambda: _tiny_config(AMRNumerics("exact")),
        lambda: _tiny_config(AMRNumerics("amr_lut", border=BORDER)),
        lambda: _tiny_config(AMRNumerics("amr_inject", border=BORDER)),
        lambda: _tiny_config(AMRNumerics("amr_kernel", border=BORDER, rank=0)),
    ]
    for make_cfg in policies:
        r = _bit_exact_row(make_cfg, gen, capacity, n_requests)
        results.append(r)
        rows.append(f"serve_bit_exact_{r['mode']},0,"
                    f"bit_exact={r['bit_exact']};max_abs_diff={r['max_abs_diff']}")

    artifact = {
        "schema": "BENCH_serve/v1",
        "engine": "jax",
        "quick": quick,
        "gen": gen,
        "capacity": capacity,
        "border": BORDER,
        "config": CFG,
        "results": results,
        "wall_clock_s": round(time.time() - t0, 2),
    }
    out = out or os.environ.get("REPRO_BENCH_SERVE_OUT", "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"serve_bench_artifact,0,{out}:{len(results)}_results")

    # Hard gates mirrored from check_bench: incomplete serving or any
    # batching-dependent numerics drift fails the bench run itself.
    bad = [r["mode"] for r in results
           if r["kind"] == "bit_exact" and not r["bit_exact"]]
    if bad:
        raise RuntimeError(
            f"slot-batched decode is not bit-identical to solo decode under "
            f"mode(s): {bad}")
    incomplete = [r["concurrency"] for r in results
                  if r["kind"] == "throughput" and not r["complete"]]
    if incomplete:
        raise RuntimeError(
            f"serve run did not complete all requests at concurrency "
            f"{incomplete}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="artifact path (BENCH_serve.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, out=args.out):
        print(row)


if __name__ == "__main__":
    main()

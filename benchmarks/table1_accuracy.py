"""Paper Table I: MRED/MARED/NMED vs border column for 2/4/8-digit AMR-MULs."""
from __future__ import annotations

import time

from repro.core import AMRMultiplier

from .paper_data import TABLE1

# paper uses 50K/500K/1M; scaled for CPU wall-time (MARED is stable well
# before that — std error ~ mared/sqrt(n))
SAMPLES = {2: 50_000, 4: 100_000, 8: 50_000}
SAMPLES_QUICK = {2: 20_000, 4: 20_000, 8: 5_000}


def run(quick: bool = False, engine: str = "jax") -> list[str]:
    rows = []
    samples = SAMPLES_QUICK if quick else SAMPLES
    for digits, ref in TABLE1.items():
        for i, border in enumerate(ref["borders"]):
            t0 = time.time()
            m = AMRMultiplier(digits, border=border, engine=engine)
            r = m.monte_carlo(samples[digits], seed=0)
            us = (time.time() - t0) * 1e6
            ratio = r["mared"] / ref["mared"][i]
            rows.append(
                f"table1_{digits}d_b{border}[{engine}],{us:.0f},"
                f"mared={r['mared']:.3e};paper={ref['mared'][i]:.3e};"
                f"ratio={ratio:.2f};mred={r['mred']:+.2e};nmed={r['nmed']:+.2e}")
    return rows

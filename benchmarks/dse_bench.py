"""Engine-in-the-loop DSE sweep: measured (error, energy) Pareto frontier.

For each digit width the whole-multiplier search (``core.dse``) produces k
candidate cell assignments per border; every candidate is materialized into
a real ``reduction.Schedule``, Monte-Carlo-measured through ONE fused engine
dispatch per operand chunk (``engine.compile_candidates``), and costed with
the component energy model calibrated against the paper's Table II.  The
run fails (exit 1) unless the measured (|MRED|, energy) frontier keeps at
least ``MIN_FRONTIER`` non-dominated points per digit width, and every
candidate's measured metrics are re-derived from a *direct* per-candidate
engine replay of the exported schedule — ``replay_match`` must be
bit-identical (float-equal) or the run fails.

  PYTHONPATH=src python -m benchmarks.dse_bench --quick --out BENCH_dse.json

JSON schema (``BENCH_dse.json``)::

  {"schema": "BENCH_dse/v1", "engine": "jax", "quick": bool,
   "samples": {"<n_digits>": int},
   "results": [{"n_digits": int, "border": int, "candidate": int,
                "expected_error": float, "mred": float, "mared": float,
                "nmed": float, "energy_pj": float, "nodes": int,
                "complete": bool, "frontier": bool, "replay_match": bool}],
   "frontier_sizes": {"<n_digits>": int},
   "nodes_visited": int, "wall_clock_s": float}

``scripts/check_bench.py`` diffs the error fields against the committed
baseline under ``benchmarks/baselines/`` — accuracy drift fails CI.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import dse, metrics, mrsd, ppgen, reduction
from repro.core.energy import DesignFeatures, fit

from .paper_data import TABLE2

MIN_FRONTIER = 3  # acceptance floor: non-dominated points per digit width

# borders swept per digit width (paper Table I/II design points; the last
# paper border per width is dropped in --quick to bound CI time)
SWEEP = {
    False: {4: (12, 15, 18, 21, 24), 8: (45, 48, 50, 53, 55)},
    True: {4: (12, 15, 18, 21), 8: (45, 48, 50, 53)},
}
SAMPLES = {False: {4: 65536, 8: 32768}, True: {4: 16384, 8: 8192}}
SEARCH_KW = {
    False: dict(beam_width=32, branch_cap=6, max_nodes=40_000),
    True: dict(beam_width=16, branch_cap=4, max_nodes=8_000),
}


def calibrated_model():
    """Energy model fit on ALL of the paper's Table II design points."""
    feats, area, energy, delay = [], [], [], []
    for digits, ref in TABLE2.items():
        for i, border in enumerate(ref["borders"]):
            feats.append(DesignFeatures.from_schedule(
                reduction.get_schedule(digits, border)))
            area.append(ref["area_um2"][i])
            energy.append(ref["energy_pj"][i])
            delay.append(ref["delay_ns"][i])
    return fit(feats, np.asarray(area), np.asarray(energy), np.asarray(delay))


def _direct_metrics(schedule, n_samples: int, seed: int, chunk: int) -> dict:
    """Reference metrics from a DIRECT single-schedule engine replay.

    Same rng protocol as ``dse.measure_candidates`` but each chunk runs the
    candidate's own compiled replay and the exact schedule's, separately —
    the oracle the fused-dispatch measurement must match bit for bit.
    """
    from repro.core import engine as engine_mod

    n = schedule.n_digits
    eng = engine_mod.compile_schedule(schedule)
    exact = engine_mod.get_engine(n, None)
    acc = metrics.ErrorAccumulator(max_abs=(16.0 ** n * (16.0 / 15.0)) ** 2)
    rng = np.random.default_rng(seed)
    remaining = n_samples
    while remaining > 0:
        b = min(chunk, remaining)
        xd = mrsd.random_digits(rng, n, b)
        yd = mrsd.random_digits(rng, n, b)
        xb = ppgen.flatten_operand_bits(xd)
        yb = ppgen.flatten_operand_bits(yd)
        acc.update_split(*eng.evaluate_split(xb, yb),
                         *exact.evaluate_split(xb, yb))
        remaining -= b
    return acc.result()


def run(quick: bool = False, out: str | None = None) -> list[str]:
    t0 = time.time()
    rows = []
    model = calibrated_model()
    cost = lambda s: model.energy(DesignFeatures.from_schedule(s))  # noqa: E731

    results = []
    frontier_sizes = {}
    samples_used = {}
    total_nodes = 0
    for n_digits, borders in sorted(SWEEP[quick].items()):
        n_samples = SAMPLES[quick][n_digits]
        chunk = min(n_samples, 16384)
        samples_used[str(n_digits)] = n_samples
        t_sweep = time.time()
        points = dse.pareto_sweep(
            n_digits, borders, k=2 if n_digits <= 4 else 1,
            n_samples=n_samples, seed=0, chunk=chunk, cost_fn=cost,
            err_key="mred", **SEARCH_KW[quick])
        sweep_us = (time.time() - t_sweep) * 1e6
        for pt in points:
            direct = _direct_metrics(pt.schedule, n_samples, seed=0, chunk=chunk)
            replay_match = direct == pt.measured
            if pt.candidate == 0:
                # candidates of one border share one search's node total
                total_nodes += pt.assignment.nodes
            results.append({
                "n_digits": pt.n_digits, "border": pt.border,
                "candidate": pt.candidate,
                "expected_error": float(pt.assignment.expected_error),
                "mred": pt.measured["mred"], "mared": pt.measured["mared"],
                "nmed": pt.measured["nmed"],
                "energy_pj": round(pt.energy, 6),
                "nodes": pt.assignment.nodes,
                "complete": pt.assignment.complete,
                "frontier": pt.frontier, "replay_match": replay_match,
            })
            rows.append(
                f"dse_{pt.n_digits}d_b{pt.border}_c{pt.candidate},0,"
                f"mred={pt.measured['mred']:+.3e};mared={pt.measured['mared']:.3e};"
                f"energy_pj={pt.energy:.2f};frontier={pt.frontier};"
                f"replay_match={replay_match}")
        n_front = sum(pt.frontier for pt in points)
        frontier_sizes[str(n_digits)] = n_front
        rows.append(f"dse_sweep_{n_digits}d,{sweep_us:.0f},"
                    f"{len(points)}_candidates;{n_front}_on_frontier")

    artifact = {
        "schema": "BENCH_dse/v1",
        "engine": "jax",
        "quick": quick,
        "samples": samples_used,
        "results": results,
        "frontier_sizes": frontier_sizes,
        "nodes_visited": total_nodes,
        "wall_clock_s": round(time.time() - t0, 2),
    }
    out = out or os.environ.get("REPRO_BENCH_DSE_OUT", "BENCH_dse.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"dse_bench_artifact,0,{out}:{len(results)}_results")

    # Hard gates: the artifact is only worth shipping if the frontier is
    # populated and the fused measurement matches the direct replay exactly.
    bad_replay = [r for r in results if not r["replay_match"]]
    if bad_replay:
        raise RuntimeError(
            f"fused measurement != direct engine replay for "
            f"{[(r['n_digits'], r['border'], r['candidate']) for r in bad_replay]}")
    thin = {d: n for d, n in frontier_sizes.items() if n < MIN_FRONTIER}
    if thin:
        raise RuntimeError(
            f"measured Pareto frontier too thin (< {MIN_FRONTIER}): {thin}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="artifact path (BENCH_dse.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, out=args.out):
        print(row)


if __name__ == "__main__":
    main()

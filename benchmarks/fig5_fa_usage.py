"""Paper Fig. 5: percentage breakdown of employed FA types."""
from __future__ import annotations

import time

from repro.core import AMRMultiplier


def run(quick: bool = False) -> list[str]:
    rows = []
    for digits, border in [(4, 24), (8, 50)]:
        t0 = time.time()
        m = AMRMultiplier(digits, border=border)
        usage = m.cell_usage_percent()
        us = (time.time() - t0) * 1e6
        detail = ";".join(f"{k}={v:.1f}%" for k, v in usage.items())
        # paper's qualitative claims: FA_PP dominant among approximates,
        # FA_NP2 (large positive error) least used
        approx = {k: v for k, v in usage.items() if k != "FA"}
        claims = (f"pp_dominant={max(approx, key=approx.get) == 'FA_PP'};"
                  f"np2_rare={min(approx, key=approx.get) in ('FA_NP2', 'FA_NN', 'FA_PN1')}")
        rows.append(f"fig5_usage_{digits}d_b{border},{us:.0f},{detail};{claims}")
    return rows

"""Beyond-paper: fidelity of the low-rank MXU form vs the bit-exact LUT."""
from __future__ import annotations

import time

import numpy as np

from repro.core.lut import build_int8_lut, exact_int8_table, lowrank_factor


def run(quick: bool = False) -> list[str]:
    rows = []
    err = build_int8_lut(8).astype(np.float64) - exact_int8_table()
    scale = np.abs(exact_int8_table()).mean()
    for rank in (2, 4, 8, 16, 32, 64, 128, 256):
        t0 = time.time()
        f = lowrank_factor(8, rank)
        resid_abs = np.abs(err - f.reconstruct()).mean()
        rows.append(f"lowrank_r{rank},{(time.time()-t0)*1e6:.0f},"
                    f"fro_resid={f.residual_fro:.4f};"
                    f"mean_abs_resid={resid_abs:.2f};"
                    f"flops_multiplier={1 + rank}x")
    return rows

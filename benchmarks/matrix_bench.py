"""Cross-architecture numerics conformance matrix (the tentpole artifact).

Drives ``repro.conformance`` over the config zoo: tiny reduced variants of
every family (dense attention, SSM, hybrid, MoE, audio encoder-decoder,
VLM) through the real train-step and prefill->decode paths under every
registered numerics mode, recording per-arm invariants:

  * train               — finite loss/grads, non-degenerate logits;
  * inject_audit        — amr_inject bit-identical to the LUT-gather oracle
                          at every dense call site (grid-step units);
  * decode_parity       — prefill->decode vs full forward within per-mode
                          tolerance;
  * noise_decorrelation — amr_noise reproducible within a step coordinate,
                          distinct across steps;
  * restart             — FaultTolerantLoop under amr_inject preempted
                          mid-run resumes bitwise (loss-stream equality),
                          including DSE-schedule re-registration (full run).

  PYTHONPATH=src python -m benchmarks.matrix_bench --quick \
      --out BENCH_matrix.json

JSON schema (``BENCH_matrix.json``)::

  {"schema": "BENCH_matrix/v1", "engine": "jax", "quick": bool,
   "border": int,
   "results": [{"kind": "train", "arch": str, "mode": str, ...},
               {"kind": "inject_audit", "arch": str, "schedule": str,
                "bit_exact": bool, "max_abs_diff": float, ...},
               {"kind": "decode_parity", "arch": str, "mode": str,
                "applicable": bool, "within_tol": bool, ...},
               {"kind": "noise_decorrelation", "arch": str, ...},
               {"kind": "restart", "arch": str, "schedule": str,
                "bit_exact": bool, "tmp_cleaned": bool, ...}],
   "wall_clock_s": float}

``scripts/check_bench.py`` gates every exactness/finiteness field against
``benchmarks/baselines/BENCH_matrix.json``; losses and parity diffs are
advisory (they ride on float matmuls).  Quick mode keeps CI tractable:
one representative arch per family, with amr_inject (the load-bearing
approximate mode) and exact covering the train grid and the full mode
list covered on the dense representative; ``--quick`` off sweeps every
arch x every mode (the nightly workflow).
"""
from __future__ import annotations

import argparse
import json
import os
import time

BORDER = 8
QUICK_TRAIN_MODES = ("exact", "amr_inject")


def _arms(quick: bool):
    from repro.conformance import REPRESENTATIVE, arch_mode_arms
    from repro.numerics import is_exact_mode, mode_names

    reps = list(REPRESENTATIVE.values())
    modes = list(mode_names())
    if quick:
        train = [(a, m) for a in reps for m in QUICK_TRAIN_MODES]
        # full mode list still exercised, on the dense representative
        dense = REPRESENTATIVE["dense"]
        train += [(dense, m) for m in modes if m not in QUICK_TRAIN_MODES]
        parity = [(a, "exact") for a in reps] + \
                 [(dense, m) for m in modes if not is_exact_mode(m)]
        audit = reps
        noise = [dense]
    else:
        train = arch_mode_arms()
        parity = arch_mode_arms()
        from repro.configs import ALL_NAMES
        audit = list(ALL_NAMES)
        noise = reps
    return train, parity, audit, noise


def run(quick: bool = False, out: str | None = None) -> list[str]:
    from repro.conformance import (
        run_decode_parity,
        run_inject_audit,
        run_noise_decorrelation,
        run_restart_arm,
        run_train_arm,
    )
    from repro.core import reduction
    from repro.numerics import injection

    t0 = time.time()
    rows: list[str] = []
    results: list[dict] = []
    train, parity, audit, noise = _arms(quick)

    for arch, mode in train:
        t_arm = time.time()
        r = run_train_arm(arch, mode, steps=2)
        results.append(r)
        rows.append(
            f"matrix_train_{arch}_{mode},0,"
            f"loss={r['first_loss']:.4f}->{r['final_loss']:.4f};"
            f"finite={r['loss_finite'] and r['grad_finite']};"
            f"wall={time.time() - t_arm:.1f}s")

    for arch in audit:
        r = run_inject_audit(arch)
        results.append(r)
        rows.append(f"matrix_audit_{arch},0,bit_exact={r['bit_exact']};"
                    f"sites={r['sites']};calls={r['calls']}")

    for arch, mode in parity:
        r = run_decode_parity(arch, mode)
        results.append(r)
        rows.append(f"matrix_parity_{arch}_{mode},0,"
                    f"diff={r['parity_diff']:.4g};within_tol={r['within_tol']}")

    for arch in noise:
        r = run_noise_decorrelation(arch)
        results.append(r)
        rows.append(f"matrix_noise_{arch},0,reproducible={r['reproducible']};"
                    f"decorrelated={r['steps_decorrelated']}")

    t_arm = time.time()
    r = run_restart_arm()
    results.append(r)
    rows.append(f"matrix_restart_default,0,bit_exact={r['bit_exact']};"
                f"resumed_from={r['resumed_from']};"
                f"wall={time.time() - t_arm:.1f}s")
    if not quick:
        # the DSE-schedule restart: registry wiped between lives, restored
        # by the on_restore hook — the real process-death protocol
        sched = reduction.get_schedule(2, BORDER)
        handle = injection.register_schedule(sched, name="matrix:restart")
        r = run_restart_arm(
            schedule_ref=handle,
            between_lives=lambda: (injection._SCHEDULES.pop(handle, None),
                                   injection._INJECTORS.pop(handle, None)),
            on_restore=lambda s, st: injection.register_schedule(
                sched, name=handle))
        results.append(r)
        rows.append(f"matrix_restart_dse,0,bit_exact={r['bit_exact']}")

    artifact = {
        "schema": "BENCH_matrix/v1",
        "engine": "jax",
        "quick": quick,
        "border": BORDER,
        "results": results,
        "wall_clock_s": round(time.time() - t0, 2),
    }
    out = out or os.environ.get("REPRO_BENCH_MATRIX_OUT", "BENCH_matrix.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"matrix_bench_artifact,0,{out}:{len(results)}_results")

    # Hard gates — the artifact is only worth committing if the invariants
    # hold; a regression must fail the bench itself, not just the diff.
    sick = [(r["arch"], r["mode"]) for r in results if r["kind"] == "train"
            and not (r["loss_finite"] and r["grad_finite"]
                     and r["nondegenerate"])]
    if sick:
        raise RuntimeError(f"non-finite/degenerate train arms: {sick}")
    bad = [r["arch"] for r in results if r["kind"] == "inject_audit"
           and (not r["bit_exact"] or r["max_abs_diff"] != 0.0)]
    if bad:
        raise RuntimeError(f"amr_inject disagrees with the LUT oracle: {bad}")
    off = [(r["arch"], r["mode"]) for r in results
           if r["kind"] == "decode_parity" and not r["within_tol"]]
    if off:
        raise RuntimeError(f"decode parity out of tolerance: {off}")
    broken = [r["arch"] for r in results if r["kind"] == "restart"
              and not (r["bit_exact"] and r["tmp_cleaned"])]
    if broken:
        raise RuntimeError(f"restart not bit-consistent: {broken}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="artifact path (BENCH_matrix.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, out=args.out):
        print(row)


if __name__ == "__main__":
    main()

"""Training under the numerics policy: loss trajectories per AMR mode.

The bridge benchmark from circuit to workload (ROADMAP "open a new
workload" axis): one tiny LM is trained under ``exact`` / ``amr_noise`` /
``amr_inject`` / ``amr_lut`` numerics — plus ``amr_inject`` driving a raw
DSE candidate schedule straight from the whole-multiplier search (no
materialized LUT) — and the loss trajectories are recorded side by side.
Before training, the injected path is asserted BIT-CONSISTENT with the
``amr_lut`` gather oracle at oracle-feasible shapes (max_abs_diff must be
exactly 0.0), for both the default schedule and the DSE candidate; the run
fails (exit 1) on any mismatch or non-finite loss/grad.

  PYTHONPATH=src python -m benchmarks.train_numerics_bench --quick \
      --out BENCH_train.json

JSON schema (``BENCH_train.json``)::

  {"schema": "BENCH_train/v1", "engine": "jax", "quick": bool,
   "steps": int, "border": int,
   "config": {"d_model": int, "d_ff": int, "vocab": int, "n_layers": int,
              "seq": int, "batch": int},
   "results": [{"mode": str, "schedule": "default"|"dse_c0",
                "border": int|null, "first_loss": float, "final_loss": float,
                "loss_finite": bool, "grad_finite": bool,
                "params_finite": bool, "s_per_step": float},
               {"mode": "consistency", "case": str, "bit_exact": bool,
                "max_abs_diff": float}],
   "wall_clock_s": float}

``scripts/check_bench.py`` gates the bit-consistency / finiteness fields
exactly against ``benchmarks/baselines/BENCH_train.json``; losses and
timings are advisory (they ride on float matmuls whose last ulp is
platform/BLAS dependent, unlike the integer-exact consistency fields).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

MODES = ("exact", "amr_noise", "amr_inject", "amr_lut")
BORDER = 8
STEPS = {False: 12, True: 6}
CFG = dict(d_model=32, d_ff=64, vocab=64, n_layers=2, seq=16, batch=4)


def _tiny_config(numerics):
    from repro.configs.base import ModelConfig

    return ModelConfig(
        name="train-bench-tiny", family="dense", n_layers=CFG["n_layers"],
        d_model=CFG["d_model"], n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=CFG["d_ff"], vocab=CFG["vocab"], mlp_act="swiglu",
        tie_embeddings=True, remat="none", numerics=numerics)


def _dse_candidate_ref():
    """Register a whole-multiplier search candidate for injection."""
    from repro.core.dse import materialize, search_assignments
    from repro.numerics import injection

    cands = search_assignments(2, BORDER, k=1, beam_width=8, branch_cap=4,
                               max_nodes=2000)
    sched = materialize(cands[0])
    return injection.register_schedule(sched, name="bench:dse_c0"), sched


def _consistency_case(name, numerics, reference_table):
    """Injected matmul vs the LUT-gather oracle on an oracle-feasible shape."""
    import jax
    import jax.numpy as jnp

    from repro.numerics import approx_matmul
    from repro.numerics.quant import quantize_int8

    a = jax.random.normal(jax.random.PRNGKey(11), (8, 24), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(12), (24, 12), jnp.float32)
    got = np.asarray(jax.jit(lambda a, b: approx_matmul(a, b, numerics))(a, b))
    qa, sa = quantize_int8(a, axis=-1)
    qb, sb = quantize_int8(b, axis=0)
    ia = np.asarray(qa, np.int64) + 128
    ib = np.asarray(qb, np.int64) + 128
    acc = reference_table[ia[:, :, None], ib[None, :, :]].sum(-2).astype(np.float32)
    want = acc * np.asarray(sa) * np.asarray(sb)
    diff = float(np.abs(got - want).max())
    return {"mode": "consistency", "case": name,
            "bit_exact": bool(np.array_equal(got, want)),
            "max_abs_diff": diff}


def _train_arm(mode, schedule_tag, numerics, steps):
    import jax
    import jax.numpy as jnp

    from repro.data import SyntheticLM
    from repro.train.steps import make_grads_step, make_train_state, make_train_step

    cfg = _tiny_config(numerics)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=CFG["seq"], batch=CFG["batch"],
                       seed=0, noise=0.02)
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, peak_lr=5e-3, warmup=2, total_steps=steps),
                   donate_argnums=(0,))
    losses = []
    t0 = time.time()
    last_b = None
    for i in range(steps):
        last_b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, last_b)
        losses.append(float(m["loss"]))
        if i == 0:
            t0 = time.time()  # exclude the compile step from the timing
    s_per_step = (time.time() - t0) / max(steps - 1, 1)

    def _finite(tree):
        return all(bool(np.isfinite(np.asarray(g, np.float32)).all())
                   for g in jax.tree_util.tree_leaves(tree))

    # actual gradients of the TRAINED params (not just the updated params):
    # the STE backward of every approximate mode must stay finite
    grads = jax.jit(make_grads_step(cfg))(state.params, last_b)
    return {
        "mode": mode, "schedule": schedule_tag,
        "border": None if numerics.is_exact() else BORDER,
        "first_loss": round(losses[0], 6), "final_loss": round(losses[-1], 6),
        "loss_finite": bool(np.isfinite(losses).all()),
        "grad_finite": _finite(grads),
        "params_finite": _finite(state.params),
        "s_per_step": round(s_per_step, 4),
    }, losses


def run(quick: bool = False, out: str | None = None) -> list[str]:
    from repro.core import lut
    from repro.core.dse import lut_from_schedule
    from repro.numerics import AMRNumerics

    t0 = time.time()
    steps = STEPS[quick]
    rows: list[str] = []
    results: list[dict] = []

    dse_ref, dse_sched = _dse_candidate_ref()

    # -- bit-consistency: injected path vs the LUT-gather oracle -----------
    results.append(_consistency_case(
        f"inject_vs_lut_b{BORDER}", AMRNumerics("amr_inject", border=BORDER),
        lut.build_int8_lut(BORDER)))
    results.append(_consistency_case(
        "inject_dse_vs_lut_export",
        AMRNumerics("amr_inject", border=BORDER, schedule_ref=dse_ref),
        lut_from_schedule(dse_sched)))

    # -- loss trajectories --------------------------------------------------
    arms = [(m, "default", AMRNumerics(m, border=BORDER)) for m in MODES]
    arms.append(("amr_inject", "dse_c0",
                 AMRNumerics("amr_inject", border=BORDER, schedule_ref=dse_ref)))
    for mode, tag, nm in arms:
        t_arm = time.time()
        row, losses = _train_arm(mode, tag, nm, steps)
        results.append(row)
        rows.append(
            f"train_{mode}_{tag},{row['s_per_step'] * 1e6:.0f},"
            f"loss={losses[0]:.4f}->{losses[-1]:.4f};finite={row['loss_finite']}"
            f";wall={time.time() - t_arm:.1f}s")

    artifact = {
        "schema": "BENCH_train/v1",
        "engine": "jax",
        "quick": quick,
        "steps": steps,
        "border": BORDER,
        "config": CFG,
        "results": results,
        "wall_clock_s": round(time.time() - t0, 2),
    }
    out = out or os.environ.get("REPRO_BENCH_TRAIN_OUT", "BENCH_train.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"train_bench_artifact,0,{out}:{len(results)}_results")

    # Hard gates: consistency must be bit-exact, every arm finite.
    bad = [r["case"] for r in results
           if r.get("mode") == "consistency"
           and (not r["bit_exact"] or r["max_abs_diff"] != 0.0)]
    if bad:
        raise RuntimeError(f"amr_inject disagrees with the amr_lut oracle: {bad}")
    sick = [(r["mode"], r["schedule"]) for r in results
            if r.get("mode") != "consistency"
            and not (r["loss_finite"] and r["grad_finite"] and r["params_finite"])]
    if sick:
        raise RuntimeError(f"non-finite loss/grad under numerics mode(s): {sick}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="artifact path (BENCH_train.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, out=args.out):
        print(row)


if __name__ == "__main__":
    main()

"""Paper Fig. 6: relative-error distribution shape (near-Gaussian, mu ~= 0).

Contrast: AMR-MUL's signed-cell compensation vs a truncation multiplier's
one-sided error (the paper's point about prior compressors' negative bias).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import AMRMultiplier, exact_multiplier, relative_errors
from repro.core import mrsd
from repro.core.baselines import trunc_mul


def _moments(re: np.ndarray) -> dict:
    mu, sd = re.mean(), re.std()
    z = (re - mu) / max(sd, 1e-12)
    return {"mean": mu, "std": sd, "skew": float((z**3).mean()),
            "exkurt": float((z**4).mean() - 3.0),
            "within_1sigma": float((np.abs(z) < 1).mean())}


def run(quick: bool = False) -> list[str]:
    n = 20_000 if quick else 100_000
    t0 = time.time()
    rng = np.random.default_rng(0)
    xd = mrsd.random_digits(rng, 2, n)
    yd = mrsd.random_digits(rng, 2, n)
    m = AMRMultiplier(2, border=8)
    approx = m.multiply_digits(xd, yd)
    exact = exact_multiplier(2).multiply_digits(xd, yd)
    re_amr = relative_errors(approx, exact)
    re_amr = re_amr[np.abs(re_amr) < 1.0]  # paper plots the [-1, 1] window
    amr = _moments(re_amr)

    x = rng.integers(-128, 128, n)
    y = rng.integers(-128, 128, n)
    tr = trunc_mul(x, y, width=8, t=4).astype(np.float64)
    ex = (x * y).astype(np.float64)
    nz = ex != 0
    re_tr = (tr[nz] - ex[nz]) / ex[nz]
    re_tr = re_tr[np.abs(re_tr) < 1.0]
    trm = _moments(re_tr)

    us = (time.time() - t0) * 1e6
    return [
        f"fig6_amr_2d_b8,{us:.0f},mean={amr['mean']:+.3e};std={amr['std']:.3e};"
        f"skew={amr['skew']:+.2f};exkurt={amr['exkurt']:+.2f};"
        f"within1sigma={amr['within_1sigma']:.2f}",
        f"fig6_trunc8_t4,{us:.0f},mean={trm['mean']:+.3e};std={trm['std']:.3e};"
        f"skew={trm['skew']:+.2f} (one-sided bias vs AMR's ~0 mean)",
    ]

"""Paper Fig. 6: relative-error distribution shape (near-Gaussian, mu ~= 0).

Contrast: AMR-MUL's signed-cell compensation vs a truncation multiplier's
one-sided error (the paper's point about prior compressors' negative bias).

The AMR replay runs on the selected backend (``engine="jax"`` compiles the
schedule once and evaluates batched on-device; ``"numpy"`` is the host
reference).  On the jax backend an extra row reports the measured replay
speedup over numpy at a >= 64K batch.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (AMRMultiplier, exact_multiplier, mrsd, ppgen,
                        reduction, relative_errors)
from repro.core.baselines import trunc_mul

SPEEDUP_BATCH = 65_536  # acceptance batch for the engine-vs-numpy timing row


def _moments(re: np.ndarray) -> dict:
    mu, sd = re.mean(), re.std()
    z = (re - mu) / max(sd, 1e-12)
    return {"mean": mu, "std": sd, "skew": float((z**3).mean()),
            "exkurt": float((z**4).mean() - 3.0),
            "within_1sigma": float((np.abs(z) < 1).mean())}


def _time_backends(m: AMRMultiplier, batch: int, repeats: int = 3) -> tuple[float, float]:
    """Best-of-N wall time (s) of the numpy vs jax replay on one batch."""
    from repro.core import engine as engine_mod

    rng = np.random.default_rng(42)
    xd = mrsd.random_digits(rng, m.cfg.n_digits, batch)
    yd = mrsd.random_digits(rng, m.cfg.n_digits, batch)
    xb = ppgen.flatten_operand_bits(xd)
    yb = ppgen.flatten_operand_bits(yd)
    eng = engine_mod.get_engine(m.cfg.n_digits, m.cfg.border)
    eng.evaluate_split(xb, yb)  # warm-up: compile outside the timed region
    t_np = min(
        _timed(lambda: reduction.evaluate_split(m.schedule, xb, yb))
        for _ in range(repeats)
    )
    t_jax = min(_timed(lambda: eng.evaluate_split(xb, yb)) for _ in range(repeats))
    return t_np, t_jax


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(quick: bool = False, engine: str = "jax") -> list[str]:
    n = 20_000 if quick else 100_000
    t0 = time.time()
    rng = np.random.default_rng(0)
    xd = mrsd.random_digits(rng, 2, n)
    yd = mrsd.random_digits(rng, 2, n)
    m = AMRMultiplier(2, border=8, engine=engine)
    approx = m.multiply_digits(xd, yd)
    exact = exact_multiplier(2).multiply_digits(xd, yd, engine=engine)
    re_amr = relative_errors(approx, exact)
    re_amr = re_amr[np.abs(re_amr) < 1.0]  # paper plots the [-1, 1] window
    amr = _moments(re_amr)

    x = rng.integers(-128, 128, n)
    y = rng.integers(-128, 128, n)
    tr = trunc_mul(x, y, width=8, t=4).astype(np.float64)
    ex = (x * y).astype(np.float64)
    nz = ex != 0
    re_tr = (tr[nz] - ex[nz]) / ex[nz]
    re_tr = re_tr[np.abs(re_tr) < 1.0]
    trm = _moments(re_tr)

    us = (time.time() - t0) * 1e6
    rows = [
        f"fig6_amr_2d_b8[{engine}],{us:.0f},mean={amr['mean']:+.3e};std={amr['std']:.3e};"
        f"skew={amr['skew']:+.2f};exkurt={amr['exkurt']:+.2f};"
        f"within1sigma={amr['within_1sigma']:.2f}",
        f"fig6_trunc8_t4,{us:.0f},mean={trm['mean']:+.3e};std={trm['std']:.3e};"
        f"skew={trm['skew']:+.2f} (one-sided bias vs AMR's ~0 mean)",
    ]
    if engine == "jax":
        batch = SPEEDUP_BATCH // 4 if quick else SPEEDUP_BATCH
        t_np, t_jax = _time_backends(m, batch)
        rows.append(
            f"fig6_engine_speedup_b{batch},{t_jax*1e6:.0f},"
            f"numpy_ms={t_np*1e3:.1f};jax_ms={t_jax*1e3:.1f};"
            f"speedup={t_np/t_jax:.1f}x")
    return rows

"""Fused-attention kernel bench: fused Pallas call vs the unfused seam.

Times ``kernels.attn_fused.fused_attention`` (LUT gather / injection
replay inside one kernel with the masked softmax) against the jitted
unfused composition (``fused_attention_reference`` — literally the
models/attention.py seam chain on pre-folded operands), and records the
bit-identity the kernel promises: both methods must agree with the seam
EXACTLY (``max_abs_diff == 0.0``) on every backend, interpret or
compiled.  Shapes cover the decode-style ragged mask plus word-ragged
T/P (the injection path's lane-padding edge).

  PYTHONPATH=src python -m benchmarks.attn_bench --quick --out BENCH_attn.json

JSON schema (``BENCH_attn.json``)::

  {"schema": "BENCH_attn/v1", "backend": str, "interpret": bool,
   "results": [{"method": "lut|inject", "border": int,
                "g": int, "m": int, "d": int, "t": int, "p": int,
                "bm": int, "us_per_call": float, "ref_us_per_call": float,
                "max_abs_diff": float, "bit_exact": bool}]}
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.amr_matmul.tiling import pick_attn_tile
from repro.kernels.attn_fused import fused_attention, fused_attention_reference
from repro.kernels.pallas_config import backend_kind, default_interpret

# (G, M, D, T, P): grouped heads, query rows, head_dim, attended length,
# value head_dim.  The (2, 8, 8, 40, 24) point keeps T and P off the
# 32-column lane-word grid on purpose.
QUICK_SHAPES = [(2, 8, 8, 32, 16), (2, 8, 8, 40, 24)]
FULL_SHAPES = QUICK_SHAPES + [(4, 16, 16, 64, 16)]


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _case(g, m, d, t, p, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (g, m, d), jnp.float32)
    kt = jax.random.normal(ks[1], (g, d, t), jnp.float32)
    v = jax.random.normal(ks[2], (g, t, p), jnp.float32)
    lengths = jax.random.randint(ks[3], (g, m), 1, t + 1)
    mask = jnp.arange(t)[None, None, :] < lengths[:, :, None]
    return q, kt, v, mask


def _sweep_point(method: str, border: int, shape) -> dict:
    g, m, d, t, p = shape
    ops = _case(*shape)
    fused = jax.jit(lambda q, kt, v, mask: fused_attention(
        q, kt, v, mask, method=method, border=border))
    ref = jax.jit(lambda q, kt, v, mask: fused_attention_reference(
        q, kt, v, mask, method=method, border=border))
    got = np.asarray(fused(*ops)).astype(np.float64)
    want = np.asarray(ref(*ops)).astype(np.float64)
    diff = float(np.abs(got - want).max())
    return {
        "method": method, "border": border,
        "g": g, "m": m, "d": d, "t": t, "p": p,
        "bm": pick_attn_tile(m, d),
        "us_per_call": round(_time(fused, *ops), 1),
        "ref_us_per_call": round(_time(ref, *ops), 1),
        "max_abs_diff": diff,
        "bit_exact": bool(diff == 0.0),
    }


def run(quick: bool = False, out: str | None = None) -> list[str]:
    rows = []
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    borders = (8,) if quick else (4, 8)
    results = []
    for shape in shapes:
        for border in borders:
            for method in ("lut", "inject"):
                r = _sweep_point(method, border, shape)
                results.append(r)
                g, m, d, t, p = shape
                rows.append(
                    f"attn_fused_{method}_g{g}m{m}d{d}t{t}p{p}_b{border},"
                    f"{r['us_per_call']:.0f},"
                    f"ref={r['ref_us_per_call']:.0f}us;"
                    f"max_abs_diff={r['max_abs_diff']:.3g};"
                    f"bit_exact={r['bit_exact']}")

    artifact = {
        "schema": "BENCH_attn/v1",
        "backend": backend_kind(),
        "interpret": default_interpret(),
        "results": results,
    }
    out = out or os.environ.get("REPRO_BENCH_OUT", "BENCH_attn.json")
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
    rows.append(f"attn_bench_artifact,0,{out}:{len(results)}_results")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="artifact path (BENCH_attn.json)")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick, out=args.out):
        print(row)


if __name__ == "__main__":
    main()
